"""Wireless channel models: payload bytes -> transmission time + drop events.

The CHB core already knows the exact payload of every uplink
(``core/quantize.py: payload_bytes_dense / payload_bytes_int8``); this module
turns those bytes into air time, delivery outcomes, and (via ``energy.py``)
joules. Three models, all host-side sampling:

  * ``fixed``     — deterministic bitrate; time = overhead + 8B/rate.
  * ``bernoulli`` — fixed bitrate, but each uplink is lost i.i.d. with
                    probability ``loss_prob``. A lost uplink still costs the
                    full air time and transmit energy; the server's stale
                    bank row is left untouched (the delta never arrives) and
                    the client keeps its local bank copy unchanged, so
                    worker/server views never diverge.
  * ``fading``    — block-fading bitrate: per-transmission rate multiplier
                    drawn from an exponential(1) channel-power gain, floored
                    at ``fading_floor`` (outage => crawling rate, the
                    straggler-by-channel case). Composes with loss_prob.

``kind`` is a preset over the same knobs, so scenario sweeps can also mix
knobs freely (e.g. fading + loss).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


class Transmission(NamedTuple):
    """Outcome of one (up/down)link transmission."""
    time_s: float        # air time actually spent
    delivered: bool      # False => packet lost, payload discarded
    rate_bps: float      # effective bitrate used for this transmission


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Air-interface model for every uplink/downlink transmission.

    Attributes:
      kind: ``"fixed"`` | ``"bernoulli"`` | ``"fading"`` (see module
        docstring); a preset over the shared knobs below.
      uplink_rate_bps: nominal worker->server bitrate.
      downlink_rate_bps: server broadcast bitrate (deterministic,
        lossless).
      overhead_s: per-packet protocol overhead, charged even to zero-byte
        censor beacons.
      loss_prob: i.i.d. uplink loss probability in [0, 1).
      fading_floor: minimum rate multiplier under block fading (outage
        turns into a crawling transmission instead of a loss).
    """
    kind: str = "fixed"             # "fixed" | "bernoulli" | "fading"
    uplink_rate_bps: float = 1e6    # nominal uplink bitrate
    downlink_rate_bps: float = 2e7  # server broadcast bitrate (fast, reliable)
    overhead_s: float = 0.0         # per-packet protocol overhead
    loss_prob: float = 0.0          # Bernoulli uplink loss probability
    fading_floor: float = 0.05      # minimum rate multiplier under fading

    def __post_init__(self):
        if self.kind not in ("fixed", "bernoulli", "fading"):
            raise ValueError(f"unknown channel kind {self.kind!r}")
        if not 0.0 <= self.loss_prob < 1.0:
            raise ValueError("loss_prob must be in [0, 1)")

    # ------------------------------------------------------------- presets
    @classmethod
    def ideal(cls) -> "ChannelConfig":
        """Zero-latency lossless channel — the sync-mode degenerate case."""
        return cls(kind="fixed", uplink_rate_bps=float("inf"),
                   downlink_rate_bps=float("inf"), overhead_s=0.0)

    @classmethod
    def lossy(cls, loss_prob: float, **kw) -> "ChannelConfig":
        return cls(kind="bernoulli", loss_prob=loss_prob, **kw)

    @classmethod
    def fading(cls, **kw) -> "ChannelConfig":
        return cls(kind="fading", **kw)

    # ------------------------------------------------------------ sampling
    def _effective_rate(self, rng: np.random.Generator) -> float:
        rate = self.uplink_rate_bps
        if self.kind == "fading":
            gain = max(float(rng.exponential(1.0)), self.fading_floor)
            rate = rate * gain
        return rate

    def uplink(self, nbytes: int, rng: np.random.Generator) -> Transmission:
        """Sample one uplink transmission of ``nbytes`` payload bytes."""
        rate = self._effective_rate(rng)
        air = self.overhead_s + (8.0 * nbytes / rate if nbytes else 0.0)
        lost = self.loss_prob > 0.0 and bool(rng.random() < self.loss_prob)
        return Transmission(time_s=air, delivered=not lost, rate_bps=rate)

    def downlink_time(self, nbytes: int) -> float:
        """Broadcast latency for ``nbytes`` (deterministic, lossless)."""
        if nbytes == 0 or self.downlink_rate_bps == float("inf"):
            return self.overhead_s
        return self.overhead_s + 8.0 * nbytes / self.downlink_rate_bps

"""Heterogeneous edge-client population for the event-driven runtime.

Each client is described by a static :class:`ClientProfile` (compute speed,
jitter law, availability trace, radio power draw); the population bundles M
profiles plus the per-round participation-sampling policy. All randomness is
host-side ``numpy.random.Generator`` draws — the event loop lives on the
host, only the math (gradients, bank folds, server updates) is jitted.

Availability models
  * ``always``     — the client can be dispatched whenever idle.
  * ``bernoulli``  — available with probability ``avail_p`` per dispatch
                     attempt (intermittent duty-cycling, e.g. deep sleep).
  * ``cycle``      — deterministic on/off square wave in wall-clock time:
                     available iff ((t + phase) mod period) < duty*period
                     (e.g. a phone that charges at night).

Compute-latency models (seconds per local gradient evaluation)
  * ``fixed``      — exactly ``compute_mean_s``.
  * ``exp``        — exponential with mean ``compute_mean_s`` (memoryless
                     interference from other on-device work).
  * ``lognormal``  — lognormal with mean ``compute_mean_s`` and shape
                     ``jitter_sigma`` (heavy-tailed stragglers).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    """Static description of one edge device.

    Attributes:
      compute_mean_s: mean seconds per local gradient evaluation.
      jitter: latency law — ``"fixed"`` | ``"exp"`` | ``"lognormal"``
        (see module docstring).
      jitter_sigma: lognormal shape parameter (heavier tail when larger).
      availability: ``"always"`` | ``"bernoulli"`` | ``"cycle"``.
      avail_p: per-dispatch availability probability (bernoulli model).
      cycle_period_s / cycle_duty / cycle_phase_s: square-wave on/off
        availability trace parameters (cycle model).
      compute_w: device power draw while computing, in watts.
    """
    compute_mean_s: float = 1.0       # mean seconds per gradient evaluation
    jitter: str = "fixed"             # "fixed" | "exp" | "lognormal"
    jitter_sigma: float = 0.5         # lognormal shape parameter
    availability: str = "always"      # "always" | "bernoulli" | "cycle"
    avail_p: float = 1.0              # bernoulli availability probability
    cycle_period_s: float = 60.0      # cycle model: full period
    cycle_duty: float = 0.5           # cycle model: fraction of period on
    cycle_phase_s: float = 0.0        # cycle model: per-client offset
    compute_w: float = 2.0            # device power draw while computing (W)

    def draw_compute_time(self, rng: np.random.Generator) -> float:
        if self.jitter == "fixed":
            return self.compute_mean_s
        if self.jitter == "exp":
            return float(rng.exponential(self.compute_mean_s))
        if self.jitter == "lognormal":
            # parameterize so the mean is compute_mean_s regardless of sigma
            mu = math.log(self.compute_mean_s) - 0.5 * self.jitter_sigma ** 2
            return float(rng.lognormal(mu, self.jitter_sigma))
        raise ValueError(f"unknown jitter model {self.jitter!r}")

    def is_available(self, t: float, rng: np.random.Generator) -> bool:
        if self.availability == "always":
            return True
        if self.availability == "bernoulli":
            return bool(rng.random() < self.avail_p)
        if self.availability == "cycle":
            pos = math.fmod(t + self.cycle_phase_s, self.cycle_period_s)
            return pos < self.cycle_duty * self.cycle_period_s
        raise ValueError(f"unknown availability model {self.availability!r}")


@dataclasses.dataclass(frozen=True)
class Population:
    """M client profiles + the server's per-round sampling policy.

    Attributes:
      profiles: one ``ClientProfile`` per client; the tuple length is M.
      participation: fraction of the idle+available candidates the server
        dispatches each round, in (0, 1].
    """
    profiles: tuple[ClientProfile, ...]
    participation: float = 1.0    # fraction of idle+available clients sampled

    def __post_init__(self):
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")

    @property
    def num_clients(self) -> int:
        return len(self.profiles)

    def as_vector(self) -> "VectorPopulation":
        """Columnar view for the mesh runtime (``fed.mesh``).

        Keeps the mean compute latency and power per client; jitter and
        availability laws are event-runtime concepts and are dropped (the
        mesh runtime's wall-clock model is the nominal mean-latency
        straggler bound — see docs/fed_scaling.md).
        """
        return VectorPopulation(
            compute_mean_s=np.asarray(
                [p.compute_mean_s for p in self.profiles], np.float64),
            compute_w=np.asarray(
                [p.compute_w for p in self.profiles], np.float64),
            participation=self.participation)

    def sample_cohort(self, idle_available: Sequence[int],
                      rng: np.random.Generator) -> list[int]:
        """Server-side client sampling: choose ceil(p * |candidates|)."""
        cands = list(idle_available)
        if not cands:
            return []
        k = max(1, math.ceil(self.participation * len(cands)))
        if k >= len(cands):
            return cands
        return sorted(rng.choice(cands, size=k, replace=False).tolist())


@dataclasses.dataclass(frozen=True)
class VectorPopulation:
    """Columnar client population for the mesh runtime (``fed.mesh``).

    ``Population`` keeps one ``ClientProfile`` object per client — fine
    for the event runtime's hundreds of clients, hopeless for 10^5–10^6
    (a million Python objects before the first round). This is the same
    information as plain arrays, sliceable into contiguous per-shard
    blocks. Only the knobs the synchronous mesh rounds consume are
    carried: per-client compute latency/power (wall-clock + energy
    models) — availability/jitter laws stay event-runtime-only.

    Attributes:
      compute_mean_s: (M,) mean seconds per local gradient evaluation.
      compute_w: (M,) device power draw while computing, in watts.
      participation: per-client per-round cohort-join probability (the
        mesh runtime's i.i.d. Bernoulli analogue of cohort sampling,
        matching ``sweep.fed_sweep``).
    """
    compute_mean_s: np.ndarray
    compute_w: np.ndarray
    participation: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "compute_mean_s",
                           np.asarray(self.compute_mean_s, np.float64))
        object.__setattr__(self, "compute_w",
                           np.asarray(self.compute_w, np.float64))
        if self.compute_mean_s.shape != self.compute_w.shape or \
                self.compute_mean_s.ndim != 1:
            raise ValueError("compute_mean_s/compute_w must be matching "
                             "(M,) vectors")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")

    @property
    def num_clients(self) -> int:
        return int(self.compute_mean_s.shape[0])


def uniform_vector_population(num_clients: int, compute_mean_s: float = 1.0,
                              compute_w: float = 2.0,
                              participation: float = 1.0,
                              straggler_frac: float = 0.0,
                              straggler_slowdown: float = 10.0,
                              seed: int = 0) -> VectorPopulation:
    """Columnar population, optionally with a straggler tail."""
    mean = np.full((num_clients,), compute_mean_s, np.float64)
    if straggler_frac > 0.0:
        rng = np.random.default_rng(seed)
        n_slow = int(round(straggler_frac * num_clients))
        slow = rng.choice(num_clients, size=n_slow, replace=False)
        mean[slow] *= straggler_slowdown
    return VectorPopulation(
        compute_mean_s=mean,
        compute_w=np.full((num_clients,), compute_w, np.float64),
        participation=participation)


# ------------------------------------------------------------ constructors
def uniform_population(num_clients: int, compute_mean_s: float = 1.0,
                       participation: float = 1.0,
                       **profile_kw) -> Population:
    """Identical clients (the paper's implicit deployment)."""
    p = ClientProfile(compute_mean_s=compute_mean_s, **profile_kw)
    return Population(profiles=(p,) * num_clients,
                      participation=participation)


def straggler_population(num_clients: int, compute_mean_s: float = 1.0,
                         straggler_frac: float = 0.1,
                         straggler_slowdown: float = 10.0,
                         jitter: str = "exp",
                         participation: float = 1.0,
                         seed: int = 0, **profile_kw) -> Population:
    """A fraction of clients is ``straggler_slowdown``x slower (tail latency)."""
    rng = np.random.default_rng(seed)
    n_slow = int(round(straggler_frac * num_clients))
    slow = set(rng.choice(num_clients, size=n_slow, replace=False).tolist())
    profiles = tuple(
        ClientProfile(
            compute_mean_s=compute_mean_s * (straggler_slowdown
                                             if i in slow else 1.0),
            jitter=jitter, **profile_kw)
        for i in range(num_clients))
    return Population(profiles=profiles, participation=participation)


def intermittent_population(num_clients: int, compute_mean_s: float = 1.0,
                            avail_p: float = 0.7,
                            participation: float = 1.0,
                            **profile_kw) -> Population:
    """Clients that answer a dispatch only with probability ``avail_p``."""
    p = ClientProfile(compute_mean_s=compute_mean_s,
                      availability="bernoulli", avail_p=avail_p, **profile_kw)
    return Population(profiles=(p,) * num_clients,
                      participation=participation)


def duty_cycle_population(num_clients: int, compute_mean_s: float = 1.0,
                          period_s: float = 60.0, duty: float = 0.5,
                          participation: float = 1.0, seed: int = 0,
                          **profile_kw) -> Population:
    """Deterministic on/off traces with random per-client phase offsets."""
    rng = np.random.default_rng(seed)
    profiles = tuple(
        ClientProfile(compute_mean_s=compute_mean_s, availability="cycle",
                      cycle_period_s=period_s, cycle_duty=duty,
                      cycle_phase_s=float(rng.uniform(0.0, period_s)),
                      **profile_kw)
        for _ in range(num_clients))
    return Population(profiles=profiles, participation=participation)

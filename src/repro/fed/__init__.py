"""repro.fed: event-driven federated edge runtime around the CHB core.

The core (``repro.core``) answers the paper's question — how many uplinks
does censoring save? — under synchronous lockstep rounds. This package
answers the deployment questions the paper raises but never simulates:
stragglers, intermittent availability, lossy/fading channels, partial
participation, and the energy / wall-clock cost of every byte.

    population = fed.straggler_population(9, straggler_frac=0.2)
    edge = fed.EdgeConfig(population=population,
                          channel=fed.ChannelConfig.lossy(0.1),
                          quorum=0.8)
    hist = fed.run_edge(opt.make("chb", alpha, 9), task, edge,
                        num_rounds=500)

``fed.sync_config(M)`` is the correctness anchor: it reproduces
``core.simulator.run`` exactly (see tests/test_fed_runtime.py).

Past ~10^3 clients the event heap stops scaling; ``fed.run_mesh`` runs
the same deployment knobs as synchronous rounds with the client axis
sharded over a device mesh (10^5–10^6 clients — see docs/fed_scaling.md
and ``fed.mesh``'s module docstring for the exactness anchors).
"""
from .channel import ChannelConfig, Transmission
from .clients import (ClientProfile, Population, VectorPopulation,
                      duty_cycle_population, intermittent_population,
                      straggler_population, uniform_population,
                      uniform_vector_population)
from .energy import EdgeStats, EnergyModel
from .mesh import MeshHistory, MeshScenario, run_mesh
from .runner import (EdgeConfig, EdgeHistory, edge_metrics_to_accuracy,
                     quorum_need, run_edge, sync_config)

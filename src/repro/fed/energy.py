"""Per-client energy + wall-clock accounting for the edge runtime.

Extends the core's uplink-count accounting (``core/accounting.CommStats``)
with the quantities the paper motivates but never measures (Sec. I:
"wireless and battery-driven devices"): joules spent computing gradients and
joules spent radiating bytes, plus wall-clock time. Benchmarks can then
report *energy-to-accuracy* and *wall-clock-to-accuracy* instead of uplink
counts alone.

All accounting here is host-side Python ints / numpy float64 — exact byte
counts (no float-accumulator precision cliff) and no jit interaction.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """First-order radio + compute energy model.

    Defaults are in the right ballpark for a WiFi/LTE-class mobile device:
    a few microjoules per transmitted byte and a few watts while computing.
    The *relative* numbers across algorithms are what the benchmarks use.

    Attributes:
      uplink_j_per_byte: radio energy per transmitted payload byte.
      uplink_j_per_tx: fixed per-transmission radio wakeup cost (joules).
      downlink_j_per_byte: receive energy per broadcast byte.
      compute_w: when set, overrides every ``ClientProfile.compute_w``.
    """
    uplink_j_per_byte: float = 5e-6   # radio energy per transmitted byte
    uplink_j_per_tx: float = 1e-3     # fixed per-transmission wakeup cost
    downlink_j_per_byte: float = 1e-6  # receive energy per broadcast byte
    compute_w: float | None = None    # override ClientProfile.compute_w

    def tx_energy(self, nbytes: int) -> float:
        """Joules to transmit one uplink (spent even if the packet drops)."""
        return self.uplink_j_per_tx + self.uplink_j_per_byte * nbytes

    def rx_energy(self, nbytes: int) -> float:
        return self.downlink_j_per_byte * nbytes

    def compute_energy(self, seconds: float, profile_w: float) -> float:
        w = self.compute_w if self.compute_w is not None else profile_w
        return w * seconds

    def round_energy(self, attempted, cohort, payload_bytes: int):
        """Radio joules for one synchronous round (or a whole (B, R) grid).

        ``attempted`` uplinks each pay the tx cost (drops burn air energy
        too); every ``cohort`` member receives the broadcast. This is the
        shared accounting for the synchronous surfaces —
        ``sweep.fed_sweep`` and the mesh runtime (``fed.mesh``) — so their
        energy frontiers are comparable by construction; the event runtime
        (``fed.runner``) accrues the same model per transmission instead.
        Accepts scalars or numpy arrays (vectorized over rounds/points).
        """
        return (attempted * self.tx_energy(payload_bytes)
                + cohort * self.rx_energy(payload_bytes))


@dataclasses.dataclass
class EdgeStats:
    """Mutable per-client deployment accounting, owned by ``fed.runner``.

    ``uplink_bytes`` are exact Python ints; everything else float64.
    """
    num_clients: int
    uplink_count: np.ndarray = None       # (M,) transmissions attempted
    delivered_count: np.ndarray = None    # (M,) transmissions that arrived
    dropped_count: np.ndarray = None      # (M,) transmissions lost in channel
    censored_count: np.ndarray = None     # (M,) gradient evals self-censored
    stale_count: np.ndarray = None        # (M,) uplinks folded after their round
    uplink_bytes: list = None             # (M,) exact ints
    compute_s: np.ndarray = None          # (M,) seconds spent computing
    tx_s: np.ndarray = None               # (M,) seconds spent transmitting
    energy_j: np.ndarray = None           # (M,) total joules per client
    rounds: int = 0
    wall_clock_s: float = 0.0

    def __post_init__(self):
        m = self.num_clients
        z = lambda dt: np.zeros((m,), dt)
        if self.uplink_count is None:
            self.uplink_count = z(np.int64)
            self.delivered_count = z(np.int64)
            self.dropped_count = z(np.int64)
            self.censored_count = z(np.int64)
            self.stale_count = z(np.int64)
            self.uplink_bytes = [0] * m
            self.compute_s = z(np.float64)
            self.tx_s = z(np.float64)
            self.energy_j = z(np.float64)

    # ------------------------------------------------------------- fold-ins
    def record_compute(self, i: int, seconds: float, joules: float) -> None:
        self.compute_s[i] += seconds
        self.energy_j[i] += joules

    def record_uplink(self, i: int, nbytes: int, seconds: float,
                      joules: float, delivered: bool) -> None:
        self.uplink_count[i] += 1
        self.uplink_bytes[i] += int(nbytes)
        self.tx_s[i] += seconds
        self.energy_j[i] += joules
        if delivered:
            self.delivered_count[i] += 1
        else:
            self.dropped_count[i] += 1

    def record_censored(self, i: int) -> None:
        self.censored_count[i] += 1

    def record_downlink(self, i: int, joules: float) -> None:
        self.energy_j[i] += joules

    def record_stale(self, i: int) -> None:
        self.stale_count[i] += 1

    # ------------------------------------------------------------ summaries
    @property
    def total_uplinks(self) -> int:
        return int(self.uplink_count.sum())

    @property
    def total_uplink_bytes(self) -> int:
        return sum(self.uplink_bytes)

    @property
    def total_energy_j(self) -> float:
        return float(self.energy_j.sum())

    def as_dict(self) -> dict:
        return {
            "rounds": self.rounds,
            "wall_clock_s": self.wall_clock_s,
            "uplinks": self.total_uplinks,
            "delivered": int(self.delivered_count.sum()),
            "dropped": int(self.dropped_count.sum()),
            "censored": int(self.censored_count.sum()),
            "stale_folds": int(self.stale_count.sum()),
            "uplink_bytes": self.total_uplink_bytes,
            "compute_s": float(self.compute_s.sum()),
            "tx_s": float(self.tx_s.sum()),
            "energy_j": self.total_energy_j,
        }

"""Mesh-sharded synchronous federated runtime: 10^5–10^6 clients per sweep.

The event runtime (``fed.runner``) walks a host-side event heap — perfect
wall-clock fidelity, hopeless past ~10^3 clients. This module runs the
same deployment knobs as synchronous rounds with the **client axis as a
first-class sharded leading axis**: every client bank (stale-gradient
``ghat``, EF residual, censor state, comm counters) lives as per-shard
blocks on a 1-D ``("clients",)`` mesh (``launch.mesh.make_client_mesh``),
each device runs one jitted round program over its contiguous client
block, and the shards meet at the server through a single ``psum`` fold
(``core.distributed.make_client_fold``) carrying the eq.-(5) partial
aggregates plus the quorum/loss scalars. Nothing client-sized ever
crosses the shard boundary — the fold traffic is one parameter-sized
pytree plus five scalars per round, independent of M.

Round semantics are exactly ``sweep.fed_sweep``'s (i.i.d. Bernoulli
participation and uplink loss, censoring via the composed policy,
deliveries always folding into the bank, quorum gating only the theta
update — see ``fed.runner.quorum_need`` for the shared quorum
definition), but draws are **per-client key-folded** by absolute client
id instead of drawn from a split chain, which is what makes the masks
invariant to the shard count.

Two exactness anchors (pinned by tests/test_fed_mesh.py and the
multi-device legs in tests/test_distributed.py; contracts stated in
docs/fed_scaling.md):

  (a) **sync anchor** — the ideal scenario (participation 1, loss 0,
      quorum 1) sharded over ONE device is bit-identical to
      ``core.simulator.run``: objective, masks, ``agg_grad_sqnorm``,
      final params, uplink counts.
  (b) **K-invariance** — the same run over K shards draws the *same*
      participation/loss/censor decisions for every client (masks
      bit-equal for K in {1, 2, 8}); float trajectories agree to the
      reduction-order ulps of the K-way partial-sum fold.

Anchor (b) deliberately batches each shard's gradient evaluations with
``jax.vmap`` over the **contiguous block** rather than the ``lax.map``
the draw-exact doctrine usually demands: vmapped row math is bit-stable
under *splitting a leading axis into contiguous blocks* (the only
regrouping sharding performs), which experiment-validated bitwise at
K in {1, 2, 4, 8}, while a per-client ``lax.map`` is NOT bit-identical
to the vmapped ``simulator.run`` grads and would break anchor (a). The
inline lint suppressions below carry that argument.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributed import make_client_fold
from ..core.simulator import FedTask
from ..core.util import tree_sqnorm
from ..launch.mesh import make_client_mesh
from ..launch.sharding import (client_shard_sizes, per_device_views,
                               replicated_sharding, stack_shards)
from ..lint import draw_exact
from ..obs import compile_log
from ..opt import AdaptiveCensor, as_optimizer
from ..opt.api import StepStats
from .channel import ChannelConfig
from .clients import Population, VectorPopulation
from .energy import EnergyModel


@dataclasses.dataclass(frozen=True)
class MeshScenario:
    """One deployment scenario for the mesh runtime.

    Same knobs and semantics as ``sweep.fed_sweep.FedScenarioPoint``:
    ``participation`` is the per-client per-round i.i.d. cohort-join
    probability, ``loss_prob`` the i.i.d. uplink drop probability,
    ``quorum`` the arrived fraction gating the theta update, ``seed``
    keys every draw. Draws are folded per (seed, round, client-id), so a
    scenario replays identically at any shard count.
    """
    participation: float = 1.0
    loss_prob: float = 0.0
    quorum: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")
        if not 0.0 <= self.loss_prob < 1.0:
            raise ValueError("loss_prob must be in [0, 1)")
        if not 0.0 < self.quorum <= 1.0:
            raise ValueError("quorum must be in (0, 1]")

    @property
    def sync_draws(self) -> bool:
        """True when no participation/loss randomness exists — the round
        programs then compile with NO RNG ops at all (the sync-anchor
        fast path; quorum is trivially met but still evaluated)."""
        return self.participation >= 1.0 and self.loss_prob == 0.0


class MeshHistory(NamedTuple):
    """Per-round trajectory + cohort accounting of one ``run_mesh``.

    Counts are exact (int32 in-graph sums of {0,1} indicators, int64
    host-side cumulatives); bytes are exact Python-int products of the
    static per-uplink payload.
    """
    objective: np.ndarray        # (R,) f(theta^k) before round k's update
    agg_grad_sqnorm: np.ndarray  # (R,) ||sum_m ghat_m||^2 at the update
    quorum_met: np.ndarray       # (R,) bool — theta advanced this round
    participated: np.ndarray     # (R,) cohort size per round
    attempted: np.ndarray        # (R,) uplinks attempted (censor & cohort)
    delivered: np.ndarray        # (R,) uplinks that survived the channel
    comm_cum: np.ndarray         # (R,) cumulative attempted uplinks
    delivered_cum: np.ndarray    # (R,) cumulative delivered uplinks
    bytes_cum: np.ndarray        # (R,) cumulative attempted payload bytes
    energy_cum: np.ndarray       # (R,) cumulative joules (radio + compute)
    wall_clock: np.ndarray       # (R,) modeled seconds at end of round k
    final_params: Any            # replicated global array pytree
    mask: Optional[np.ndarray] = None     # (R, M) int8 attempted-uplink rows
    metrics: tuple = ()          # per-round merged MetricBags (host floats)


def run_mesh(cfg, task: FedTask, num_rounds: int, *,
             mesh=None,
             scenario: Optional[MeshScenario] = None,
             population: Optional[VectorPopulation] = None,
             channel: Optional[ChannelConfig] = None,
             energy: Optional[EnergyModel] = None,
             collect_mask: bool = True,
             collect_metrics: bool = False,
             donate: bool = False,
             bake_data: bool = True) -> MeshHistory:
    """Run one scenario with the client axis sharded over ``mesh``.

    Args:
      cfg: the composed optimizer (any transport/backend with a
        ``shard_step`` path: dense/int8/topk/lowrank on both backends);
        adaptive censoring is rejected for consistency with
        ``sweep.fed_sweep`` (its cohort-wide EMA is ill-defined under
        partial participation).
      task: the distributed problem; ``worker_data``'s leading axis M
        must equal ``cfg.num_workers`` and divide the shard count.
      num_rounds: synchronous server rounds R.
      mesh: a ``("clients",)`` mesh from ``launch.mesh.make_client_mesh``
        (default: 1 shard). Each device owns the contiguous client block
        ``[i*M/K, (i+1)*M/K)``.
      scenario: deployment knobs (default: the ideal sync scenario).
      population: optional columnar per-client compute model
        (``VectorPopulation``, or a ``Population`` — converted via
        ``as_vector``) driving the wall-clock and compute-energy models;
        its ``participation`` field is ignored here —
        ``scenario.participation`` governs the draws.
      channel: nominal air-interface for the wall-clock model (rates and
        overhead only; its ``loss_prob``/fading knobs are ignored —
        ``scenario.loss_prob`` governs drops). Default: ideal.
      energy: radio/compute energy model (default ``EnergyModel()``).
      collect_mask: record the (R, M) attempted-uplink rows (exact masks
        for the anchor tests; turn off at 10^6 clients to keep host
        memory flat).
      collect_metrics: collect one merged ``repro.obs`` MetricBag per
        round (per-shard bags folded via ``obs.metrics.merge_shard_bags``
        with the cross-shard ``agg_grad_sqnorm`` overwritten post-fold).
      donate: donate each shard's state buffers into its round program —
        the (M_local, ...) banks are the dominant memory at scale, and
        donation lets XLA reuse them across rounds.
      bake_data: fold each shard's data block into its round program as a
        compile-time constant (one trace per shard) instead of passing it
        as a jit argument (one shared trace). The default matches what
        ``simulator.run``'s scan does with its closed-over
        ``worker_data`` — and that is load-bearing for anchor (a): on
        dot-product tasks XLA contracts a *constant* operand differently
        from a parameter operand by ~1 ulp, so argument-passed data is
        only ``allclose`` to the scan, not bit-identical. Pass ``False``
        at 10^5+ clients, where constant-folding the data bloats the
        executable and compile time; element-wise tasks
        (``data.edge_tasks.make_edge_quadratics``) lose nothing either
        way, and the K-invariance anchor (b) holds in both modes.
    Returns:
      A ``MeshHistory``.
    """
    opt = as_optimizer(cfg)
    if getattr(opt, "censor", None) is None or \
            getattr(opt, "server", None) is None:
        raise TypeError(
            "run_mesh drives the censor/transport stages through "
            "shard_step, so it needs a ComposedOptimizer (or an optimizer "
            f"exposing the stage attributes), not {type(opt).__name__}")
    if opt.granularity != "global":
        raise NotImplementedError("run_mesh supports granularity='global'")
    if isinstance(opt.censor, AdaptiveCensor):
        raise NotImplementedError(
            "run_mesh rejects adaptive censoring (cohort-wide EMA is "
            "ill-defined under partial participation; see fed_sweep)")
    scenario = scenario if scenario is not None else MeshScenario()
    if isinstance(population, Population):
        population = population.as_vector()
    channel = channel if channel is not None else ChannelConfig.ideal()
    energy = energy if energy is not None else EnergyModel()

    m = jax.tree_util.tree_leaves(task.worker_data)[0].shape[0]
    if opt.num_workers != m:
        raise ValueError(f"cfg.num_workers={opt.num_workers} != task M={m}")
    if population is not None and population.num_clients != m:
        raise ValueError(
            f"population has {population.num_clients} clients, task has {m}")
    mesh = mesh if mesh is not None else make_client_mesh(1)
    m_local = client_shard_sizes(m, mesh)
    devices = list(mesh.devices.flat)
    k_shards = len(devices)
    compile_log.record("fed.mesh", "run_mesh")

    # ---------------------------------------------- per-shard constant data
    def _block(x, i):
        return x[i * m_local:(i + 1) * m_local]

    data_blocks, ids_blocks, comp_blocks, compw_blocks = [], [], [], []
    comp = np.zeros((m,), np.float32) if population is None else \
        np.asarray(population.compute_mean_s, np.float32)
    compw = np.zeros((m,), np.float32) if population is None else \
        np.asarray(population.compute_w, np.float32)
    for i, dev in enumerate(devices):
        data_blocks.append(jax.device_put(jax.tree_util.tree_map(
            lambda x: _block(x, i), task.worker_data), dev))
        ids_blocks.append(jax.device_put(
            jnp.arange(i * m_local, (i + 1) * m_local, dtype=jnp.uint32),
            dev))
        comp_blocks.append(jax.device_put(_block(comp, i), dev))
        compw_blocks.append(jax.device_put(_block(compw, i), dev))

    opt_local = dataclasses.replace(opt, num_workers=m_local)
    part_p, loss_p = scenario.participation, scenario.loss_prob
    sync_draws, seed = scenario.sync_draws, scenario.seed

    # --------------------------------------------------- shard round program
    @draw_exact
    def shard_round(state, params, data, ids, comp_s, compw_s, round_idx):
        # the contiguous-block vmap: bit-stable under resplitting the
        # leading axis (the only regrouping sharding performs) and
        # identical to simulator.run's batching — see module docstring
        # repro-lint: disable=vmap-in-draw-exact -- contiguous-block vmap
        # is the anchor-(a) batching; lax.map would break bit-identity
        # with simulator.run's vmapped grads
        grads = jax.vmap(task.grad_fn, in_axes=(None, 0))(params, data)
        if sync_draws:
            participate = channel_mask = None
        else:
            rkey = jax.random.fold_in(jax.random.PRNGKey(seed), round_idx)

            def draws(cid):
                ck = jax.random.fold_in(rkey, cid)
                return (jax.random.uniform(jax.random.fold_in(ck, 0)),
                        jax.random.uniform(jax.random.fold_in(ck, 1)))

            # repro-lint: disable=vmap-in-draw-exact -- each lane's draw
            # is keyed by (seed, round, absolute client id) alone, so
            # batching cannot regroup or leak across lanes
            u_part, u_drop = jax.vmap(draws)(ids)
            participate = (u_part < part_p).astype(jnp.float32)
            channel_mask = (u_drop >= loss_p).astype(jnp.float32)
        new_state, partial_agg, st = opt_local.shard_step(
            state, params, grads, worker_ids=ids,
            participate=participate, channel_mask=channel_mask)
        # repro-lint: disable=vmap-in-draw-exact -- same contiguous-block
        # batching as the grads; the per-shard sum is the psum partial
        losses = jax.vmap(task.loss_fn, in_axes=(None, 0))(params, data)
        loss_part = jnp.sum(losses)
        if participate is None:
            n_part = jnp.asarray(m_local, jnp.int32)
            comp_active = comp_s
        else:
            n_part = jnp.sum(participate.astype(jnp.int32))
            comp_active = jnp.where(participate != 0, comp_s, 0.0)
        n_att = jnp.sum(st.attempted.astype(jnp.int32))
        n_del = jnp.sum(st.delivered.astype(jnp.int32))
        wall_local = jnp.max(comp_active) if m_local else \
            jnp.zeros((), jnp.float32)
        comp_j = jnp.sum(comp_active * compw_s)
        partials = (partial_agg, loss_part, n_part, n_att, n_del, comp_j)
        stacked_row = jax.tree_util.tree_map(lambda v: v[None], partials)
        out = (new_state, stacked_row, st.attempted, wall_local)
        if collect_metrics:
            from ..obs.metrics import step_metrics
            bag = step_metrics(opt_local, new_state, StepStats(
                mask=st.mask, delta_sq=st.delta_sq, step_sq=st.step_sq,
                agg_grad_sqnorm=tree_sqnorm(partial_agg)))
            out = out + (bag,)
        return out

    donate_args = (0,) if donate else ()
    if bake_data:
        def _baked(d, ii):
            def fn(state, params, comp_s, compw_s, round_idx):
                return shard_round(state, params, d, ii, comp_s, compw_s,
                                   round_idx)
            return jax.jit(fn, donate_argnums=donate_args)
        progs = [_baked(data_blocks[i], ids_blocks[i])
                 for i in range(k_shards)]

        def run_shard(i, state, pview, k):
            return progs[i](state, pview, comp_blocks[i], compw_blocks[i],
                            np.int32(k))
    else:
        shard_prog = jax.jit(shard_round, donate_argnums=donate_args)

        def run_shard(i, state, pview, k):
            return shard_prog(state, pview, data_blocks[i], ids_blocks[i],
                              comp_blocks[i], compw_blocks[i], np.int32(k))

    # ------------------------------------------------- fold + server program
    fold = make_client_fold(mesh)
    rep = replicated_sharding(mesh)
    quo = scenario.quorum

    def server_round(stacked, params, prev):
        partial_agg, loss_sum, n_part, n_att, n_del, comp_j = fold(stacked)
        # beacons count toward quorum, drops don't: arrived =
        # participated - (attempted - delivered), as in fed_sweep
        arrived = n_part - (n_att - n_del)
        met = (arrived.astype(jnp.float32)
               >= jnp.ceil(jnp.asarray(quo, jnp.float32)
                           * n_part.astype(jnp.float32))) & (n_part > 0)
        upd = opt.apply_server(params, prev, partial_agg)
        new_params = jax.tree_util.tree_map(
            lambda u, t: jnp.where(met, u, t), upd, params)
        new_prev = jax.tree_util.tree_map(
            lambda t, tp: jnp.where(met, t, tp), params, prev)
        return (new_params, new_prev, met, loss_sum,
                tree_sqnorm(partial_agg), n_part, n_att, n_del, comp_j)

    server_prog = jax.jit(server_round, out_shardings=rep)
    copy_tree = jax.jit(
        lambda t: jax.tree_util.tree_map(jnp.copy, t))

    # --------------------------------------------------------- init + loop
    params_rep = jax.device_put(task.init_params, rep)
    prev_rep = jax.device_put(
        jax.tree_util.tree_map(jnp.copy, task.init_params), rep)
    states = []
    for i, dev in enumerate(devices):
        params_dev = jax.device_put(task.init_params, dev)
        states.append(jax.jit(opt_local.init)(params_dev))

    payload = opt.transport.payload_bytes(task.init_params)
    uplink_air = 0.0
    if np.isfinite(channel.uplink_rate_bps):
        uplink_air = channel.overhead_s + 8.0 * payload / \
            channel.uplink_rate_bps
    downlink_air = channel.downlink_time(payload)

    objective, gsq_hist, met_hist = [], [], []
    n_part_h, n_att_h, n_del_h = [], [], []
    wall, energy_cum, t, joules = [], [], 0.0, 0.0
    mask_rows: list[np.ndarray] = []
    bags: list[dict] = []

    for k in range(num_rounds):
        params_views = per_device_views(params_rep, mesh)
        outs = [run_shard(i, states[i], params_views[i], k)
                for i in range(k_shards)]
        states = [o[0] for o in outs]
        stacked = stack_shards([o[1] for o in outs], mesh)
        (params_rep, prev_rep, met, loss_sum, gsq, n_part, n_att, n_del,
         comp_j) = server_prog(stacked, params_rep, prev_rep)

        # shard states carry theta^{k-1} for the next eq.-(8) step norm;
        # quorum may have frozen it, so overwrite from the server's
        # (replicated) new_prev. Copy under donation: the raw per-device
        # views alias prev_rep's buffers, which the next round would
        # donate away while server_round still needs them.
        prev_views = per_device_views(prev_rep, mesh)
        states = [
            st._replace(prev_params=copy_tree(pv) if donate else pv)
            for st, pv in zip(states, prev_views)]

        objective.append(float(loss_sum))
        gsq_hist.append(float(gsq))
        met_hist.append(bool(met))
        n_part_h.append(int(n_part))
        n_att_h.append(int(n_att))
        n_del_h.append(int(n_del))
        t += (max(float(o[3]) for o in outs)
              + (uplink_air if int(n_att) else 0.0) + downlink_air)
        wall.append(t)
        joules += float(energy.round_energy(int(n_att), int(n_part),
                                            payload)) + float(comp_j)
        energy_cum.append(joules)
        if collect_mask:
            mask_rows.append(np.concatenate(
                [np.asarray(o[2]) for o in outs]).astype(np.int8))
        if collect_metrics:
            from ..obs.metrics import merge_shard_bags
            shard_bags = [
                {kk: np.asarray(v) for kk, v in o[4].items()} for o in outs]
            merged = merge_shard_bags(shard_bags,
                                      weights=[m_local] * k_shards)
            merged = {kk: float(np.asarray(v)) for kk, v in merged.items()}
            merged["agg_grad_sqnorm"] = float(gsq)
            bags.append(merged)

    att = np.asarray(n_att_h, np.int64)
    return MeshHistory(
        objective=np.asarray(objective),
        agg_grad_sqnorm=np.asarray(gsq_hist),
        quorum_met=np.asarray(met_hist, bool),
        participated=np.asarray(n_part_h, np.int64),
        attempted=att,
        delivered=np.asarray(n_del_h, np.int64),
        comm_cum=np.cumsum(att),
        delivered_cum=np.cumsum(np.asarray(n_del_h, np.int64)),
        bytes_cum=np.cumsum(att * payload),
        energy_cum=np.asarray(energy_cum),
        wall_clock=np.asarray(wall),
        final_params=params_rep,
        mask=np.stack(mask_rows) if mask_rows else None,
        metrics=tuple(bags),
    )

"""Event-driven federated edge runtime for the CHB family.

Wraps the *exact* Algorithm-1 semantics of a composed ``repro.opt``
optimizer in a deployment simulation: heterogeneous clients
(``clients.py``) compute local gradients with per-client latency and
availability, uplinks travel through a channel model (``channel.py``) that
charges air time + joules (``energy.py``) and may drop packets, and the
server advances by the composed server update (eq. 4 for heavy ball)
whenever a quorum of the round's cohort has reported. The censor and
transport stages run through their per-client entry points
(``client_decide`` / ``*_row``), so any composition whose censor supports
per-client decisions — including the stochastic CSGD policy — runs here
unchanged.

Correctness anchor (tested): with zero latency, lossless channel, full
participation, and full quorum (``sync_config``), the event loop reduces to
``core/simulator.run`` — numerically identical objective / uplink
trajectories for GD / HB / LAG / CHB. Every deployment knob is a relaxation
away from that anchor.

Semantics under asynchrony — all derived from the eq. (5) stale-bank view:
  * Client ``i`` is the only writer of bank row ``ghat_i``, and its local
    copy advances in lockstep with the server's (drops are NACKed), so a
    delta computed against the row is fold-safe *no matter how late it
    arrives*. Stragglers' uplinks are folded on arrival ("stale folds").
  * A censored client sends a zero-byte beacon (it still counts toward the
    quorum — the server heard from it; its bank row stays stale, which is
    precisely the eq. (5) semantics of censoring).
  * A dropped uplink costs full air time and transmit energy but leaves the
    server bank untouched, and the client does not advance its local copy.
  * Clients that were unavailable or unsampled simply keep stale bank rows —
    partial participation is "censoring by the scheduler".

The event loop itself is host-side Python (a heap of timed events); all the
math — gradient evaluation, censor test, bank folds, the eq. (4) server
update — runs in jitted closures compiled once per run.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.censoring import step_sqnorm
from ..core.quantize import payload_bytes_dense
from ..lint import draw_exact
from ..core.simulator import FedTask, global_loss
from ..core.util import (tree_sqnorm, tree_sum_leading, tree_worker_slice)
from ..kernels import ops as kernel_ops
from ..obs import compile_log
from ..opt import as_optimizer
from ..opt.optimizer import ComposedOptimizer
from .channel import ChannelConfig
from .clients import Population, uniform_population
from .energy import EdgeStats, EnergyModel


@dataclasses.dataclass(frozen=True)
class EdgeConfig:
    """Deployment scenario: who computes, over what air, at what cost.

    Attributes:
      population: M client profiles + the server's cohort-sampling policy.
      channel: uplink/downlink air-time and loss model.
      energy: radio/compute joule model for the per-client accounting.
      quorum: fraction of the round's cohort that must report before the
        server applies the eq.-(4) update; must be in (0, 1].
      seed: host-side RNG seed for every latency/availability/channel draw.
      retry_tick_s: wall-clock step used to re-poll availability when all
        clients are idle but unavailable.
    """
    population: Population
    channel: ChannelConfig = dataclasses.field(
        default_factory=ChannelConfig)
    energy: EnergyModel = dataclasses.field(default_factory=EnergyModel)
    # server advances when this fraction of the round's cohort has reported
    quorum: float = 1.0
    seed: int = 0
    # wall-clock step used to re-poll availability when nothing is in flight
    retry_tick_s: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.quorum <= 1.0:
            raise ValueError("quorum must be in (0, 1]")


def quorum_need(quorum: float, cohort_size: int) -> int:
    """Arrivals required before theta advances: ``max(1, ceil(q * |C|))``.

    The single definition of quorum shared by every surface: the event
    loop (``run_edge``) blocks on this count, and the synchronous rounds
    (``sweep.fed_sweep``, ``fed.mesh``) compute the same predicate
    in-graph as ``#arrived >= ceil(q * #cohort)`` with an empty-cohort
    guard — integer-identical for every non-empty cohort.
    """
    return max(1, math.ceil(quorum * cohort_size))


def sync_config(num_clients: int, seed: int = 0) -> EdgeConfig:
    """The degenerate scenario that must reproduce ``core/simulator.run``.

    Args:
      num_clients: M, the worker count of the task it will be run with.
      seed: RNG seed (irrelevant in this scenario — nothing is random).
    Returns:
      An ``EdgeConfig`` with zero latency, a lossless infinite-rate
      channel, full participation, and full quorum.
    """
    return EdgeConfig(
        population=uniform_population(num_clients, compute_mean_s=0.0),
        channel=ChannelConfig.ideal(),
        energy=EnergyModel(),
        quorum=1.0,
        seed=seed,
    )


class EdgeHistory(NamedTuple):
    """Per-round trajectory + deployment accounting."""
    objective: np.ndarray      # (R,) f(theta^k) before round k's update
    comm_cum: np.ndarray       # (R,) cumulative uplink transmissions
    mask: np.ndarray           # (R, M) 1 = fresh delta folded during round k
    agg_grad_sqnorm: np.ndarray  # (R,) ||sum_m ghat_m||^2 at the update
    wall_clock: np.ndarray     # (R,) seconds at the end of round k
    energy_cum: np.ndarray     # (R,) cumulative joules across all clients
    bytes_cum: np.ndarray      # (R,) cumulative uplink payload bytes
    final_params: Any
    final_bank: Any            # (M, ...) server stale-gradient bank
    stats: EdgeStats
    # () unless run_edge(collect_metrics=True): per-round ``repro.obs``
    # MetricBag series {name: (R,) array} — censor/transmit rates, drop
    # counts, exact byte/energy counters, and the staleness histogram
    # (how many rounds late each folded delta arrived, bucketed
    # 0 / 1 / 2-3 / 4+)
    metrics: Any = ()


class _Event(NamedTuple):
    """Heap entry; ``seq`` makes same-time ordering FIFO-stable."""
    time: float
    seq: int
    kind: str                  # "finish" | "arrive"
    client: int
    round_: int
    data: Any                  # finish: None; arrive: (payload, delivered,
    #                            transmitted, new_err_row)


def _compile(opt: ComposedOptimizer, task: FedTask):
    """Jitted closures mirroring the composed ``opt.step`` stage-for-stage.

    The censor and transport stages expose per-client entry points
    (``client_decide`` / ``*_row``) precisely so this event loop can
    evaluate one worker's upload at whatever wall-clock moment it finishes
    computing, while staying draw- and bit-compatible with the batched
    simulator step.

    A ``backend="pallas"`` composition routes its parameter-sized sweeps
    through the same fused kernels here as in the batched step: the
    eq.-(8) norm runs the M=1 row of the batched sqnorm kernel (identical
    tile partials, so censor decisions match the simulator bit-for-bit)
    and the server advances through ``opt.apply_server`` (the fused
    eq.-(4) kernel with traced alpha/beta).
    """
    pallas = getattr(opt, "backend", "reference") == "pallas"

    def client_eval(params, data_i, ghat_row, err_row, ssq, rnd, worker):
        compile_log.record("fed", "client_eval")   # ticks at trace time only
        g = task.grad_fn(params, data_i)
        delta = jax.tree_util.tree_map(
            lambda x, h: x.astype(h.dtype) - h, g, ghat_row)
        pending = opt.transport.prepare_row(delta, err_row)
        if pallas:                   # fused row of the batched kernel
            dsq = kernel_ops.tree_sqnorm_row(pending)
        else:
            dsq = tree_sqnorm(pending)   # f32 acc == delta_sqnorms row
        transmit = opt.censor.client_decide(rnd, worker, dsq, ssq)
        payload, aux = opt.transport.encode_row(pending, err_row)
        new_err = opt.transport.feedback_row(pending, payload, aux, err_row)
        return payload, new_err, dsq, transmit

    def fold(ghat, payload, i):
        return jax.tree_util.tree_map(
            lambda h, q: h.at[i].add(q.astype(h.dtype)), ghat, payload)

    apply_server = getattr(opt, "apply_server", None) or \
        (lambda p, pp, agg: opt.server.apply(p, pp, agg))

    def server_update(params, prev_params, ghat):
        compile_log.record("fed", "server_update")   # trace-time tick
        agg = tree_sum_leading(ghat)
        new_params = apply_server(params, prev_params, agg)
        # ||theta^{k+1} - theta^k||^2, broadcast with theta^{k+1} so the next
        # cohort runs the eq. (8) test with exactly the batched step norm
        next_ssq = step_sqnorm(new_params, params)
        return new_params, next_ssq, tree_sqnorm(agg)

    loss = jax.jit(lambda p: global_loss(task, p))
    return (jax.jit(client_eval), jax.jit(fold), jax.jit(server_update),
            loss)


@draw_exact
def run_edge(cfg, task: FedTask, edge: EdgeConfig,
             num_rounds: int, *, collect_metrics: bool = False,
             runlog=None) -> EdgeHistory:
    """Run the deployment scenario for ``num_rounds`` server rounds.

    Args:
      cfg: the algorithm — a ``repro.opt`` optimizer (or a legacy
        ``FedOptConfig``); must use ``granularity="global"`` and a censor
        policy with per-client decisions (``supports_event_runtime`` —
        everything except the adaptive EMA), and its ``num_workers`` must
        equal the population size.
      task: the distributed problem (leaves stacked with leading axis M).
      edge: the deployment scenario (clients, channel, energy, quorum).
      num_rounds: number of server (eq.-4) updates to perform.
      collect_metrics: record a per-round ``repro.obs`` MetricBag in
        ``EdgeHistory.metrics`` — censor/transmit/drop counts, exact
        byte/energy counters, and the staleness histogram (rounds-late of
        each folded delta, bucketed 0 / 1 / 2-3 / 4+). Host-side
        accounting only: trajectories are identical with it on or off.
      runlog: optional ``repro.obs.RunLog``; when given, one ``"round"``
        event (with the round's metrics, when collected) is appended per
        server update as it completes.
    Returns:
      An ``EdgeHistory`` with per-round objective/uplink/energy/wall-clock
      trajectories and the per-client ``EdgeStats`` accounting.
    Raises:
      NotImplementedError: for per-tensor granularity or censor policies
        without a per-client decision rule (adaptive).
      ValueError: if ``cfg.num_workers`` mismatches the population.
    """
    opt = as_optimizer(cfg)
    if getattr(opt, "censor", None) is None or \
            getattr(opt, "transport", None) is None or \
            getattr(opt, "server", None) is None:
        raise TypeError(
            "fed.run_edge drives the censor/transport/server stages "
            "directly (per-client entry points), so it needs a "
            "ComposedOptimizer (or an optimizer exposing those stage "
            f"attributes), not {type(opt).__name__}")
    if getattr(opt, "granularity", "global") != "global":
        raise NotImplementedError(
            "fed.runner supports granularity='global' only")
    if not getattr(opt.censor, "supports_event_runtime", False):
        raise NotImplementedError(
            f"censor policy {type(opt.censor).__name__} has no per-client "
            "decision rule (adaptive censoring needs the whole cohort); "
            "it cannot run in the event-driven runtime")
    m = edge.population.num_clients
    if opt.num_workers != m:
        raise ValueError(
            f"cfg.num_workers={opt.num_workers} != population "
            f"num_clients={m}")

    rng = np.random.default_rng(edge.seed)
    client_eval, fold, server_update, loss = _compile(opt, task)

    # reuse opt.init so bank/err construction (dtypes included) is identical
    st0 = opt.init(task.init_params)
    ghat, err = st0.ghat, st0.err
    params = task.init_params
    prev_params = params           # theta^{-1} = theta^0, as in opt.init
    ssq = jnp.zeros(())            # ||theta^0 - theta^{-1}||^2 = 0

    quantized = opt.transport.stateful
    payload_nbytes = opt.transport.payload_bytes(task.init_params)
    down_nbytes = payload_bytes_dense(task.init_params)

    stats = EdgeStats(num_clients=m)
    prof = edge.population.profiles
    idle = [True] * m
    # params/ssq version each busy client is computing against
    assigned: dict[int, tuple[Any, Any, int]] = {}

    heap: list[_Event] = []
    seq = 0
    t = 0.0
    round_ = 0

    def push(time_, kind, client, rnd, data=None):
        nonlocal seq
        heapq.heappush(heap, _Event(time_, seq, kind, client, rnd, data))
        seq += 1

    def dispatch_cohort() -> list[int]:
        """Sample idle+available clients; pushes their finish events."""
        nonlocal t
        for _attempt in range(100_000):
            cands = [i for i in range(m) if idle[i]
                     and prof[i].is_available(t, rng)]
            cohort = edge.population.sample_cohort(cands, rng)
            if cohort:
                break
            if heap:        # let in-flight stragglers land and free clients
                handle(heapq.heappop(heap))
            else:           # everyone idle but unavailable: wait and re-poll
                t += edge.retry_tick_s
        else:
            raise RuntimeError("no dispatchable client after 100k attempts")
        for i in cohort:
            idle[i] = False
            assigned[i] = (params, ssq, round_)
            dl = edge.channel.downlink_time(down_nbytes)
            stats.record_downlink(i, edge.energy.rx_energy(down_nbytes))
            ct = prof[i].draw_compute_time(rng)
            stats.record_compute(
                i, ct, edge.energy.compute_energy(ct, prof[i].compute_w))
            push(t + dl + ct, "finish", i, round_)
        return cohort

    arrived_from: dict[int, int] = {}   # round -> arrivals from its cohort
    fold_row = np.zeros((m,), np.int8)
    # per-round observability counters (reset after each server update);
    # staleness histogram buckets: folded deltas 0 / 1 / 2-3 / 4+ rounds late
    rc = {"transmit": 0, "censor": 0, "drop": 0,
          "staleness/h0": 0, "staleness/h1": 0, "staleness/h2_3": 0,
          "staleness/h4p": 0}

    def handle(ev: _Event) -> None:
        nonlocal t, ghat, err
        t = max(t, ev.time)
        i = ev.client
        if ev.kind == "finish":
            p_i, ssq_i, rnd = assigned[i]
            payload, new_err_row, _dsq, transmit = client_eval(
                params=p_i, data_i=tree_worker_slice(task.worker_data, i),
                ghat_row=tree_worker_slice(ghat, i),
                err_row=tree_worker_slice(err, i) if quantized else (),
                ssq=ssq_i, rnd=jnp.asarray(rnd, jnp.int32),
                worker=jnp.asarray(i, jnp.int32))
            if bool(transmit):
                rc["transmit"] += 1
                tx = edge.channel.uplink(payload_nbytes, rng)
                stats.record_uplink(i, payload_nbytes, tx.time_s,
                                    edge.energy.tx_energy(payload_nbytes),
                                    tx.delivered)
                push(ev.time + tx.time_s, "arrive", i, rnd,
                     (payload, tx.delivered, True, new_err_row))
            else:
                rc["censor"] += 1
                stats.record_censored(i)
                # zero-byte beacon: the server hears "no update" after the
                # protocol overhead; no payload energy is charged
                push(ev.time + edge.channel.overhead_s, "arrive", i, rnd,
                     (None, True, False, None))
        else:  # arrive
            payload, delivered, transmitted, new_err_row = ev.data
            if transmitted and not delivered:
                rc["drop"] += 1
            if transmitted and delivered:
                ghat = fold(ghat, payload, jnp.asarray(i))
                if quantized:
                    err = jax.tree_util.tree_map(
                        lambda e, n: e.at[i].set(n.astype(e.dtype)),
                        err, new_err_row)
                fold_row[i] = 1
                staleness = round_ - ev.round_
                rc["staleness/h0" if staleness <= 0 else
                   "staleness/h1" if staleness == 1 else
                   "staleness/h2_3" if staleness <= 3 else
                   "staleness/h4p"] += 1
                if ev.round_ != round_:
                    stats.record_stale(i)
            idle[i] = True
            assigned.pop(i, None)
            if ev.round_ >= round_:   # stale arrivals can't satisfy a quorum
                arrived_from[ev.round_] = arrived_from.get(ev.round_, 0) + 1

    objective, comm_cum, masks, gsq_hist = [], [], [], []
    wall, energy_cum, bytes_cum = [], [], []
    bag_hist: list[dict] = []

    while round_ < num_rounds:
        cohort = dispatch_cohort()
        need = quorum_need(edge.quorum, len(cohort))
        while arrived_from.get(round_, 0) < need:
            handle(heapq.heappop(heap))
        # record f(theta^k) *before* the update, matching simulator.run
        objective.append(float(loss(params)))
        new_params, next_ssq, agg_sq = server_update(params, prev_params,
                                                     ghat)
        gsq_hist.append(float(agg_sq))
        prev_params, params, ssq = params, new_params, next_ssq
        masks.append(fold_row.copy())
        fold_row[:] = 0
        comm_cum.append(stats.total_uplinks)
        wall.append(t)
        energy_cum.append(stats.total_energy_j)
        bytes_cum.append(stats.total_uplink_bytes)
        if collect_metrics or runlog is not None:
            decided = rc["transmit"] + rc["censor"]
            bag = {
                "censor_rate": rc["censor"] / max(1, decided),
                "transmit_rate": rc["transmit"] / max(1, decided),
                "drops": float(rc["drop"]),
                "folds": float(masks[-1].sum()),
                "agg_grad_sqnorm": gsq_hist[-1],
                "bank_sqnorm": float(tree_sqnorm(ghat)),
                "comm/uplink_total": float(stats.total_uplinks),
                "comm/uplink_bytes": float(stats.total_uplink_bytes),
                "energy_j": float(stats.total_energy_j),
                "wall_clock_s": float(t),
                "staleness/h0": float(rc["staleness/h0"]),
                "staleness/h1": float(rc["staleness/h1"]),
                "staleness/h2_3": float(rc["staleness/h2_3"]),
                "staleness/h4p": float(rc["staleness/h4p"]),
            }
            if collect_metrics:
                bag_hist.append(bag)
            if runlog is not None:
                runlog.write_round(round_, bag, cohort_size=len(cohort))
        for k in rc:
            rc[k] = 0
        arrived_from.pop(round_, None)
        round_ += 1

    stats.rounds = num_rounds
    stats.wall_clock_s = t
    metrics: Any = ()
    if collect_metrics and bag_hist:
        metrics = {k: np.asarray([b[k] for b in bag_hist])
                   for k in bag_hist[0]}
    return EdgeHistory(
        objective=np.asarray(objective),
        comm_cum=np.asarray(comm_cum, np.int64),
        mask=np.stack(masks),
        agg_grad_sqnorm=np.asarray(gsq_hist),
        wall_clock=np.asarray(wall),
        energy_cum=np.asarray(energy_cum),
        bytes_cum=np.asarray(bytes_cum, np.int64),
        final_params=params,
        final_bank=ghat,
        stats=stats,
        metrics=metrics,
    )


def edge_metrics_to_accuracy(hist: EdgeHistory, fstar: float,
                             tol: float) -> dict:
    """{rounds, uplinks, bytes, energy_j, wall_clock_s} when f - f* first
    drops below ``tol``; all -1 if the tolerance is never reached."""
    err = hist.objective - fstar
    hits = np.nonzero(err < tol)[0]
    if hits.size == 0:
        return {"rounds": -1, "uplinks": -1, "bytes": -1,
                "energy_j": -1.0, "wall_clock_s": -1.0}
    k = int(hits[0])
    return {
        "rounds": k,
        "uplinks": int(hist.comm_cum[k]),
        "bytes": int(hist.bytes_cum[k]),
        "energy_j": float(hist.energy_cum[k]),
        "wall_clock_s": float(hist.wall_clock[k]),
    }

"""repro.sweep: device-resident batched experiment engine for CHB.

Every paper figure that varies a hyperparameter (stepsize, censoring
threshold, seed) is a grid of Algorithm-1 runs. ``run_sweep`` executes an
entire :class:`ConfigGrid` as one (or a few) compiled device programs —
bit-exact against per-point ``core.simulator.run`` by default — and
``run_fed_sweep`` does the same for ``repro.fed`` deployment scenarios
(loss rate, participation, quorum) over vmappable synchronous rounds.

    from repro import sweep
    grid = sweep.ConfigGrid(alpha=(a,), beta=(0.4,),
                            eps1_scale=(0.01, 0.1, 1.0), seed=(0, 1))
    res = sweep.run_sweep(grid, task_factory=make_task, num_iters=3000)
    res.frontier(fstar, tol=1e-7)      # communication/accuracy frontier
    res.to_json("BENCH_fig11.json")

See docs/sweep_guide.md for the worked tutorial.
"""
from .engine import SweepResult, run_sweep
from .fed_sweep import (FedScenarioGrid, FedScenarioPoint, FedSweepResult,
                        run_fed_sweep)
from .grid import ConfigGrid, GridPoint

"""Edge-scenario sweeps: repro.fed deployment knobs as one device program.

The event-driven runtime (``repro.fed.runner``) is host-side Python — ideal
for wall-clock fidelity, hopeless for dense scenario grids. This module
models the same deployment knobs in *vmappable synchronous rounds* so a
whole (loss rate × participation × quorum × seed) grid runs as a single
jitted scan, sharing the engine's partition/export machinery.

Synchronous-round semantics (each a documented simplification of the event
runtime, reducing to it exactly in the ideal case):

  * participation — each client independently joins the round's cohort with
    probability ``participation`` (the event runtime samples a fixed-size
    cohort; i.i.d. Bernoulli is the vmappable analogue).
  * censoring — cohort members apply the exact eq.-(8) test against the
    current step norm, as in ``chb.step``.
  * loss — each transmission drops i.i.d. with ``loss_prob``; a dropped
    uplink costs air bytes/energy but leaves the server bank and quorum
    count untouched (censored zero-byte beacons do count toward quorum).
  * quorum — the server applies the eq.-(4) update only when
    ``#arrived >= ceil(quorum * #cohort)``; a failed round folds any
    delivered deltas into the bank (they arrived) but freezes theta.

Correctness anchor (tests/test_fed_sweep in tests/test_sweep.py): the ideal
point (loss 0, participation 1, quorum 1) reproduces
``core/simulator.run`` trajectories bit-exactly.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.censoring import delta_sqnorms, step_sqnorm
from ..core.quantize import payload_bytes_dense
from ..core.simulator import FedTask, global_loss
from ..core.util import tree_sqnorm, tree_stack_zeros, tree_sum_leading
from ..fed.energy import EnergyModel
from ..opt import AdaptiveCensor, as_optimizer
from ..opt.transport import _bcast


class FedScenarioPoint(NamedTuple):
    """One deployment scenario inside a fed sweep.

    Attributes:
      loss_prob: i.i.d. uplink drop probability.
      participation: per-client per-round cohort-join probability.
      quorum: fraction of the cohort that must arrive before theta advances.
      seed: PRNG seed for the scenario's participation/loss draws.
    """
    loss_prob: float = 0.0
    participation: float = 1.0
    quorum: float = 1.0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class FedScenarioGrid:
    """Cartesian product over deployment knobs (all traced axes).

    Args:
      loss_prob / participation / quorum / seed: axis values; the product
        is enumerated row-major in this field order.
    """
    loss_prob: Sequence[float] = (0.0,)
    participation: Sequence[float] = (1.0,)
    quorum: Sequence[float] = (1.0,)
    seed: Sequence[int] = (0,)

    def points(self) -> tuple[FedScenarioPoint, ...]:
        return tuple(
            FedScenarioPoint(float(l), float(p), float(q), int(s))
            for l, p, q, s in itertools.product(
                self.loss_prob, self.participation, self.quorum, self.seed))


def run_fed_sweep(cfg, task: FedTask,
                  grid, num_rounds: int, *,
                  energy: Optional[EnergyModel] = None,
                  vectorize: bool = False,
                  mesh=None) -> "FedSweepResult":
    """Sweep deployment scenarios for one algorithm as one device program.

    Args:
      cfg: the algorithm shared by every scenario — a ``repro.opt``
        optimizer (or legacy ``FedOptConfig``); must use a dense transport,
        ``granularity="global"``, and a non-adaptive censor policy (the
        modes the synchronous-round model covers; the adaptive EMA's
        cohort-wide state update is ill-defined under partial
        participation).
      task: the distributed problem.
      grid: a ``FedScenarioGrid`` or explicit ``FedScenarioPoint`` sequence.
      num_rounds: synchronous server rounds R per scenario.
      energy: radio/compute energy model for the per-point accounting
        (defaults to ``fed.EnergyModel()``).
      vectorize: as in ``run_sweep`` — ``False`` (lax.map) keeps the ideal
        point bit-exact vs ``simulator.run``; ``True`` batches for speed.
      mesh: optional 1-D device mesh (``launch.mesh.make_client_mesh``):
        the scenario grid is partitioned over its devices — scenarios are
        embarrassingly parallel, so each shard runs its contiguous block
        of points with the same per-point program and the results are
        bit-identical to the unpartitioned sweep at any shard count
        (tests/test_distributed.py pins this). The grid size must divide
        the shard count.
    Returns:
      A ``FedSweepResult`` with objective/uplink/bytes/energy trajectories
      per scenario.
    """
    opt = as_optimizer(cfg)
    if getattr(opt, "censor", None) is None or \
            getattr(opt, "server", None) is None:
        raise TypeError(
            "run_fed_sweep drives the censor/server stages directly, so "
            "it needs a ComposedOptimizer (or an optimizer exposing those "
            f"stage attributes), not {type(opt).__name__}")
    if opt.quantize is not None:
        raise NotImplementedError("fed sweep supports dense transport only")
    if opt.granularity != "global":
        raise NotImplementedError("fed sweep supports granularity='global'")
    if isinstance(opt.censor, AdaptiveCensor):
        raise NotImplementedError("fed sweep does not cover adaptive mode")
    points = grid.points() if isinstance(grid, FedScenarioGrid) \
        else tuple(grid)
    m = jax.tree_util.tree_leaves(task.worker_data)[0].shape[0]
    if opt.num_workers != m:
        raise ValueError(f"cfg.num_workers={opt.num_workers} != task M={m}")
    energy = energy if energy is not None else EnergyModel()

    worker_grads_fn = jax.vmap(task.grad_fn, in_axes=(None, 0))

    def one_scenario(point):
        loss_p, part, quo, seed = point

        def one_round(carry, _):
            params, prev, ghat, key, cstate = carry
            key, k_part, k_drop = jax.random.split(key, 3)
            participate = (jax.random.uniform(k_part, (m,)) < part
                           ).astype(jnp.float32)
            grads = worker_grads_fn(params, task.worker_data)
            delta = jax.tree_util.tree_map(
                lambda g, h: g.astype(h.dtype) - h, grads, ghat)
            dsq = delta_sqnorms(delta)
            ssq = step_sqnorm(params, prev)
            censor_pass, new_cstate = opt.censor.decide(cstate, dsq, ssq)
            # repro-lint: disable=mask-multiply-select -- both operands are
            # 0/1 masks, so this is a boolean AND, not a payload select
            transmit = participate * censor_pass
            dropped = (jax.random.uniform(k_drop, (m,)) < loss_p
                       ).astype(jnp.float32) * transmit
            delivered = transmit - dropped
            # deliveries always fold (eq. 5 stale-bank semantics); quorum
            # only gates the theta update, exactly like the event runtime
            new_ghat = jax.tree_util.tree_map(
                lambda h, q: h + _bcast(delivered, h) * q.astype(h.dtype),
                ghat, delta)
            agg = tree_sum_leading(new_ghat)
            upd = opt.server.apply(params, prev, agg)
            arrived = participate - dropped     # beacons count, drops don't
            cohort = jnp.sum(participate)
            met = (jnp.sum(arrived) >= jnp.ceil(quo * cohort)) & (cohort > 0)
            new_params = jax.tree_util.tree_map(
                lambda u, t: jnp.where(met, u, t), upd, params)
            new_prev = jax.tree_util.tree_map(
                lambda t, tp: jnp.where(met, t, tp), params, prev)
            rec = (global_loss(task, params), tree_sqnorm(agg),
                   transmit.astype(jnp.int8), delivered.astype(jnp.int8),
                   participate.astype(jnp.int8), met)
            return (new_params, new_prev, new_ghat, key, new_cstate), rec

        p0 = task.init_params
        ghat0 = tree_stack_zeros(p0, m)
        key0 = jax.random.PRNGKey(seed)
        _, recs = jax.lax.scan(
            one_round, (p0, p0, ghat0, key0, opt.censor.init(m)), None,
            length=num_rounds)
        return recs

    ftype = jnp.result_type(float)
    pts_dev = (jnp.asarray([p.loss_prob for p in points], ftype),
               jnp.asarray([p.participation for p in points], ftype),
               jnp.asarray([p.quorum for p in points], ftype),
               jnp.asarray([p.seed for p in points], jnp.uint32))
    inner = jax.vmap(one_scenario) if vectorize else \
        (lambda xs: jax.lax.map(one_scenario, xs))
    if mesh is None:
        program = jax.jit(inner)
    else:
        # scenarios are independent, so sharding the grid is a pure
        # partition: no collectives, each device scans its own block
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as _P
        from ..core.distributed import _shard_map
        axis = mesh.axis_names[0]
        n_shards = mesh.devices.size
        if len(points) % n_shards:
            raise ValueError(
                f"grid has {len(points)} points; a {n_shards}-shard mesh "
                "needs the point count divisible by the shard count — pad "
                "the grid or drop mesh=")
        pts_dev = jax.device_put(pts_dev, NamedSharding(mesh, _P(axis)))
        program = jax.jit(_shard_map(inner, mesh, in_specs=(_P(axis),),
                                     out_specs=_P(axis),
                                     manual_axes={axis}))
    obj, gsq, transmit, delivered, participate, met = \
        jax.tree_util.tree_map(np.asarray, program(pts_dev))

    # uplink and downlink ship the same dense parameter payload here
    payload = payload_bytes_dense(task.init_params)
    attempted = transmit.astype(np.int64).sum(axis=2)        # (B, R)
    cohort = participate.astype(np.int64).sum(axis=2)
    energy_per_round = energy.round_energy(attempted, cohort, payload)
    return FedSweepResult(
        points=points, num_rounds=num_rounds,
        objective=obj, agg_grad_sqnorm=gsq,
        transmit_mask=transmit, delivered_mask=delivered,
        participate_mask=participate, quorum_met=met,
        comm_cum=np.cumsum(attempted, axis=1),
        delivered_cum=np.cumsum(delivered.astype(np.int64).sum(axis=2),
                                axis=1),
        bytes_cum=np.cumsum(attempted * payload, axis=1),
        energy_cum=np.cumsum(energy_per_round, axis=1),
    )


@dataclasses.dataclass(frozen=True)
class FedSweepResult:
    """Per-scenario synchronous-round trajectories and edge accounting.

    Attributes:
      points: scenario coordinates, index-aligned with every array below.
      num_rounds: R.
      objective: (B, R) f(theta^k) before each round's update.
      agg_grad_sqnorm: (B, R) ||sum_m ghat_m||^2 at each update.
      transmit_mask / delivered_mask / participate_mask: (B, R, M) int8
        per-round indicators (attempted uplink / survived the channel /
        joined the cohort).
      quorum_met: (B, R) whether the round's theta update was applied.
      comm_cum / delivered_cum: (B, R) cumulative attempted / delivered
        uplinks.
      bytes_cum: (B, R) cumulative attempted uplink payload bytes (drops
        still burn air bytes).
      energy_cum: (B, R) cumulative radio joules (tx per attempt + rx per
        cohort member).
    """
    points: tuple[FedScenarioPoint, ...]
    num_rounds: int
    objective: np.ndarray
    agg_grad_sqnorm: np.ndarray
    transmit_mask: np.ndarray
    delivered_mask: np.ndarray
    participate_mask: np.ndarray
    quorum_met: np.ndarray
    comm_cum: np.ndarray
    delivered_cum: np.ndarray
    bytes_cum: np.ndarray
    energy_cum: np.ndarray

    def __len__(self) -> int:
        return len(self.points)

    def frontier(self, fstar: float, tol: float) -> list[dict]:
        """Edge frontier rows: rounds/uplinks/bytes/joules to accuracy.

        Args:
          fstar: optimal objective value.
          tol: target error; -1 entries mean the target was never reached.
        Returns:
          One dict per scenario, mirroring
          ``fed.runner.edge_metrics_to_accuracy``.
        """
        rows = []
        for i, p in enumerate(self.points):
            err = self.objective[i] - fstar
            hits = np.nonzero(err < tol)[0]
            if hits.size == 0:
                rec = {"rounds": -1, "uplinks": -1, "bytes": -1,
                       "energy_j": -1.0}
            else:
                k = int(hits[0])
                rec = {"rounds": k,
                       "uplinks": int(self.comm_cum[i, k]),
                       "bytes": int(self.bytes_cum[i, k]),
                       "energy_j": float(self.energy_cum[i, k])}
            rows.append({"index": i, **p._asdict(), **rec,
                         "final_err": float(err[-1])})
        return rows

    def to_json(self, path: Optional[str] = None,
                fstar: Optional[float] = None,
                tol: Optional[float] = None) -> str:
        """Serialize scenario trajectories (and optionally the frontier)."""
        doc: dict[str, Any] = {
            "num_points": len(self.points),
            "num_rounds": self.num_rounds,
            "points": [p._asdict() for p in self.points],
            "objective": self.objective.tolist(),
            "comm_cum": self.comm_cum.tolist(),
            "bytes_cum": self.bytes_cum.tolist(),
            "energy_cum": self.energy_cum.tolist(),
        }
        if fstar is not None and tol is not None:
            doc["frontier"] = self.frontier(fstar, tol)
        text = json.dumps(doc, indent=1, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

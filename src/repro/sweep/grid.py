"""Configuration grids for batched CHB experiments.

A :class:`ConfigGrid` describes a cartesian product over the CHB family's
hyperparameters — step size alpha, momentum beta, censoring threshold eps1
(absolute, or relative via the paper's eps1 = scale/(alpha^2 M^2) rule),
task PRNG seed, quantization mode, and worker count M. ``grid.points()``
enumerates it into concrete :class:`GridPoint` tuples, which is what
``repro.sweep.run_sweep`` consumes.

Axes fall into two classes (see ``repro/opt``):

  * **traced axes** — ``alpha``, ``beta``, ``eps1``/``eps1_scale``.
    Points differing only here run inside ONE compiled program.
  * **static axes** — ``quantize``, ``num_workers``, ``seed`` (it selects
    the closed-over task), and a named ``algo`` (it selects the stage
    composition) change the compiled program's structure; the engine
    partitions the grid into one compiled group per distinct combination.

Point order is the row-major cartesian product in field order
(alpha, beta, eps, seed, quantize, num_workers) — stable, so sweep results
can be reshaped back into the grid's axes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple, Optional, Sequence

from ..core.censoring import paper_eps1


class GridPoint(NamedTuple):
    """One concrete experiment configuration inside a sweep.

    Attributes:
      alpha: step size.
      beta: heavy-ball momentum (0 => GD/LAG family).
      eps1: absolute censoring threshold (0 => no censoring). For a named
        ``algo`` the builder may reinterpret it (e.g. ``csgd`` reads it as
        the initial threshold ``tau0``). For named points, ``beta``/
        ``eps1`` left at their 0.0 defaults are treated as *unset* — the
        algorithm's registered defaults apply (``GridPoint(algo="chb")``
        runs the paper's chb, not a beta=0/eps1=0 variant).
      seed: task PRNG seed — selects which stacked task instance the point
        runs on (data generation happens host-side in the task factory);
        also forwarded to seeded censor policies of named algorithms.
      quantize: ``None`` or a registered transport kind
        (``opt.transport_names()``: dense/int8/topk/lowrank) at its
        default hyperparameters (static axis).
      num_workers: M, or ``None`` to inherit the task's worker count.
      algo: ``None`` for the default eq.-(8)/heavy-ball continuum (gd, hb,
        lag, chb are all points of it), or a ``repro.opt`` registry name —
        the point is then built via ``opt.make_for_point`` and compiles as
        its own static partition.
    """
    alpha: float
    beta: float = 0.0
    eps1: float = 0.0
    seed: int = 0
    quantize: Optional[str] = None
    num_workers: Optional[int] = None
    algo: Optional[str] = None

    @property
    def algo_name(self) -> str:
        """gd/hb/lag/chb classification of this point (paper Sec. II),
        or the registry name for named-algorithm points."""
        if self.algo is not None:
            return self.algo
        if self.eps1 > 0 and self.beta > 0:
            return "chb"
        if self.eps1 > 0:
            return "lag"
        if self.beta > 0:
            return "hb"
        return "gd"


@dataclasses.dataclass(frozen=True)
class ConfigGrid:
    """Cartesian product over CHB hyperparameters.

    Exactly one of ``eps1`` (absolute thresholds) or ``eps1_scale``
    (relative: resolved per point as ``scale / (alpha^2 M^2)``, the paper's
    Sec.-IV practical rule) may be given; omitting both means no censoring.

    Args:
      alpha: step sizes to sweep (required, at least one).
      beta: momentum values.
      eps1: absolute censoring thresholds.
      eps1_scale: relative thresholds (mutually exclusive with ``eps1``).
      seed: task-generation seeds; more than one seed requires a
        ``task_factory`` at ``run_sweep`` time.
      quantize: transport kinds (``None`` or ``opt.transport_names()``
        entries), a static axis.
      num_workers: worker counts, a static axis; ``(None,)`` inherits the
        task's M.
    """
    alpha: Sequence[float]
    beta: Sequence[float] = (0.0,)
    eps1: Optional[Sequence[float]] = None
    eps1_scale: Optional[Sequence[float]] = None
    seed: Sequence[int] = (0,)
    quantize: Sequence[Optional[str]] = (None,)
    num_workers: Sequence[Optional[int]] = (None,)

    def __post_init__(self):
        if self.eps1 is not None and self.eps1_scale is not None:
            raise ValueError("give eps1 or eps1_scale, not both")
        if not self.alpha:
            raise ValueError("alpha axis must have at least one value")
        from ..opt.registry import TRANSPORT_KINDS, transport_names
        for q in self.quantize:
            if q is not None and q not in TRANSPORT_KINDS:
                raise ValueError(f"unknown quantize mode {q!r} (expected "
                                 f"None or one of {transport_names()})")

    @property
    def num_points(self) -> int:
        eps = self.eps1 if self.eps1 is not None else \
            self.eps1_scale if self.eps1_scale is not None else (0.0,)
        return (len(self.alpha) * len(self.beta) * len(eps) * len(self.seed)
                * len(self.quantize) * len(self.num_workers))

    def points(self, default_num_workers: Optional[int] = None
               ) -> tuple[GridPoint, ...]:
        """Enumerate the grid (row-major in declared field order).

        Args:
          default_num_workers: M used to resolve ``eps1_scale`` for points
            whose ``num_workers`` axis value is ``None``.
        Returns:
          Tuple of concrete ``GridPoint``s, ``self.num_points`` long.
        """
        relative = self.eps1_scale is not None
        eps = self.eps1 if self.eps1 is not None else \
            self.eps1_scale if relative else (0.0,)
        out = []
        for a, b, e, s, q, m in itertools.product(
                self.alpha, self.beta, eps, self.seed, self.quantize,
                self.num_workers):
            m_eff = m if m is not None else default_num_workers
            if relative:
                if m_eff is None:
                    raise ValueError(
                        "eps1_scale needs num_workers (in the grid or via "
                        "default_num_workers) to resolve the threshold")
                e = paper_eps1(a, m_eff, e)
            out.append(GridPoint(alpha=float(a), beta=float(b),
                                 eps1=float(e), seed=int(s), quantize=q,
                                 num_workers=m))
        return tuple(out)

"""Device-resident sweep engine: a whole ConfigGrid as one compiled program.

Every grid point is one Algorithm-1 run (``core/simulator.trajectory``).
Instead of re-tracing and re-jitting ``simulator.run`` per point — which is
what made dense hyperparameter frontiers dispatch-bound — the engine:

  1. partitions the grid by its *static* axes (num_workers, quantize,
     seed, named ``algo``; plus eps1 under per-tensor granularity), which
     genuinely change the compiled program;
  2. inside each partition, stacks the *traced* axes (alpha, beta, eps1)
     into device arrays and maps the pure trajectory over them with
     ``lax.map`` (default) or ``vmap`` (``vectorize=True``);
  3. jits each partition once, so a 32-point grid pays one compilation
     instead of 32.

Exactness contract: the default ``lax.map`` execution traces the per-point
program with exactly the shapes ``simulator.run`` uses, so trajectories are
**bit-identical** to per-point runs (asserted by tests/test_sweep.py).
``vectorize=True`` batches the gradient matmuls across points, which is
faster for large grids of tiny problems but perturbs float reductions by
~1 ulp per iteration — enough to flip an occasional f32 censor decision
near the numerical floor. Use it when throughput matters more than
bit-reproducibility.

Seeds: multiple ``seed`` values require a ``task_factory(seed, num_workers)
-> FedTask``. Task data is closed over as program constants — exactly as
``simulator.run`` does, which is what keeps the trajectories bit-identical
(passing the data as a program argument, or gathering it from a stacked
bank, perturbs XLA's matmul lowering by ~1 ulp) — so each distinct seed is
its own compiled partition. A 16-point eps-grid over 2 seeds compiles twice
instead of 32 times.
"""
from __future__ import annotations

import dataclasses
import json
import time
import warnings
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import opt as opt_mod
from ..core import simulator
from ..lint import draw_exact
from ..core.simulator import FedTask, History
from ..opt import (ComposedOptimizer, DenseTransport, Eq8Censor, HeavyBall,
                   NeverCensor, as_optimizer)
from ..opt.registry import _transport
from .grid import ConfigGrid, GridPoint

TaskFactory = Callable[[int, int], FedTask]


def _leading_dim(task: FedTask) -> int:
    return jax.tree_util.tree_leaves(task.worker_data)[0].shape[0]


def _float_dtype():
    return jnp.result_type(float)   # f64 under jax_enable_x64, else f32


def _base_optimizer(base_cfg, m: int) -> ComposedOptimizer:
    """The partition's template composition (num_workers not yet bound)."""
    if base_cfg is None:
        return ComposedOptimizer(
            censor=NeverCensor(), transport=DenseTransport(),
            server=HeavyBall(0.0, 0.0), num_workers=m)
    base = as_optimizer(base_cfg)
    if not isinstance(base, ComposedOptimizer):
        raise TypeError(
            "base_cfg must be a ComposedOptimizer (or a legacy "
            "FedOptConfig); arbitrary FedOptimizers have no sweepable "
            f"(alpha, beta, eps1) hooks: {type(base).__name__}")
    return base


def _named_axes(p: GridPoint) -> tuple[bool, bool]:
    """Which optional grid axes a named-``algo`` point explicitly set.

    ``GridPoint``'s 0.0 defaults mean "unset" for named points: a default
    axis is *omitted* from the builder call so the algorithm's registered
    defaults apply (``GridPoint(algo="chb")`` must run the paper's chb,
    not a beta=0/eps1=0 impostor labeled chb). The flags are part of the
    partition key — they change which scalars the compiled program traces.
    """
    return (p.beta != 0.0, p.eps1 != 0.0)


def _point_optimizer(p: GridPoint, m: int, base_cfg,
                     *, alpha=None, beta=None, eps1=None) -> ComposedOptimizer:
    """The optimizer a grid point describes.

    Called twice per point: host-side with concrete floats (for
    ``SweepResult.specs``) and inside the trace with device scalars (the
    ``alpha``/``beta``/``eps1`` overrides). Named-``algo`` points build
    through the registry; continuum points rebind the template's
    hyperparameters.
    """
    alpha = p.alpha if alpha is None else alpha
    beta = p.beta if beta is None else beta
    eps1 = p.eps1 if eps1 is None else eps1
    if p.algo is not None:
        beta_set, eps_set = _named_axes(p)
        kw = {"quantize": p.quantize, "seed": p.seed}
        if beta_set:
            kw["beta"] = beta
        if eps_set:
            kw["eps1"] = eps1
        return opt_mod.make_for_point(p.algo, alpha, m, **kw)
    base = _base_optimizer(base_cfg, m)
    # reuse the template's transport when it already is the point's kind —
    # this is what lets a task-scaled instance (e.g. TopKTransport(k=...))
    # survive the sweep instead of being clobbered by kind defaults
    if getattr(base.transport, "mode", None) == p.quantize:
        transport = base.transport
    else:
        transport = _transport(p.quantize)
    o = dataclasses.replace(base, num_workers=m, transport=transport)
    return o.with_hparams(alpha=alpha, beta=beta, eps1=eps1)


def run_sweep(grid: Union[ConfigGrid, Sequence[GridPoint]],
              task: Optional[FedTask] = None, *,
              num_iters: int,
              task_factory: Optional[TaskFactory] = None,
              base_cfg=None,
              vectorize: bool = False,
              collect_metrics: bool = False) -> "SweepResult":
    """Run every grid point as (a few) single compiled device programs.

    Args:
      grid: a ``ConfigGrid`` or an explicit sequence of ``GridPoint``s
        (e.g. the four gd/hb/lag/chb baselines, which are not a cartesian
        product).
      task: the shared ``FedTask`` when the grid has a single seed.
      num_iters: scan length K for every point.
      task_factory: ``(seed, num_workers) -> FedTask``; required when the
        grid sweeps seeds or worker counts beyond the shared task.
      base_cfg: template for composition choices outside the grid axes —
        a ``repro.opt.ComposedOptimizer`` (or legacy ``FedOptConfig``)
        whose granularity / bank_dtype / censor family (e.g. adaptive) are
        kept; its alpha/beta/eps1/num_workers/quantize are overridden per
        point. Ignored by named-``algo`` points, which build through the
        registry.
      vectorize: ``False`` (default) = ``lax.map``, bit-exact vs
        ``simulator.run``; ``True`` = ``vmap``, faster on large grids but
        ulp-divergent (see module docstring).
      collect_metrics: thread a per-round ``repro.obs`` MetricBag through
        every point's trajectory (``History.metrics`` becomes a
        ``{name: (K,) array}`` series). Static per partition — it changes
        the mapped program's outputs but not its partition key, and adds
        zero extra compiles relative to a metrics-off sweep of the same
        grid (pinned by tests/test_obs.py via ``obs.compile_log``).
    Returns:
      A ``SweepResult`` with one full ``History`` per point, in grid order.
    """
    if task is None and task_factory is None:
        raise ValueError("need a task or a task_factory")
    m_default = _leading_dim(task) if task is not None else None
    if base_cfg is not None and m_default is None:
        m_default = base_cfg.num_workers
    points = grid.points(m_default) if isinstance(grid, ConfigGrid) \
        else tuple(grid)
    if not points:
        raise ValueError("empty grid")

    granularity = "global" if base_cfg is None else \
        getattr(as_optimizer(base_cfg), "granularity", "global")

    if base_cfg is not None:
        # a censor without an eps1 hook (adaptive/stochastic/custom) keeps
        # its own thresholds (see with_hparams), so a varying eps axis
        # would produce N identical trajectories labeled as distinct
        # points — refuse loudly rather than plot a flat "frontier"
        base_censor = getattr(as_optimizer(base_cfg), "censor", None)
        if base_censor is not None and \
                not isinstance(base_censor, (Eq8Censor, NeverCensor)):
            eps_axis = {p.eps1 for p in points if p.algo is None}
            if len(eps_axis) > 1:
                raise ValueError(
                    f"base_cfg censor {type(base_censor).__name__} has no "
                    "eps1 hook, so the grid's varying eps1 axis "
                    f"({sorted(eps_axis)[:4]}...) would be silently "
                    "ignored; sweep its own threshold via named "
                    "GridPoint(algo=...) points instead")

    # ---- partition by the static axes (worker count, quantize, seed,
    # named algorithm; plus eps1 under per_tensor granularity, whose byte
    # accounting needs a static threshold) ----
    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        m = p.num_workers if p.num_workers is not None else m_default
        if m is None:
            raise ValueError(
                f"point {i} has no num_workers and no task to infer it from")
        eps_static = p.eps1 if (granularity == "per_tensor"
                                and p.algo is None) else None
        # named points additionally partition by which optional axes they
        # set (see _named_axes): set vs builder-default axes trace
        # different scalars, i.e. different compiled programs
        axes = _named_axes(p) if p.algo is not None else None
        groups.setdefault((m, p.quantize, p.seed, p.algo, eps_static, axes),
                          []).append(i)

    if task_factory is None and any(k[2] != 0 for k in groups):
        # a shared task has no seed axis: silently running it under a
        # non-default seed label would mislabel every result row
        raise ValueError(
            "non-default seeds need a task_factory(seed, num_workers)")

    histories: list[Optional[History]] = [None] * len(points)
    specs: list[Optional[dict]] = [None] * len(points)
    elapsed = 0.0
    for (m, quant, seed, algo, eps_static, axes), idxs in groups.items():
        if task_factory is not None:
            group_task = task_factory(seed, m)
        else:
            group_task = task
        if group_task is None or _leading_dim(group_task) != m:
            raise ValueError(
                f"group needs a task with num_workers={m}; pass a "
                "task_factory to sweep worker counts")
        for i in idxs:     # full composition of each point, host-side
            try:
                specs[i] = opt_mod.to_spec(
                    _point_optimizer(points[i], m, base_cfg))
            except ValueError:
                # a custom stage class outside the spec vocabulary (see
                # opt.CENSOR_KINDS etc.) is still perfectly sweepable —
                # record no spec rather than refusing to run the grid
                specs[i] = None
        t0 = time.perf_counter()
        group_hist = _run_group([points[i] for i in idxs], m, base_cfg,
                                eps_static, group_task, num_iters,
                                vectorize, collect_metrics)
        elapsed += time.perf_counter() - t0
        for j, i in enumerate(idxs):
            histories[i] = jax.tree_util.tree_map(
                lambda x, j=j: x[j], group_hist)
    return SweepResult(points=points, num_iters=num_iters,
                       histories=tuple(histories), elapsed_s=elapsed,
                       num_programs=len(groups), specs=tuple(specs))


@draw_exact
def _run_group(pts: list[GridPoint], m: int, base_cfg,
               eps_static: Optional[float], task: FedTask,
               num_iters: int, vectorize: bool,
               collect_metrics: bool = False) -> History:
    """Compile and execute one static partition; returns a stacked History.

    The task is closed over (program constants), matching ``simulator.run``
    bit-for-bit; only (alpha, beta, eps1) live in device arrays. Every
    point of the partition shares its quantize/seed/algo statics, so the
    representative ``pts[0]`` decides them.
    """
    from ..obs import compile_log
    compile_log.record("sweep", "partition")   # trace-time tick per program
    rep = pts[0]
    ftype = _float_dtype()
    pts_dev = (jnp.asarray([p.alpha for p in pts], ftype),
               jnp.asarray([p.beta for p in pts], ftype),
               jnp.asarray([p.eps1 for p in pts], ftype))

    def one_point(point):
        alpha, beta, eps1 = point
        if eps_static is not None:      # per_tensor: eps1 closed over
            eps1 = eps_static
        o = _point_optimizer(rep, m, base_cfg,
                             alpha=alpha, beta=beta, eps1=eps1)
        return simulator.trajectory(o, task, num_iters,
                                    collect_metrics=collect_metrics)

    # pts_dev is built fresh per partition and never reused after the
    # call, so its buffers are donated to the compiled program (the
    # hyperparameter vectors are tiny, but donation also documents the
    # ownership handoff the fused step's carry donation relies on).
    # No History output is (P,)-shaped, so XLA cannot actually reuse
    # these buffers — suppress its (expected) "not usable" warning.
    if vectorize:
        # repro-lint: disable=vmap-in-draw-exact -- vectorize=True is the
        # documented opt-in fast path; callers accept ulp-level drift vs
        # the default lax.map program (test_sweep_vectorized_mode_close)
        program = jax.jit(jax.vmap(one_point), donate_argnums=(0,))
    else:
        program = jax.jit(lambda xs: jax.lax.map(one_point, xs),
                          donate_argnums=(0,))
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        out = program(pts_dev)
    jax.block_until_ready(out.objective)
    return jax.tree_util.tree_map(np.asarray, out)


# ---------------------------------------------------------------- results
@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Stacked trajectories + accounting for every grid point, in order.

    Attributes:
      points: the concrete grid points, index-aligned with ``histories``.
      num_iters: K, shared by all points.
      histories: one host-side (numpy-leaved) ``History`` per point.
      elapsed_s: wall-clock seconds for all device programs (compile+run).
      num_programs: how many static partitions were compiled.
      specs: the full ``repro.opt`` registry spec of each point's
        optimizer (``opt.from_spec(specs[i])`` rebuilds it exactly), so an
        exported artifact is reproducible without the code that made it.
        ``None`` for points whose composition uses a custom stage class
        not registered in the spec vocabulary (``opt.CENSOR_KINDS`` &co).
    """
    points: tuple[GridPoint, ...]
    num_iters: int
    histories: tuple[History, ...]
    elapsed_s: float
    num_programs: int
    specs: tuple[dict, ...] = ()

    def __len__(self) -> int:
        return len(self.points)

    def history(self, i: int) -> History:
        """The full per-point ``History`` (same layout as simulator.run)."""
        return self.histories[i]

    # ------------------------------------------------------ stacked views
    @property
    def objective(self) -> np.ndarray:
        """(B, K) objective trajectories."""
        return np.stack([np.asarray(h.objective) for h in self.histories])

    @property
    def comm_cum(self) -> np.ndarray:
        """(B, K) cumulative uplink transmissions."""
        return np.stack([np.asarray(h.comm_cum) for h in self.histories])

    @property
    def agg_grad_sqnorm(self) -> np.ndarray:
        """(B, K) ||grad_k||^2 trajectories."""
        return np.stack([np.asarray(h.agg_grad_sqnorm)
                         for h in self.histories])

    @property
    def uplink_bytes(self) -> np.ndarray:
        """(B,) exact cumulative uplink payload bytes per point."""
        return np.asarray([h.final_state.comm.uplink_bytes_exact()
                           for h in self.histories], np.int64)

    def metrics(self, i: int) -> dict:
        """Point ``i``'s stacked ``{name: (K,) array}`` MetricBag series.

        Empty unless the sweep ran with ``collect_metrics=True``.
        """
        bags = self.histories[i].metrics
        return dict(bags) if bags else {}

    def metrics_summary(self) -> list[dict]:
        """One ``{name: final float}`` row per point (JSON-ready).

        Final-round values: cumulative series (bytes, counts) read their
        total; rate-like series read the last round. Empty dicts when the
        sweep did not collect metrics.
        """
        from ..obs.metrics import summarize
        return [summarize(self.metrics(i)) if self.metrics(i) else {}
                for i in range(len(self.points))]

    def _fstar_for(self, fstar, i: int) -> float:
        if isinstance(fstar, dict):
            return float(fstar[self.points[i].seed])
        if np.ndim(fstar) == 0:
            return float(fstar)
        return float(fstar[i])

    def frontier(self, fstar, tol: float) -> list[dict]:
        """Per-point communication/accuracy frontier rows.

        Args:
          fstar: optimal value — a scalar, a per-point sequence, or a
            ``{seed: fstar}`` dict for multi-seed sweeps.
          tol: target objective error (paper-style ``f - f* < tol``).
        Returns:
          One dict per point: the point's coordinates plus
          ``iters_to_tol``/``comms_to_tol`` (-1 = never reached),
          ``total_comms``, ``final_err``, and exact ``uplink_bytes``.
        """
        rows = []
        ub = self.uplink_bytes          # (B,) once, not once per row
        for i, (p, h) in enumerate(zip(self.points, self.histories)):
            fs = self._fstar_for(fstar, i)
            rows.append({
                "index": i,
                "algo": p.algo_name,
                "alpha": p.alpha, "beta": p.beta, "eps1": p.eps1,
                "seed": p.seed, "quantize": p.quantize,
                "num_workers": int(np.asarray(h.mask).shape[1]),
                "iters_to_tol": simulator.iterations_to_accuracy(h, fs, tol),
                "comms_to_tol": simulator.comms_to_accuracy(h, fs, tol),
                "total_comms": int(np.asarray(h.comm_cum)[-1]),
                "final_err": float(np.asarray(h.objective)[-1]) - fs,
                "uplink_bytes": int(ub[i]),
            })
        return rows

    # ----------------------------------------------------------- export
    def to_json(self, path: Optional[str] = None,
                include_trajectories: bool = True,
                fstar=None, tol: Optional[float] = None) -> str:
        """Serialize the sweep for BENCH artifacts.

        Args:
          path: if given, also write the JSON there.
          include_trajectories: include (B, K) objective/comm trajectories
            (masks are always omitted — they dominate the payload).
          fstar, tol: if both given, a ``frontier`` section is included.
        Returns:
          The JSON string.
        """
        doc: dict[str, Any] = {
            "num_points": len(self.points),
            "num_iters": self.num_iters,
            "num_programs": self.num_programs,
            "elapsed_s": self.elapsed_s,
            "points": [p._asdict() for p in self.points],
            "specs": list(self.specs),
            "uplink_bytes": self.uplink_bytes.tolist(),
        }
        if include_trajectories:
            doc["objective"] = self.objective.tolist()
            doc["comm_cum"] = self.comm_cum.tolist()
        summary = self.metrics_summary()
        if any(summary):
            doc["metrics"] = summary
        if fstar is not None and tol is not None:
            doc["frontier"] = self.frontier(fstar, tol)
        text = json.dumps(doc, indent=1, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_csv(self, fstar, tol: float, path: Optional[str] = None) -> str:
        """Frontier rows as CSV (header + one line per point)."""
        rows = self.frontier(fstar, tol)
        cols = ["index", "algo", "alpha", "beta", "eps1", "seed", "quantize",
                "num_workers", "iters_to_tol", "comms_to_tol", "total_comms",
                "final_err", "uplink_bytes"]
        lines = [",".join(cols)]
        for r in rows:
            lines.append(",".join(
                "" if r[c] is None else f"{r[c]:.6e}" if c == "final_err"
                else str(r[c]) for c in cols))
        text = "\n".join(lines) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

"""Server updates: how theta advances from the aggregated stale bank.

  * :class:`HeavyBall` — the paper's eq. (4):
    ``theta^{k+1} = theta^k - alpha*grad_k + beta*(theta^k - theta^{k-1})``.
  * :class:`GradientDescent` — the beta=0 specialization (classical GD /
    LAG server). Implemented by delegating to the same formula so GD and
    HB(beta=0) trajectories are bit-identical by construction.

``alpha``/``beta`` may be traced scalars (the sweep engine). Each scalar
is pinned to the parameter leaf's dtype before multiplying — a traced
scalar arrives strongly typed (f64 under x64) and would otherwise silently
promote an f32 update and double-round, diverging from the static path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


def scal(s, leaf: jax.Array) -> jax.Array:
    """Pin a config scalar to a leaf's dtype before multiplying."""
    return jnp.asarray(s).astype(leaf.dtype)


@runtime_checkable
class ServerUpdate(Protocol):
    """Pluggable stage applying the server iterate update."""

    alpha: Any

    def apply(self, params, prev_params, agg):
        """theta^{k+1} from (theta^k, theta^{k-1}, grad_k)."""
        ...

    def metrics(self) -> dict:
        """Optional ``repro.obs`` hook: stage-local scalar observables.

        Keys are namespaced ``server/<kind>/<key>``. The built-in servers
        report their (possibly traced) step scalars so a sweep's metric
        series identifies each point's hyperparameters. Must be read-only.
        """
        ...


@dataclasses.dataclass(frozen=True)
class HeavyBall:
    """The paper's eq.-(4) momentum update."""

    alpha: Any
    beta: Any = 0.0

    def apply(self, params, prev_params, agg):
        return jax.tree_util.tree_map(
            lambda t, g, tp: (t - scal(self.alpha, t) * g.astype(t.dtype)
                              + scal(self.beta, t) * (t - tp)).astype(t.dtype),
            params, agg, prev_params)

    def metrics(self) -> dict:
        return {"alpha": jnp.asarray(self.alpha, jnp.float32),
                "beta": jnp.asarray(self.beta, jnp.float32)}


@dataclasses.dataclass(frozen=True)
class GradientDescent:
    """Plain distributed GD (eq. 4 with beta = 0)."""

    alpha: Any

    def apply(self, params, prev_params, agg):
        return HeavyBall(self.alpha, 0.0).apply(params, prev_params, agg)

    def metrics(self) -> dict:
        return {"alpha": jnp.asarray(self.alpha, jnp.float32)}

"""Transports: what bits a transmitted delta carries on the wire.

  * :class:`DenseTransport` — the paper's uplink: the raw delta pytree.
  * :class:`Int8Transport` — beyond paper (Sec. V's "complementary
    techniques such as quantization"): symmetric per-tensor int8 with a
    per-worker scale and error feedback, so worker and server views never
    diverge (see ``core/quantize.py``).

Like the censor policies, every transport exposes a batched interface
(leading-M stacked pytrees, used by the composed step) and a row interface
(one worker's slice, used by the event-driven ``repro.fed`` runtime). The
two are built from the same quantizer so they agree bit-for-bit.

``stateful`` tells the host whether the error-feedback bank exists — a
*structural* property (it sizes state buffers), so it is a class variable,
never traced.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..core.quantize import (payload_bytes_dense, payload_bytes_int8,
                             tree_quantize_roundtrip,
                             tree_quantize_roundtrip_per_worker)
from ..core.util import tree_stack_zeros


def _bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast a per-worker mask (M,) against a leading-M leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


@runtime_checkable
class Transport(Protocol):
    """Pluggable stage encoding transmitted deltas (+ error feedback)."""

    mode: ClassVar[Optional[str]]   # config token: None | "int8"
    stateful: ClassVar[bool]        # does the error-feedback bank exist?

    def init(self, params, num_workers: int) -> Any:
        """Error-feedback state (lives in ``OptState.err``)."""
        ...

    def prepare(self, delta, err):
        """Batched: fold the error-feedback residual into the delta."""
        ...

    def encode(self, pending):
        """Batched: the payload the receiver reconstructs."""
        ...

    def feedback(self, mask, pending, payload, err):
        """Batched: next error-feedback state given the transmit mask."""
        ...

    def prepare_row(self, delta, err_row):
        """One worker's ``prepare`` (event runtime)."""
        ...

    def encode_row(self, pending):
        """One worker's ``encode`` (event runtime)."""
        ...

    def feedback_row(self, pending, payload, err_row):
        """One worker's post-transmit error residual (event runtime)."""
        ...

    def payload_bytes(self, params) -> int:
        """Static uplink bytes for one transmission of this pytree."""
        ...

    def metrics(self, err) -> dict:
        """Optional ``repro.obs`` hook: stage-local scalar observables.

        Called with the transport's error-feedback state after each step;
        keys are namespaced ``transport/<kind>/<key>``. Must be read-only.
        """
        ...


@dataclasses.dataclass(frozen=True)
class DenseTransport:
    """Raw-delta uplinks (the paper's transport)."""

    mode: ClassVar[Optional[str]] = None
    stateful: ClassVar[bool] = False

    def init(self, params, num_workers: int):
        # empty leaves keep the state pytree structure stable across
        # transports (same contract as the original core/chb.init)
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((0,), x.dtype), params)

    def prepare(self, delta, err):
        return delta

    def encode(self, pending):
        return pending

    def feedback(self, mask, pending, payload, err):
        return err

    def prepare_row(self, delta, err_row):
        return delta

    def encode_row(self, pending):
        return pending

    def feedback_row(self, pending, payload, err_row):
        return err_row

    def payload_bytes(self, params) -> int:
        return payload_bytes_dense(params)

    def metrics(self, err) -> dict:
        return {}


@dataclasses.dataclass(frozen=True)
class Int8Transport:
    """Int8 uplinks with per-worker scales and error feedback."""

    mode: ClassVar[Optional[str]] = "int8"
    stateful: ClassVar[bool] = True

    def init(self, params, num_workers: int):
        return tree_stack_zeros(params, num_workers)

    def prepare(self, delta, err):
        return jax.tree_util.tree_map(
            lambda d, e: jnp.add(d, e.astype(d.dtype)), delta, err)

    def encode(self, pending):
        # per-worker scales: worker m quantizes its own delta slice
        return tree_quantize_roundtrip_per_worker(pending)

    def feedback(self, mask, pending, payload, err):
        return jax.tree_util.tree_map(
            lambda p, q, e: _bcast(mask, p) * (p - q)
            + (1.0 - _bcast(mask, p)) * e.astype(p.dtype),
            pending, payload,
            jax.tree_util.tree_map(
                lambda e, p: e.astype(p.dtype), err, pending))

    def prepare_row(self, delta, err_row):
        return jax.tree_util.tree_map(
            lambda d, e: d + e.astype(d.dtype), delta, err_row)

    def encode_row(self, pending):
        return tree_quantize_roundtrip(pending)

    def feedback_row(self, pending, payload, err_row):
        return jax.tree_util.tree_map(
            lambda p, q: p - q, pending, payload)

    def payload_bytes(self, params) -> int:
        return payload_bytes_int8(params)

    def metrics(self, err) -> dict:
        # ||EF bank||^2: how much un-transmitted quantization residual the
        # cohort is carrying (an extra read-sweep; metrics are opt-in)
        from ..core.util import tree_sqnorm
        return {"ef_residual_sqnorm": tree_sqnorm(err)}

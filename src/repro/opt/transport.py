"""Transports: what bits a transmitted delta carries on the wire.

  * :class:`DenseTransport` — the paper's uplink: the raw delta pytree.
  * :class:`Int8Transport` — beyond paper (Sec. V's "complementary
    techniques such as quantization"): symmetric per-tensor int8 with a
    per-worker scale and error feedback, so worker and server views never
    diverge (see ``core/quantize.py``).
  * :class:`TopKTransport` — per-leaf magnitude top-k sparsification
    (index + value packing on the wire) with the same error-feedback
    bank as int8.
  * :class:`LowRankTransport` — PowerSGD-style rank-r power-iteration
    compression (arXiv:1905.13727 idiom; see also the compressed-adaptive
    family of arXiv:2109.05109) with warm-started factors carried in the
    transport state next to the error-feedback bank.

Like the censor policies, every transport exposes a batched interface
(leading-M stacked pytrees, used by the composed step) and a row interface
(one worker's slice, used by the event-driven ``repro.fed`` runtime). The
two are built from the same per-slice math so they agree bit-for-bit.

``stateful`` tells the host whether transport state (the error-feedback
bank, plus any warm-started factors) exists — a *structural* property (it
sizes state buffers), so it is a class variable, never traced.

Stage anatomy of one step (both batched and row):

    pending = prepare(delta, err)              # fold in the EF residual
    payload, aux = encode(pending, err)        # what the receiver gets
    new_err = feedback(mask, pending, payload, aux, err)

``aux`` is encode-internal state handed to ``feedback`` (the low-rank
transport's refreshed factors); stateless encodes return ``()``. A
stateful transport additionally implements ``encode_feedback_pallas`` —
the fused-kernel route the ``backend="pallas"`` composed step dispatches
to (see ``docs/transport_zoo.md`` for the exactness contracts).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, ClassVar, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..core.quantize import (payload_bytes_dense, payload_bytes_int8,
                             tree_quantize_roundtrip,
                             tree_quantize_roundtrip_per_worker)
from ..core.util import tree_stack_zeros
from ..lint import draw_exact


def _bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast a per-worker mask (M,) against a leading-M leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


def _ef_blend(mask, pending, payload, err):
    """Masked error-feedback bank update, leaf-wise over pytrees.

    Transmitted workers keep the fresh residual ``pending - payload``;
    censored workers keep their old residual. The arithmetic-blend form
    ``mk*new + (1-mk)*old`` is shared by the reference path, the fused
    kernels, and (at mask=1) the row path's plain ``pending - payload`` —
    which is what keeps all three bit-aligned.
    """
    return jax.tree_util.tree_map(
        lambda p, q, e: _bcast(mask, p) * (p - q)
        + (1.0 - _bcast(mask, p)) * e.astype(p.dtype),
        pending, payload, err)


@runtime_checkable
class Transport(Protocol):
    """Pluggable stage encoding transmitted deltas (+ error feedback)."""

    mode: ClassVar[Optional[str]]   # config token: None | a TRANSPORT_KINDS key
    stateful: ClassVar[bool]        # does transport state (EF bank &co) exist?
    #: True when ``payload + new_err == pending`` holds *bitwise* after a
    #: transmit (int8 / top-k: the residual subtraction is exact by a
    #: Sterbenz-style argument; low-rank payloads are arbitrary floats, so
    #: the subtraction rounds). Conformance tests key off this.
    exact_residual: ClassVar[bool] = False

    def init(self, params, num_workers: int) -> Any:
        """Transport state (lives in ``OptState.err``)."""
        ...

    def prepare(self, delta, err):
        """Batched: fold the error-feedback residual into the delta."""
        ...

    def encode(self, pending, err):
        """Batched: ``(payload, aux)`` — what the receiver reconstructs,
        plus encode-internal state for ``feedback`` (``()`` if none)."""
        ...

    def feedback(self, mask, pending, payload, aux, err):
        """Batched: next transport state given the transmit mask."""
        ...

    def prepare_row(self, delta, err_row):
        """One worker's ``prepare`` (event runtime)."""
        ...

    def encode_row(self, pending, err_row):
        """One worker's ``encode`` (event runtime); returns (payload, aux)."""
        ...

    def feedback_row(self, pending, payload, aux, err_row):
        """One worker's post-transmit state (event runtime; only applied
        when the upload is actually delivered)."""
        ...

    def payload_bytes(self, params) -> int:
        """Static uplink bytes for one transmission of this pytree."""
        ...

    def ef_bank(self, err):
        """The error-feedback bank inside the transport state (``None``
        for stateless transports). The conformance suite's telescoping
        checks read the bank through this, so transports are free to
        carry extra state (e.g. low-rank factors) next to it."""
        ...

    def metrics(self, err) -> dict:
        """Optional ``repro.obs`` hook: stage-local scalar observables.

        Called with the transport's state after each step; keys are
        namespaced ``transport/<kind>/<key>``. Must be read-only.
        """
        ...


@dataclasses.dataclass(frozen=True)
class DenseTransport:
    """Raw-delta uplinks (the paper's transport)."""

    mode: ClassVar[Optional[str]] = None
    stateful: ClassVar[bool] = False
    exact_residual: ClassVar[bool] = True   # payload == pending, err empty

    def init(self, params, num_workers: int):
        # empty leaves keep the state pytree structure stable across
        # transports (same contract as the original core/chb.init)
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((0,), x.dtype), params)

    def prepare(self, delta, err):
        return delta

    def encode(self, pending, err):
        return pending, ()

    def feedback(self, mask, pending, payload, aux, err):
        return err

    def prepare_row(self, delta, err_row):
        return delta

    def encode_row(self, pending, err_row):
        return pending, ()

    def feedback_row(self, pending, payload, aux, err_row):
        return err_row

    def payload_bytes(self, params) -> int:
        return payload_bytes_dense(params)

    def ef_bank(self, err):
        # None is the contract value: the dense transport keeps no EF bank
        return None  # noqa: RET501

    def metrics(self, err) -> dict:
        return {}


@dataclasses.dataclass(frozen=True)
class Int8Transport:
    """Int8 uplinks with per-worker scales and error feedback."""

    mode: ClassVar[Optional[str]] = "int8"
    stateful: ClassVar[bool] = True
    exact_residual: ClassVar[bool] = True

    def init(self, params, num_workers: int):
        return tree_stack_zeros(params, num_workers)

    def prepare(self, delta, err):
        return jax.tree_util.tree_map(
            lambda d, e: jnp.add(d, e.astype(d.dtype)), delta, err)

    def encode(self, pending, err):
        # per-worker scales: worker m quantizes its own delta slice
        return tree_quantize_roundtrip_per_worker(pending), ()

    def feedback(self, mask, pending, payload, aux, err):
        return _ef_blend(mask, pending, payload, err)

    def encode_feedback_pallas(self, pending, err, mask):
        """Fused route for the pallas composed step: one abs-max reduction
        plus one sweep emitting payload and new EF bank together."""
        from ..kernels import ops as kernel_ops
        return kernel_ops.tree_int8_roundtrip_ef(pending, err, mask)

    def prepare_row(self, delta, err_row):
        return jax.tree_util.tree_map(
            lambda d, e: d + e.astype(d.dtype), delta, err_row)

    def encode_row(self, pending, err_row):
        return tree_quantize_roundtrip(pending), ()

    def feedback_row(self, pending, payload, aux, err_row):
        return jax.tree_util.tree_map(
            lambda p, q: p - q, pending, payload)

    def payload_bytes(self, params) -> int:
        return payload_bytes_int8(params)

    def ef_bank(self, err):
        return err

    def metrics(self, err) -> dict:
        # ||EF bank||^2: how much un-transmitted quantization residual the
        # cohort is carrying (an extra read-sweep; metrics are opt-in)
        from ..core.util import tree_sqnorm
        return {"ef_residual_sqnorm": tree_sqnorm(err)}


# ------------------------------------------------------------------ top-k
def _keep_mask_slice(x: jax.Array, k: int) -> jax.Array:
    """Dense 0/1 keep mask of one worker's leaf: the ``min(k, size)``
    largest-|x| entries (``lax.top_k`` tie-break: lowest flat index wins,
    deterministically — the row and batched entry points agree draw-exact).
    """
    flat = x.reshape(-1)
    kk = min(int(k), flat.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(flat), kk)
    keep = jnp.zeros_like(flat).at[idx].set(jnp.ones((kk,), flat.dtype))
    return keep.reshape(x.shape)


def tree_topk_keep(pending, k: int):
    """Per-worker keep masks of a leading-M stacked pytree (vmapped —
    selection and scatter are exact, so batching cannot perturb them)."""
    return jax.tree_util.tree_map(
        lambda x: jax.vmap(lambda s: _keep_mask_slice(s, k))(x), pending)


def tree_topk_keep_row(pending_row, k: int):
    """One worker's keep masks (the ``repro.fed`` entry point)."""
    return jax.tree_util.tree_map(
        lambda x: _keep_mask_slice(x, k), pending_row)


@dataclasses.dataclass(frozen=True)
class TopKTransport:
    """Top-k sparsified uplinks with error feedback (index+value packing).

    Each worker ships, per parameter leaf, the ``min(k, leaf.size)``
    largest-magnitude entries of its pending delta as (index, value)
    pairs — ``k * (4 + itemsize)`` bytes per leaf (a 4-byte index plus one
    native-dtype value per kept entry). The receiver reconstructs the
    dense leaf with zeros elsewhere; the un-shipped mass goes into the
    same error-feedback bank the int8 transport uses, so nothing is ever
    lost, only deferred.
    """

    mode: ClassVar[Optional[str]] = "topk"
    stateful: ClassVar[bool] = True
    exact_residual: ClassVar[bool] = True   # residual is x or 0, elementwise

    k: int = 64

    def init(self, params, num_workers: int):
        return tree_stack_zeros(params, num_workers)

    def prepare(self, delta, err):
        return jax.tree_util.tree_map(
            lambda d, e: jnp.add(d, e.astype(d.dtype)), delta, err)

    def encode(self, pending, err):
        keep = tree_topk_keep(pending, self.k)
        payload = jax.tree_util.tree_map(
            lambda p, kp: jnp.where(kp != 0, p, jnp.zeros_like(p)),
            pending, keep)
        return payload, ()

    def feedback(self, mask, pending, payload, aux, err):
        return _ef_blend(mask, pending, payload, err)

    def encode_feedback_pallas(self, pending, err, mask):
        """Fused route: the keep masks are exact jnp selections; ONE fused
        sweep per leaf then emits payload and new EF bank together
        (``kernels/topk_pack.py``, the ``quantize_ef`` idiom)."""
        from ..kernels import ops as kernel_ops
        keep = tree_topk_keep(pending, self.k)
        return kernel_ops.tree_topk_pack_ef(pending, err, keep, mask)

    def prepare_row(self, delta, err_row):
        return jax.tree_util.tree_map(
            lambda d, e: d + e.astype(d.dtype), delta, err_row)

    def encode_row(self, pending, err_row):
        keep = tree_topk_keep_row(pending, self.k)
        payload = jax.tree_util.tree_map(
            lambda p, kp: jnp.where(kp != 0, p, jnp.zeros_like(p)),
            pending, keep)
        return payload, ()

    def feedback_row(self, pending, payload, aux, err_row):
        return jax.tree_util.tree_map(
            lambda p, q: p - q, pending, payload)

    def payload_bytes(self, params) -> int:
        # exact per-transmission accounting: min(k, size) kept entries per
        # leaf, each a 4-byte index + one native-dtype value
        total = 0
        for x in jax.tree_util.tree_leaves(params):
            total += min(int(self.k), x.size) * (4 + x.dtype.itemsize)
        return total

    def ef_bank(self, err):
        return err

    def metrics(self, err) -> dict:
        from ..core.util import tree_sqnorm
        return {"ef_residual_sqnorm": tree_sqnorm(err)}


# ---------------------------------------------------------------- low-rank
def _orthonormalize(p: jax.Array) -> jax.Array:
    """Modified Gram-Schmidt on the columns of ``p`` (r, rank).

    Explicit column loop (static rank) instead of ``jnp.linalg.qr`` so the
    row and batched entry points trace the *same* subgraph — vmapped QR
    lowers differently and would break the draw-exact row contract. Zero
    columns pass through unnormalized (guarded divide), never NaN.
    """
    cols = []
    for j in range(p.shape[1]):
        v = p[:, j]
        for u in cols:
            v = v - jnp.dot(u, v) * u
        nrm = jnp.sqrt(jnp.sum(v * v))
        cols.append(v / jnp.where(nrm > 0, nrm, jnp.ones_like(nrm)))
    return jnp.stack(cols, axis=1)


def _power_iter_slice(mat: jax.Array, q: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """One PowerSGD step on one worker's matrixized leaf.

    ``mat`` (r, c), ``q`` (c, rank): P = orthonormalize(mat @ q),
    Q' = mat^T P; the wire carries (P, Q') and the receiver reconstructs
    ``P @ Q'^T``. Returns (reconstruction, Q') — Q' warm-starts the next
    round's iteration from the transport state.
    """
    p = _orthonormalize(mat @ q)
    q_new = mat.T @ p
    return p @ q_new.T, q_new


def _matrixize(x: jax.Array) -> jax.Array:
    """One worker's leaf as (shape[0], prod(rest)) — PowerSGD's view."""
    return x.reshape(x.shape[0], -1)


@dataclasses.dataclass(frozen=True)
class LowRankTransport:
    """PowerSGD-style rank-r uplinks with warm-started factors + EF.

    Matrix-shaped leaves (ndim >= 2, viewed as ``(shape[0], prod(rest))``)
    are compressed to one power-iteration step of rank
    ``min(rank, rows, cols)``: the wire carries the two factors
    (``rank*(rows+cols)`` values instead of ``rows*cols``). Vector leaves
    (biases, 1-d params) ship dense — factoring them saves nothing. The
    right factor Q warm-starts the next round (it lives in the transport
    state next to the error-feedback bank, advancing only on transmitted
    rounds, exactly like the bank), so repeated rounds converge toward the
    delta's true top-r subspace. The approximation error goes into the
    standard EF bank.

    The factor math is plain jnp shared verbatim by both backends; the
    pallas route fuses only the elementwise residual/EF sweep
    (``kernels/lowrank_ef.py``) — the matmuls already run on the MXU.
    """

    mode: ClassVar[Optional[str]] = "lowrank"
    stateful: ClassVar[bool] = True
    exact_residual: ClassVar[bool] = False  # P@Q^T is an arbitrary float

    rank: int = 2

    # -- structure helpers (static, shape-driven) --
    def _rank_eff(self, leaf_shape: tuple) -> int:
        r = leaf_shape[0]
        c = math.prod(leaf_shape[1:])
        return min(int(self.rank), r, c)

    def _q_init_slice(self, leaf: jax.Array) -> jax.Array:
        """Deterministic warm-start: the first rank_eff canonical basis
        vectors of the column space (no RNG in transport state)."""
        if leaf.ndim < 2:
            return jnp.zeros((0,), leaf.dtype)
        c = math.prod(leaf.shape[1:])
        return jnp.eye(c, self._rank_eff(leaf.shape), dtype=leaf.dtype)

    def init(self, params, num_workers: int):
        err = tree_stack_zeros(params, num_workers)
        q = jax.tree_util.tree_map(
            lambda x: jnp.tile(self._q_init_slice(x),
                               (num_workers,) + (1,) * max(
                                   1, self._q_init_slice(x).ndim)),
            params)
        return {"err": err, "q": q}

    def prepare(self, delta, err):
        return jax.tree_util.tree_map(
            lambda d, e: jnp.add(d, e.astype(d.dtype)), delta, err["err"])

    def _encode_slice(self, x: jax.Array, q: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
        """One worker's (payload, new_q) for one leaf."""
        if q.shape[-1] == 0:            # vector leaf: dense passthrough
            return x, q
        recon, q_new = _power_iter_slice(_matrixize(x), q)
        return recon.reshape(x.shape), q_new

    @draw_exact
    def encode(self, pending, err):
        # explicit python loop over the static worker axis: each worker
        # slice runs the exact subgraph the row entry point runs, so the
        # fed runtime's per-client encodes are draw-exact vs the batched
        # step (vmapped matmul/orthonormalization would drift by ulps)
        def leaf(p, q):
            outs = [self._encode_slice(p[i], q[i])
                    for i in range(p.shape[0])]
            return (jnp.stack([o[0] for o in outs]),
                    jnp.stack([o[1] for o in outs]))
        leaves_p, treedef = jax.tree_util.tree_flatten(pending)
        leaves_q = treedef.flatten_up_to(err["q"])
        outs = [leaf(p, q) for p, q in zip(leaves_p, leaves_q)]
        payload = jax.tree_util.tree_unflatten(treedef,
                                               [o[0] for o in outs])
        q_new = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return payload, q_new

    def feedback(self, mask, pending, payload, aux, err):
        new_err = _ef_blend(mask, pending, payload, err["err"])
        new_q = jax.tree_util.tree_map(
            lambda qn, qo: _bcast(mask, qn) * qn
            + (1.0 - _bcast(mask, qn)) * qo.astype(qn.dtype),
            aux, err["q"])
        return {"err": new_err, "q": new_q}

    def encode_feedback_pallas(self, pending, err, mask):
        """Fused route: factor matmuls are the shared jnp helpers (bit-
        identical to the reference by construction); ONE fused sweep per
        leaf then computes the EF residual blend
        (``kernels/lowrank_ef.py``)."""
        from ..kernels import ops as kernel_ops
        payload, q_new = self.encode(pending, err)
        new_err = kernel_ops.tree_residual_ef(pending, payload,
                                              err["err"], mask)
        new_q = jax.tree_util.tree_map(
            lambda qn, qo: _bcast(mask, qn) * qn
            + (1.0 - _bcast(mask, qn)) * qo.astype(qn.dtype),
            q_new, err["q"])
        return payload, {"err": new_err, "q": new_q}

    def prepare_row(self, delta, err_row):
        return jax.tree_util.tree_map(
            lambda d, e: d + e.astype(d.dtype), delta, err_row["err"])

    def encode_row(self, pending, err_row):
        leaves_p, treedef = jax.tree_util.tree_flatten(pending)
        leaves_q = treedef.flatten_up_to(err_row["q"])
        outs = [self._encode_slice(p, q)
                for p, q in zip(leaves_p, leaves_q)]
        payload = jax.tree_util.tree_unflatten(treedef,
                                               [o[0] for o in outs])
        q_new = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return payload, q_new

    def feedback_row(self, pending, payload, aux, err_row):
        new_err = jax.tree_util.tree_map(
            lambda p, q: p - q, pending, payload)
        return {"err": new_err, "q": aux}

    def payload_bytes(self, params) -> int:
        # matrix leaves ship the two factors; vector leaves ship dense
        total = 0
        for x in jax.tree_util.tree_leaves(params):
            if x.ndim >= 2:
                r = x.shape[0]
                c = math.prod(x.shape[1:])
                total += self._rank_eff(x.shape) * (r + c) * x.dtype.itemsize
            else:
                total += x.size * x.dtype.itemsize
        return total

    def ef_bank(self, err):
        return err["err"]

    def metrics(self, err) -> dict:
        from ..core.util import tree_sqnorm
        return {"ef_residual_sqnorm": tree_sqnorm(err["err"]),
                "factor_sqnorm": tree_sqnorm(err["q"])}

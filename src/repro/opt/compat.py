"""Legacy-config bridge: ``FedOptConfig`` -> ``ComposedOptimizer``.

``core.chb.FedOptConfig`` predates the stage protocol; it is now a thin
deprecated facade whose every (alpha, beta, eps1, quantize, adaptive,
granularity) combination maps onto exactly one composition. The mapping
lives here so neither ``repro.opt`` nor ``repro.core.chb`` imports the
other's internals (chb imports this module; this module duck-types the
config).

``as_optimizer`` is what every consumer entry point calls: it accepts
either a ``FedOptimizer`` (passed through untouched) or a legacy config
(converted). The conversion itself does NOT warn — the deprecation warning
fires once at ``FedOptConfig`` construction, where the user's code is.
"""
from __future__ import annotations

from .api import FedOptimizer, static_pos
from .censor import AdaptiveCensor, Eq8Censor, NeverCensor
from .optimizer import ComposedOptimizer
from .registry import _transport
from .server import HeavyBall


def from_config(cfg) -> ComposedOptimizer:
    """Compose the optimizer a legacy ``FedOptConfig`` describes.

    Bit-exactness contract: the composition's ``step`` runs the same jnp
    ops in the same order as the pre-redesign ``chb.step`` for every
    reachable config (golden-pinned by tests/test_opt.py). Traced
    alpha/beta/eps1 are carried into the stages; a traced ``adaptive``
    raises (it decides whether the EMA state buffer exists).
    """
    adaptive_on = static_pos(cfg.adaptive)
    if adaptive_on is None:
        raise NotImplementedError(
            "cfg.adaptive cannot be traced: it decides whether the EMA "
            "state buffer exists. Sweep adaptive as a static axis instead.")
    # legacy precedence (matching the old chb.step branch order): a
    # per_tensor config with a nonzero eps1 took the eq.-(8) per-tensor
    # path before adaptive was ever consulted; otherwise adaptive > 0
    # overrode eps1 entirely.
    per_tensor_eq8 = (cfg.granularity == "per_tensor"
                      and static_pos(cfg.eps1) is not False)
    if adaptive_on and not per_tensor_eq8:
        censor = AdaptiveCensor(cfg.adaptive, cfg.adaptive_decay)
    elif static_pos(cfg.eps1) is False:
        censor = NeverCensor()
    else:
        censor = Eq8Censor(cfg.eps1)
    return ComposedOptimizer(
        censor=censor,
        transport=_transport(cfg.quantize),
        server=HeavyBall(cfg.alpha, cfg.beta),
        num_workers=cfg.num_workers,
        granularity=cfg.granularity,
        bank_dtype=cfg.bank_dtype,
    )


def as_optimizer(cfg_or_opt) -> FedOptimizer:
    """Coerce a consumer argument to the ``FedOptimizer`` protocol.

    Anything exposing callable ``init``/``step`` is passed through
    (a ``ComposedOptimizer`` or any custom protocol implementation);
    a legacy ``FedOptConfig`` is converted via :func:`from_config`.
    """
    if callable(getattr(cfg_or_opt, "step", None)) and \
            callable(getattr(cfg_or_opt, "init", None)):
        return cfg_or_opt
    return from_config(cfg_or_opt)

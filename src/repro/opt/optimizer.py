"""``ComposedOptimizer`` — Algorithm 1 assembled from pluggable stages.

This is the former ``core/chb.step`` body, refactored so that the three
orthogonal decisions (censor / transport / server) are stage calls instead
of hard-wired branches. Every composition expressible by the old
``FedOptConfig`` produces a bit-identical program (pinned by
``tests/test_opt.py``'s golden fingerprints and the ``tests/test_sweep.py``
exactness grids); new algorithms are new compositions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core import accounting
from ..core.accounting import CommStats
from ..core.censoring import delta_sqnorms, step_sqnorm
from ..core.util import tree_sqnorm, tree_stack_zeros, tree_sum_leading
from .api import OptState, StepStats, static_pos
from .censor import CensorPolicy, Eq8Censor, NeverCensor
from .server import HeavyBall, ServerUpdate
from .transport import Transport, _bcast


@dataclasses.dataclass(frozen=True)
class ComposedOptimizer:
    """One censor policy + one transport + one server update.

    Structural fields (``num_workers``, ``granularity``, ``bank_dtype``,
    and each stage's *class*) decide the compiled program and must be
    static; the stages' scalar hyperparameters (alpha, beta, eps1, tau0)
    may be traced — which is how ``repro.sweep`` runs a whole grid of
    compositions through one compiled program.

    Attributes:
      censor: who uploads (``opt.censor``).
      transport: what the upload carries (``opt.transport``).
      server: how theta advances (``opt.server``).
      num_workers: M.
      granularity: ``"global"`` (the paper's single-vector view) or
        ``"per_tensor"`` (beyond paper: the eq.-(8) test per parameter
        tensor; requires an :class:`~repro.opt.censor.Eq8Censor` with a
        static eps1 and a dense transport).
      bank_dtype: optional dtype for the stale-gradient bank (bf16 halves
        state memory at scale).
    """

    censor: CensorPolicy
    transport: Transport
    server: ServerUpdate
    num_workers: int
    granularity: str = "global"
    bank_dtype: Any = None

    # ------------------------------------------------ hyperparameter views
    # Flat views of the stages' scalars, matching the legacy FedOptConfig
    # field names so hyperparameter-only consumers (core/distributed, the
    # sweep grid) read either object interchangeably.
    @property
    def alpha(self):
        return self.server.alpha

    @property
    def beta(self):
        return getattr(self.server, "beta", 0.0)

    @property
    def eps1(self):
        return getattr(self.censor, "eps1", 0.0)

    @property
    def adaptive(self):
        return getattr(self.censor, "adaptive", 0.0)

    @property
    def quantize(self) -> Optional[str]:
        return self.transport.mode

    @property
    def name(self) -> str:
        """gd/hb/lag/chb classification (paper Sec. II), or "swept"."""
        ep, bp = static_pos(self.eps1), static_pos(self.beta)
        if ep is None or bp is None:
            return "swept"
        if ep and bp:
            return "chb"
        if ep:
            return "lag"
        if bp:
            return "hb"
        return "gd"

    def with_hparams(self, *, alpha=None, beta=None,
                     eps1=None) -> "ComposedOptimizer":
        """Rebind scalar hyperparameters (possibly with traced values).

        This is the sweep engine's hook: one composition is built per
        static partition, then each grid point rebinds (alpha, beta, eps1)
        with device scalars.

        * ``beta`` rebinds a momentum server; a momentum-free server
          (``GradientDescent``) is promoted to ``HeavyBall(alpha, beta)``,
          which is bit-identical at beta=0 — so a ``lag``/``gd`` base
          sweeps exactly like the equivalent legacy config did.
        * ``eps1`` retargets an eq.-(8) censor (or upgrades a
          ``NeverCensor`` to one). Any other policy — adaptive,
          stochastic, or a custom one — keeps its own thresholds
          untouched (the engine's eps axis does not describe them; sweep
          their knobs via named ``GridPoint(algo=...)`` points instead).
        """
        server = self.server
        if alpha is not None:
            server = dataclasses.replace(server, alpha=alpha)
        if beta is not None:
            if hasattr(server, "beta"):
                server = dataclasses.replace(server, beta=beta)
            else:
                server = HeavyBall(server.alpha, beta)
        censor = self.censor
        if eps1 is not None:
            if isinstance(censor, Eq8Censor):
                censor = dataclasses.replace(censor, eps1=eps1)
            elif isinstance(censor, NeverCensor):
                censor = Eq8Censor(eps1)
            # other policies own their thresholds: leave them as composed
        return dataclasses.replace(self, censor=censor, server=server)

    # ----------------------------------------------------------- protocol
    def init(self, params) -> OptState:
        """Build the iteration-0 state (zero bank, theta^{-1} = theta^0)."""
        bank = tree_stack_zeros(params, self.num_workers)
        if self.bank_dtype is not None:
            bank = jax.tree_util.tree_map(
                lambda x: x.astype(self.bank_dtype), bank)
        return OptState(
            prev_params=params,
            ghat=bank,
            err=self.transport.init(params, self.num_workers),
            comm=CommStats.init(self.num_workers),
            censor=self.censor.init(self.num_workers),
        )

    def step(self, state: OptState, params, worker_grads
             ) -> tuple[OptState, Any, StepStats]:
        """One iteration of Algorithm 1 (see ``api.FedOptimizer.step``)."""
        # delta_m = g_m - ghat_m (in the bank's dtype for exact sync)
        delta = jax.tree_util.tree_map(
            lambda g, h: g.astype(h.dtype) - h, worker_grads, state.ghat)
        pending = self.transport.prepare(delta, state.err)

        # per_tensor granularity binds to the eq.-(8) censor only; any other
        # policy (never / adaptive / stochastic) degenerates to the global
        # path, mirroring the legacy eps1==0 behavior.
        if self.granularity == "per_tensor" and \
                isinstance(self.censor, Eq8Censor):
            eps_pos = static_pos(self.censor.eps1)
            if eps_pos is None:
                raise NotImplementedError(
                    "per_tensor censoring needs a static eps1 (its byte "
                    "accounting divmods the payload host-side)")
            if eps_pos:
                return self._step_per_tensor(state, params, pending)

        dsq = delta_sqnorms(pending)
        ssq = step_sqnorm(params, state.prev_params)
        mask, new_censor = self.censor.decide(state.censor, dsq, ssq)

        payload = self.transport.encode(pending)
        new_err = self.transport.feedback(mask, pending, payload, state.err)
        per_tx_bytes = self.transport.payload_bytes(params)

        # server/worker synchronized advance of the stale bank
        new_ghat = jax.tree_util.tree_map(
            lambda h, q: h + _bcast(mask, h) * q.astype(h.dtype),
            state.ghat, payload)

        # grad_k = sum_m ghat_m^k  (== eq. (5) recursion unrolled)
        agg = tree_sum_leading(new_ghat)
        new_params = self.server.apply(params, state.prev_params, agg)

        stats = StepStats(mask=mask, delta_sq=dsq, step_sq=ssq,
                          agg_grad_sqnorm=tree_sqnorm(agg))
        new_state = OptState(
            prev_params=params,
            ghat=new_ghat,
            err=new_err,
            comm=state.comm.update(mask, per_tx_bytes),
            censor=new_censor,
        )
        return new_state, new_params, stats

    def _step_per_tensor(self, state: OptState, params, pending):
        """Per-tensor censoring (beyond paper; see class docstring).

        The eq.-(8) test is applied independently per parameter tensor;
        uplink bytes are accounted per transmitted tensor, uplink *count*
        counts a worker-iteration as transmitting if ANY tensor ships (so
        the headline count stays comparable with global censoring).
        Quantization/error-feedback is not combined with this mode.
        """
        assert not self.transport.stateful, \
            "per_tensor + quantized transport not supported"
        eps1 = self.censor.eps1
        leaves_delta, treedef = jax.tree_util.tree_flatten(pending)
        leaves_theta = treedef.flatten_up_to(params)
        leaves_prev = treedef.flatten_up_to(state.prev_params)
        leaves_ghat = treedef.flatten_up_to(state.ghat)

        m = self.num_workers
        new_ghat = []
        mib_up = jnp.zeros((), jnp.int32)
        rem_up = jnp.zeros((), jnp.int32)
        any_mask = jnp.zeros((m,), jnp.float32)
        for d, t, tp, h in zip(leaves_delta, leaves_theta, leaves_prev,
                               leaves_ghat):
            dsq_t = jnp.sum(jnp.square(d.astype(jnp.float32)).reshape(m, -1),
                            axis=1)                              # (M,)
            ssq_t = jnp.sum(jnp.square(t.astype(jnp.float32)
                                       - tp.astype(jnp.float32)))
            mask_t = (dsq_t > eps1 * ssq_t).astype(jnp.float32)
            any_mask = jnp.maximum(any_mask, mask_t)
            n_tx_t = jnp.sum(mask_t).astype(jnp.int32)
            # exact split-counter byte accounting (accounting.py): leaf
            # payload is static, so divmod happens in Python; carry per
            # leaf keeps the traced remainder below int32 range
            pb_mib, pb_rem = accounting.split_bytes(
                d[0].size * d.dtype.itemsize)
            mib_up, rem_up = accounting.carry_bytes(
                mib_up + n_tx_t * pb_mib, rem_up + n_tx_t * pb_rem)
            new_ghat.append(h + _bcast(mask_t, h) * d.astype(h.dtype))
        new_ghat = jax.tree_util.tree_unflatten(treedef, new_ghat)

        agg = tree_sum_leading(new_ghat)
        new_params = self.server.apply(params, state.prev_params, agg)
        comm = CommStats(
            uplink_count=state.comm.uplink_count + any_mask.astype(jnp.int32),
            uplink_mib=state.comm.uplink_mib,
            uplink_rem=state.comm.uplink_rem,
            downlink_count=state.comm.downlink_count + 1,
            iterations=state.comm.iterations + 1,
        ).add_bytes_split(mib_up, rem_up)
        stats = StepStats(mask=any_mask,
                          delta_sq=delta_sqnorms(pending),
                          step_sq=step_sqnorm(params, state.prev_params),
                          agg_grad_sqnorm=tree_sqnorm(agg))
        new_state = OptState(prev_params=params, ghat=new_ghat,
                             err=state.err, comm=comm, censor=state.censor)
        return new_state, new_params, stats

"""``ComposedOptimizer`` — Algorithm 1 assembled from pluggable stages.

This is the former ``core/chb.step`` body, refactored so that the three
orthogonal decisions (censor / transport / server) are stage calls instead
of hard-wired branches. Every composition expressible by the old
``FedOptConfig`` produces a bit-identical program (pinned by
``tests/test_opt.py``'s golden fingerprints and the ``tests/test_sweep.py``
exactness grids); new algorithms are new compositions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core import accounting
from ..core.accounting import CommStats
from ..core.censoring import delta_sqnorms, step_sqnorm
from ..core.util import tree_sqnorm, tree_stack_zeros, tree_sum_leading
from ..kernels import censor as kernel_censor
from ..kernels import fused_step as kernel_fused
from ..kernels import ops as kernel_ops
from .api import OptState, ShardStepStats, StepStats, static_pos
from .censor import CensorPolicy, Eq8Censor, NeverCensor
from .server import GradientDescent, HeavyBall, ServerUpdate
from .transport import DenseTransport, Int8Transport, Transport, _bcast

BACKENDS = ("reference", "pallas")


def _gate(mask, participate, channel_mask):
    """Compose the censor mask with the optional round gates.

    All operands are exact {0.0, 1.0} indicators, so the products are
    logical ANDs that stay exact — and with both gates absent the result
    IS ``mask``, keeping the ungated shard_step bit-identical to step.
    """
    attempted_mask = mask if participate is None else mask * participate
    delivered_mask = attempted_mask if channel_mask is None \
        else attempted_mask * channel_mask
    return attempted_mask, delivered_mask


@dataclasses.dataclass(frozen=True)
class ComposedOptimizer:
    """One censor policy + one transport + one server update.

    Structural fields (``num_workers``, ``granularity``, ``bank_dtype``,
    ``backend``, and each stage's *class*) decide the compiled program and
    must be static; the stages' scalar hyperparameters (alpha, beta, eps1,
    tau0) may be traced — which is how ``repro.sweep`` runs a whole grid
    of compositions through one compiled program.

    Attributes:
      censor: who uploads (``opt.censor``).
      transport: what the upload carries (``opt.transport``).
      server: how theta advances (``opt.server``).
      num_workers: M.
      granularity: ``"global"`` (the paper's single-vector view) or
        ``"per_tensor"`` (beyond paper: the eq.-(8) test per parameter
        tensor; requires an :class:`~repro.opt.censor.Eq8Censor` with a
        static eps1 and a dense transport).
      bank_dtype: optional dtype for the stale-gradient bank (bf16 halves
        state memory at scale).
      backend: ``"reference"`` (pure-jnp stage calls) or ``"pallas"``
        (the fused ``repro.kernels`` execution engine: one-sweep censor
        sqnorms over the stacked bank, fused bank advance, fused int8 +
        error feedback, fused eq.-(4) update). Numerics contract, for
        f32/f64 params: every fused stage runs the reference's exact
        expressions in the reference's dtypes, so steps agree up to XLA
        fusion/reduction-order ulps — and are **bit-identical on the
        pinned golden tasks** (tests/test_backend.py); see
        ``docs/kernels.md`` for the precise statement and its limits on
        large tensors. Sub-f32 params (bf16/f16) instead upcast to f32
        inside the kernels — better accumulation than the reference's
        native-bf16 arithmetic, matching the ``ref.py`` oracles but NOT
        the reference backend. Requires a fusable transport (the
        built-in dense / int8 / topk / lowrank, or any stateful
        transport providing ``encode_feedback_pallas``) and gd/hb
        servers — other custom stages have no fused path and must run
        on the reference backend.
    """

    censor: CensorPolicy
    transport: Transport
    server: ServerUpdate
    num_workers: int
    granularity: str = "global"
    bank_dtype: Any = None
    backend: str = "reference"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; valid: {BACKENDS}")
        if self.backend == "pallas":
            # the fused kernels implement the built-in stages only; a
            # custom stage silently falling back would misreport what ran.
            # A stateful transport opts into the fused step by providing
            # ``encode_feedback_pallas`` (int8/topk/lowrank do); stateless
            # ones must be the dense passthrough (the fused path never
            # calls their encode).
            fusable = isinstance(self.transport, DenseTransport) or (
                self.transport.stateful
                and hasattr(self.transport, "encode_feedback_pallas"))
            if not fusable:
                raise TypeError(
                    "backend='pallas' fuses the built-in transports "
                    "(dense | int8 | topk | lowrank) and stateful "
                    "transports providing encode_feedback_pallas; custom "
                    f"transport {type(self.transport).__name__} must run "
                    "on the reference backend")
            if not isinstance(self.server, (GradientDescent, HeavyBall)):
                raise TypeError(
                    "backend='pallas' fuses the built-in servers "
                    "(gd | hb); custom server "
                    f"{type(self.server).__name__} must run on the "
                    "reference backend")

    # ------------------------------------------------ hyperparameter views
    # Flat views of the stages' scalars, matching the legacy FedOptConfig
    # field names so hyperparameter-only consumers (core/distributed, the
    # sweep grid) read either object interchangeably.
    @property
    def alpha(self):
        return self.server.alpha

    @property
    def beta(self):
        return getattr(self.server, "beta", 0.0)

    @property
    def eps1(self):
        return getattr(self.censor, "eps1", 0.0)

    @property
    def adaptive(self):
        return getattr(self.censor, "adaptive", 0.0)

    @property
    def quantize(self) -> Optional[str]:
        return self.transport.mode

    @property
    def name(self) -> str:
        """gd/hb/lag/chb classification (paper Sec. II), or "swept"."""
        ep, bp = static_pos(self.eps1), static_pos(self.beta)
        if ep is None or bp is None:
            return "swept"
        if ep and bp:
            return "chb"
        if ep:
            return "lag"
        if bp:
            return "hb"
        return "gd"

    def with_hparams(self, *, alpha=None, beta=None,
                     eps1=None) -> "ComposedOptimizer":
        """Rebind scalar hyperparameters (possibly with traced values).

        This is the sweep engine's hook: one composition is built per
        static partition, then each grid point rebinds (alpha, beta, eps1)
        with device scalars.

        * ``beta`` rebinds a momentum server; a momentum-free server
          (``GradientDescent``) is promoted to ``HeavyBall(alpha, beta)``,
          which is bit-identical at beta=0 — so a ``lag``/``gd`` base
          sweeps exactly like the equivalent legacy config did.
        * ``eps1`` retargets an eq.-(8) censor (or upgrades a
          ``NeverCensor`` to one). Any other policy — adaptive,
          stochastic, or a custom one — keeps its own thresholds
          untouched (the engine's eps axis does not describe them; sweep
          their knobs via named ``GridPoint(algo=...)`` points instead).
        """
        server = self.server
        if alpha is not None:
            server = dataclasses.replace(server, alpha=alpha)
        if beta is not None:
            if hasattr(server, "beta"):
                server = dataclasses.replace(server, beta=beta)
            else:
                server = HeavyBall(server.alpha, beta)
        censor = self.censor
        if eps1 is not None:
            if isinstance(censor, Eq8Censor):
                censor = dataclasses.replace(censor, eps1=eps1)
            elif isinstance(censor, NeverCensor):
                censor = Eq8Censor(eps1)
            # other policies own their thresholds: leave them as composed
        return dataclasses.replace(self, censor=censor, server=server)

    # ----------------------------------------------------------- protocol
    def init(self, params) -> OptState:
        """Build the iteration-0 state (zero bank, theta^{-1} = theta^0)."""
        bank = tree_stack_zeros(params, self.num_workers)
        if self.bank_dtype is not None:
            bank = jax.tree_util.tree_map(
                lambda x: x.astype(self.bank_dtype), bank)
        # copy: prev_params must not alias params, mirroring the step-0
        # guard in core/distributed.init_scan_state — callers jit the step
        # with params AND state donated (train/trainer.py,
        # simulator.run(donate=True)), and two donated views of one buffer
        # would let XLA overwrite theta^0 while it is still theta^{-1}
        prev = jax.tree_util.tree_map(jnp.copy, params)
        return OptState(
            prev_params=prev,
            ghat=bank,
            err=self.transport.init(params, self.num_workers),
            comm=CommStats.init(self.num_workers),
            censor=self.censor.init(self.num_workers),
        )

    def metrics(self, state: OptState, stats: StepStats):
        """Per-round ``repro.obs`` MetricBag for a completed step.

        Read-only: every entry is derived from ``state``/``stats`` (plus
        each stage's ``metrics`` hook on its own state slice), so
        collecting never perturbs the trajectory. See
        ``repro.obs.metrics.step_metrics`` for the bag's contents.
        """
        from ..obs import metrics as obs_metrics
        return obs_metrics.step_metrics(self, state, stats)

    def step(self, state: OptState, params, worker_grads
             ) -> tuple[OptState, Any, StepStats]:
        """One iteration of Algorithm 1 (see ``api.FedOptimizer.step``)."""
        with jax.named_scope(f"chb_step[{self.backend}]"):
            return self._step(state, params, worker_grads)

    def _step(self, state: OptState, params, worker_grads
              ) -> tuple[OptState, Any, StepStats]:
        # per_tensor granularity binds to the eq.-(8) censor only; any other
        # policy (never / adaptive / stochastic) degenerates to the global
        # path, mirroring the legacy eps1==0 behavior.
        if self.granularity == "per_tensor" and \
                isinstance(self.censor, Eq8Censor):
            eps_pos = static_pos(self.censor.eps1)
            if eps_pos is None:
                raise NotImplementedError(
                    "per_tensor censoring needs a static eps1 (its byte "
                    "accounting divmods the payload host-side)")
            if eps_pos:
                delta = jax.tree_util.tree_map(
                    lambda g, h: g.astype(h.dtype) - h,
                    worker_grads, state.ghat)
                pending = self.transport.prepare(delta, state.err)
                return self._step_per_tensor(state, params, pending)

        if self.backend == "pallas":
            return self._step_pallas(state, params, worker_grads)

        # delta_m = g_m - ghat_m (in the bank's dtype for exact sync)
        delta = jax.tree_util.tree_map(
            lambda g, h: g.astype(h.dtype) - h, worker_grads, state.ghat)
        pending = self.transport.prepare(delta, state.err)
        dsq = delta_sqnorms(pending)
        ssq = step_sqnorm(params, state.prev_params)
        mask, new_censor = self.censor.decide(state.censor, dsq, ssq)

        payload, aux = self.transport.encode(pending, state.err)
        new_err = self.transport.feedback(mask, pending, payload, aux,
                                          state.err)
        per_tx_bytes = self.transport.payload_bytes(params)

        # server/worker synchronized advance of the stale bank
        new_ghat = jax.tree_util.tree_map(
            lambda h, q: h + _bcast(mask, h) * q.astype(h.dtype),
            state.ghat, payload)

        # grad_k = sum_m ghat_m^k  (== eq. (5) recursion unrolled)
        agg = tree_sum_leading(new_ghat)
        new_params = self.server.apply(params, state.prev_params, agg)

        stats = StepStats(mask=mask, delta_sq=dsq, step_sq=ssq,
                          agg_grad_sqnorm=tree_sqnorm(agg))
        new_state = OptState(
            prev_params=params,
            ghat=new_ghat,
            err=new_err,
            comm=state.comm.update(mask, per_tx_bytes),
            censor=new_censor,
        )
        return new_state, new_params, stats

    def _step_pallas(self, state: OptState, params, worker_grads
                     ) -> tuple[OptState, Any, StepStats]:
        """The fused-kernel execution of the global-granularity step.

        Stage semantics are identical to the reference path — same censor
        ``decide``, same accounting, same state layout — but every
        parameter-sized sweep runs through ``repro.kernels``:

          * eq.-(8) left-hand side: one fused sweep per leaf over the
            stacked bank (dense transports never materialize the delta
            tree at all);
          * bank advance: one fused ``ghat + mask * delta`` sweep;
          * stateful transports: the transport's own
            ``encode_feedback_pallas`` route — int8 runs a per-worker
            abs-max reduction plus ONE fused sweep emitting payload and
            error-feedback bank together; top-k packs its keep selection
            and the EF update in one fused sweep; low-rank fuses the
            residual/EF blend after its (jnp, MXU-bound) factor matmuls;
          * eq. (4): the one-sweep heavy-ball kernel with traced
            alpha/beta SMEM operands.

        Numerics at f32/f64: per-element expressions and dtypes match
        the reference path exactly; what may differ is XLA's fusion of
        the jnp side (FMA contraction on large tensors) and the tiled
        partial-sum order of the sqnorm reductions — both ulp-level per
        step. Golden-pinned bit-identical on the paper-scale tasks
        (tests/test_backend.py); on much larger tensors trajectories can
        drift by compounded ulps while censor masks and uplink counts
        stay aligned (see docs/kernels.md). Sub-f32 params compute in
        f32 in-kernel and therefore genuinely diverge from the
        reference's native-bf16 arithmetic (they match the ``ref.py``
        oracles instead).
        """
        # fused megakernel routing (kernels/fused_step.py): dense and
        # int8+EF run the whole post-``decide`` tail as ONE sweep per
        # leaf; topk/lowrank (host-graph top_k / factor matmuls between
        # the elementwise stages) keep the staged path. The flag is
        # consulted at trace time — ``fused_step.force_staged()`` pins a
        # program to the staged kernels for A/B comparison.
        fused = kernel_fused.fusion_enabled()
        int8_fused = fused and type(self.transport) is Int8Transport
        quantized = self.transport.stateful
        dense_fused = fused and not quantized
        pending = scales = None
        if int8_fused:
            # sweep 1: sqnorm + abs-max partials from an in-register
            # pending recompute — the pending tree is never materialized
            dsq, scales = kernel_ops.tree_int8_stats(
                worker_grads, state.ghat, state.err)
        elif quantized:
            delta = jax.tree_util.tree_map(
                lambda g, h: g.astype(h.dtype) - h,
                worker_grads, state.ghat)
            pending = self.transport.prepare(delta, state.err)
            dsq = kernel_ops.tree_sqnorms(pending)
        else:
            dsq = kernel_ops.tree_delta_sqnorms(worker_grads, state.ghat)
        ssq = step_sqnorm(params, state.prev_params)
        mask, new_censor = self.censor.decide(state.censor, dsq, ssq)

        alpha = self.server.alpha
        beta = getattr(self.server, "beta", 0.0)
        if dense_fused:
            new_err = state.err
            new_ghat, agg, new_params = kernel_ops.tree_fused_dense_step(
                worker_grads, state.ghat, params, state.prev_params, mask,
                alpha, beta)
        elif int8_fused:
            new_ghat, new_err, agg, new_params = \
                kernel_ops.tree_fused_int8_step(
                    worker_grads, state.ghat, state.err, params,
                    state.prev_params, mask, scales, alpha, beta)
        else:
            if quantized:
                payload, new_err = self.transport.encode_feedback_pallas(
                    pending, state.err, mask)
                new_ghat = kernel_ops.tree_bank_advance(state.ghat,
                                                        payload, mask)
            else:
                new_err = state.err
                new_ghat = kernel_ops.tree_censor_bank_advance(
                    worker_grads, state.ghat, mask)
            agg = tree_sum_leading(new_ghat)
            new_params = self.apply_server(params, state.prev_params, agg)
        per_tx_bytes = self.transport.payload_bytes(params)

        if dense_fused or int8_fused:
            # diagnostic-only recompute: the kernel's agg output is
            # bitwise-identical, but a sqnorm fused over a sliced pallas
            # buffer groups its reduction differently from one fused over
            # the host sum — recomputing keeps the stat's HLO subgraph
            # identical to the staged/reference path (tier-1 bit parity)
            agg = tree_sum_leading(new_ghat)
        stats = StepStats(mask=mask, delta_sq=dsq, step_sq=ssq,
                          agg_grad_sqnorm=tree_sqnorm(agg))
        new_state = OptState(
            prev_params=params,
            ghat=new_ghat,
            err=new_err,
            comm=state.comm.update(mask, per_tx_bytes),
            censor=new_censor,
        )
        return new_state, new_params, stats

    def shard_step(self, state: OptState, params, worker_grads, *,
                   worker_ids=None, participate=None, channel_mask=None
                   ) -> tuple[OptState, Any, ShardStepStats]:
        """The client-side half of a step, for ONE mesh shard.

        This is ``step`` with the server update factored out: it runs the
        censor/transport stages and the bank advance for a shard-local
        block of workers and returns the shard's eq.-(5) **partial**
        aggregate ``sum_m ghat_m`` instead of new params. The sharded fed
        runtime (``repro.fed.mesh``) folds the K partials with a single
        ``psum`` (``core.distributed.make_client_fold``) and advances
        theta once via ``apply_server`` — over one shard with no gates,
        the composed program is bit-identical to ``step`` (the sync
        anchor; partial + identity-psum + apply is the same HLO as
        ``step``'s agg + apply).

        Args:
          state: SHARD-LOCAL state (``(M_local, ...)`` bank rows, the
            shard's own CommStats; replicated censor state).
          params / worker_grads: theta^k (replicated) and the shard's
            ``(M_local, ...)`` stacked gradients.
          worker_ids: the shard's absolute global client ids — draw-keyed
            censors fold these so the masks are invariant to how the
            population is split (omit for a single full-population shard).
          participate: optional (M_local,) {0,1} gate — who woke up this
            round. Censor-passing non-participants do NOT transmit.
          channel_mask: optional (M_local,) {0,1} gate — whose uplink
            survived the channel. Transmissions that drop still spend
            bytes/energy (``attempted``) but never reach the bank
            (``delivered``), matching ``sweep.fed_sweep`` semantics.
        Returns:
          ``(new_state, partial_agg, ShardStepStats)``.
        """
        if self.granularity != "global":
            raise NotImplementedError(
                "shard_step supports global granularity only (per_tensor "
                "byte accounting is host-side and unsharded)")
        if self.backend == "pallas":
            return self._shard_step_pallas(
                state, params, worker_grads, worker_ids=worker_ids,
                participate=participate, channel_mask=channel_mask)

        delta = jax.tree_util.tree_map(
            lambda g, h: g.astype(h.dtype) - h, worker_grads, state.ghat)
        pending = self.transport.prepare(delta, state.err)
        dsq = delta_sqnorms(pending)
        ssq = step_sqnorm(params, state.prev_params)
        mask, new_censor = self._decide(state.censor, dsq, ssq, worker_ids)
        attempted_mask, delivered_mask = _gate(mask, participate,
                                               channel_mask)

        payload, aux = self.transport.encode(pending, state.err)
        new_err = self.transport.feedback(delivered_mask, pending, payload,
                                          aux, state.err)
        new_ghat = jax.tree_util.tree_map(
            lambda h, q: h + _bcast(delivered_mask, h) * q.astype(h.dtype),
            state.ghat, payload)
        partial = tree_sum_leading(new_ghat)

        stats = ShardStepStats(mask=mask, attempted=attempted_mask,
                               delivered=delivered_mask, delta_sq=dsq,
                               step_sq=ssq)
        new_state = OptState(
            prev_params=params,
            ghat=new_ghat,
            err=new_err,
            comm=state.comm.update(attempted_mask,
                                   self.transport.payload_bytes(params)),
            censor=new_censor,
        )
        return new_state, partial, stats

    def _shard_step_pallas(self, state: OptState, params, worker_grads, *,
                           worker_ids=None, participate=None,
                           channel_mask=None):
        """Staged-kernel ``shard_step``. The megakernel is out of reach
        here — it fuses the eq.-(4) update into the sweep, and the server
        half of a sharded round runs after the cross-shard fold — so this
        path always takes the staged kernels (sqnorm sweeps, fused
        encode+EF, fused bank advance), matching ``_step_pallas`` with
        ``force_staged()`` minus the server apply."""
        quantized = self.transport.stateful
        pending = None
        if quantized:
            delta = jax.tree_util.tree_map(
                lambda g, h: g.astype(h.dtype) - h,
                worker_grads, state.ghat)
            pending = self.transport.prepare(delta, state.err)
            dsq = kernel_ops.tree_sqnorms(pending)
        else:
            dsq = kernel_ops.tree_delta_sqnorms(worker_grads, state.ghat)
        ssq = step_sqnorm(params, state.prev_params)
        mask, new_censor = self._decide(state.censor, dsq, ssq, worker_ids)
        attempted_mask, delivered_mask = _gate(mask, participate,
                                               channel_mask)

        if quantized:
            payload, new_err = self.transport.encode_feedback_pallas(
                pending, state.err, delivered_mask)
            new_ghat = kernel_ops.tree_bank_advance(state.ghat, payload,
                                                    delivered_mask)
        else:
            new_err = state.err
            new_ghat = kernel_ops.tree_censor_bank_advance(
                worker_grads, state.ghat, delivered_mask)
        partial = tree_sum_leading(new_ghat)

        stats = ShardStepStats(mask=mask, attempted=attempted_mask,
                               delivered=delivered_mask, delta_sq=dsq,
                               step_sq=ssq)
        new_state = OptState(
            prev_params=params,
            ghat=new_ghat,
            err=new_err,
            comm=state.comm.update(attempted_mask,
                                   self.transport.payload_bytes(params)),
            censor=new_censor,
        )
        return new_state, partial, stats

    def _decide(self, censor_state, dsq, ssq, worker_ids):
        if worker_ids is None:
            return self.censor.decide(censor_state, dsq, ssq)
        return self.censor.decide_ids(censor_state, dsq, ssq, worker_ids)

    def apply_server(self, params, prev_params, agg):
        """The backend-dispatched server update (``repro.fed`` hook).

        The event runtime calls this instead of ``server.apply`` so a
        pallas composition advances theta through the fused eq.-(4)
        kernel there too. ``GradientDescent`` runs the kernel at beta=0,
        which is bit-identical to its reference delegation by
        construction.
        """
        if self.backend == "pallas":
            return kernel_ops.tree_hb_update(
                params, prev_params, agg, self.server.alpha,
                getattr(self.server, "beta", 0.0))
        return self.server.apply(params, prev_params, agg)

    def _step_per_tensor(self, state: OptState, params, pending):
        """Per-tensor censoring (beyond paper; see class docstring).

        The eq.-(8) test is applied independently per parameter tensor;
        uplink bytes are accounted per transmitted tensor, uplink *count*
        counts a worker-iteration as transmitting if ANY tensor ships (so
        the headline count stays comparable with global censoring).
        Quantization/error-feedback is not combined with this mode.
        """
        assert not self.transport.stateful, \
            "per_tensor + quantized transport not supported"
        eps1 = self.censor.eps1
        leaves_delta, treedef = jax.tree_util.tree_flatten(pending)
        leaves_theta = treedef.flatten_up_to(params)
        leaves_prev = treedef.flatten_up_to(state.prev_params)
        leaves_ghat = treedef.flatten_up_to(state.ghat)

        m = self.num_workers
        new_ghat = []
        mib_up = jnp.zeros((), jnp.int32)
        rem_up = jnp.zeros((), jnp.int32)
        any_mask = jnp.zeros((m,), jnp.float32)
        pallas = self.backend == "pallas"
        for d, t, tp, h in zip(leaves_delta, leaves_theta, leaves_prev,
                               leaves_ghat):
            if pallas:          # fused per-leaf eq.-(8) partials
                dsq_t = kernel_censor.sqnorm_batched(d)          # (M,)
            else:
                dsq_t = jnp.sum(
                    jnp.square(d.astype(jnp.float32)).reshape(m, -1),
                    axis=1)                                      # (M,)
            ssq_t = jnp.sum(jnp.square(t.astype(jnp.float32)
                                       - tp.astype(jnp.float32)))
            mask_t = (dsq_t > eps1 * ssq_t).astype(jnp.float32)
            any_mask = jnp.maximum(any_mask, mask_t)
            n_tx_t = jnp.sum(mask_t).astype(jnp.int32)
            # exact split-counter byte accounting (accounting.py): leaf
            # payload is static, so divmod happens in Python; carry per
            # leaf keeps the traced remainder below int32 range
            pb_mib, pb_rem = accounting.split_bytes(
                d[0].size * d.dtype.itemsize)
            mib_up, rem_up = accounting.carry_bytes(
                mib_up + n_tx_t * pb_mib, rem_up + n_tx_t * pb_rem)
            if pallas:          # fused bank advance, one sweep per leaf
                new_ghat.append(kernel_censor.bank_advance(h, d, mask_t))
            else:
                new_ghat.append(h + _bcast(mask_t, h) * d.astype(h.dtype))
        new_ghat = jax.tree_util.tree_unflatten(treedef, new_ghat)

        agg = tree_sum_leading(new_ghat)
        new_params = self.apply_server(params, state.prev_params, agg)
        comm = CommStats(
            uplink_count=state.comm.uplink_count + any_mask.astype(jnp.int32),
            uplink_mib=state.comm.uplink_mib,
            uplink_rem=state.comm.uplink_rem,
            downlink_count=state.comm.downlink_count + 1,
            iterations=state.comm.iterations + 1,
        ).add_bytes_split(mib_up, rem_up)
        stats = StepStats(mask=any_mask,
                          delta_sq=delta_sqnorms(pending),
                          step_sq=step_sqnorm(params, state.prev_params),
                          agg_grad_sqnorm=tree_sqnorm(agg))
        new_state = OptState(prev_params=params, ghat=new_ghat,
                             err=state.err, comm=comm, censor=state.censor)
        return new_state, new_params, stats

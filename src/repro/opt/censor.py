"""Censor policies: who uploads this round.

Each policy answers the same question — "is worker m's delta novel enough
to transmit?" — with different information:

  * :class:`NeverCensor` — everyone transmits (GD/HB family).
  * :class:`Eq8Censor` — the paper's eq. (8): transmit iff
    ``||delta_m||^2 > eps1 * ||theta^k - theta^{k-1}||^2``.
  * :class:`AdaptiveCensor` — beyond paper: relative-novelty EMA test
    (the paper's Sec.-V open problem on tuning eps1).
  * :class:`StochasticCensor` — CSGD-style (Li et al., arXiv:1909.03631):
    a geometrically decaying threshold ``tau_k = tau0 * decay^k`` applied
    stochastically — worker m transmits iff ``||delta_m||^2 > u_m * tau_k``
    with ``u_m ~ U(0,1)`` drawn per (round, worker).

Two entry points, two execution environments:

  * ``decide(state, delta_sq, step_sq)`` — batched over all M workers;
    used by the composed step (simulator / sweep / trainer paths).
  * ``client_decide(round_index, worker, delta_sq, step_sq)`` — one
    worker's decision, evaluated inside the event-driven ``repro.fed``
    runtime at whatever wall-clock moment the client finishes computing.
    Policies whose decisions can be made per-client (everything except the
    adaptive EMA, which needs the whole cohort's deltas) set
    ``supports_event_runtime = True`` and guarantee that a synchronous
    schedule reproduces ``decide``'s masks draw-for-draw.

Dtype discipline: every decision is evaluated in the norms' (f32)
precision for static AND traced hyperparameters — the sweep engine's
bit-exactness contract depends on it (see ``core/censoring._eps_cast``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..core.censoring import transmit_mask, _eps_cast
from .api import static_pos


@runtime_checkable
class CensorPolicy(Protocol):
    """Pluggable stage deciding the per-worker transmit mask."""

    supports_event_runtime: ClassVar[bool]

    def init(self, num_workers: int) -> Any:
        """Policy state at iteration 0 (lives in ``OptState.censor``)."""
        ...

    def decide(self, state, delta_sq: jax.Array, step_sq: jax.Array
               ) -> tuple[jax.Array, Any]:
        """Batched decision: ``((M,) f32 mask, new_state)``."""
        ...

    def client_decide(self, round_index, worker, delta_sq: jax.Array,
                      step_sq: jax.Array) -> jax.Array:
        """One worker's decision (bool scalar) for the event runtime."""
        ...

    def decide_ids(self, state, delta_sq: jax.Array, step_sq: jax.Array,
                   worker_ids: jax.Array) -> tuple[jax.Array, Any]:
        """``decide`` for a client SHARD carrying absolute worker ids.

        The sharded fed runtime (``repro.fed.mesh``) evaluates each mesh
        shard's censor decisions locally; ``worker_ids`` are the shard's
        absolute global client ids, so draw-keyed policies (stochastic)
        fold the same per-(round, client) keys regardless of how the
        population is split — the K-invariance anchor. Id-independent
        policies delegate to ``decide``.
        """
        ...

    def metrics(self, state) -> dict:
        """Optional ``repro.obs`` hook: stage-local scalar observables.

        Called with the policy's own state slice after each step; returned
        keys are namespaced ``censor/<kind>/<key>`` in the MetricBag.
        Must be read-only (metric collection never perturbs the run).
        """
        ...


@dataclasses.dataclass(frozen=True)
class NeverCensor:
    """Every worker transmits every round (classical GD/HB)."""

    supports_event_runtime: ClassVar[bool] = True

    def init(self, num_workers: int):
        return ()

    def decide(self, state, delta_sq, step_sq):
        return jnp.ones(delta_sq.shape, jnp.float32), state

    def client_decide(self, round_index, worker, delta_sq, step_sq):
        return jnp.ones((), jnp.bool_)

    def decide_ids(self, state, delta_sq, step_sq, worker_ids):
        return self.decide(state, delta_sq, step_sq)

    def metrics(self, state) -> dict:
        return {}


@dataclasses.dataclass(frozen=True)
class Eq8Censor:
    """The paper's skip condition (eq. 8).

    ``eps1`` may be a Python float or a traced scalar (the sweep engine
    maps a whole eps-grid through one compiled program). A traced eps1
    compiles a branch-free ``where`` that is bitwise identical to the
    static branches for every concrete value.
    """

    eps1: Any
    supports_event_runtime: ClassVar[bool] = True

    def init(self, num_workers: int):
        return ()

    def decide(self, state, delta_sq, step_sq):
        pos = static_pos(self.eps1)
        if pos is None:
            # traced eps1 (repro.sweep): eps1 > 0 runs the eq.-(8) test,
            # eps1 == 0 transmits unconditionally.
            mask = jnp.where(jnp.asarray(self.eps1) > 0,
                             transmit_mask(delta_sq, step_sq, self.eps1),
                             jnp.ones(delta_sq.shape, jnp.float32))
        elif pos:
            mask = transmit_mask(delta_sq, step_sq, self.eps1)
        else:
            mask = jnp.ones(delta_sq.shape, jnp.float32)
        return mask, state

    def client_decide(self, round_index, worker, delta_sq, step_sq):
        if static_pos(self.eps1) is False:
            return jnp.ones((), jnp.bool_)
        return delta_sq > _eps_cast(self.eps1, step_sq) * step_sq

    def decide_ids(self, state, delta_sq, step_sq, worker_ids):
        # eq. (8) reads only the norms; the shard's ids are irrelevant
        return self.decide(state, delta_sq, step_sq)

    def metrics(self, state) -> dict:
        # the threshold itself (possibly traced): a swept eps1 shows up in
        # the per-point metric series, making sweep bags self-describing
        return {"eps1": jnp.asarray(self.eps1, jnp.float32)}


@dataclasses.dataclass(frozen=True)
class AdaptiveCensor:
    """Beyond paper: transmit iff ``||delta_m||^2 > adaptive * EMA_m``.

    A scale-free relative-novelty test needing no knowledge of L or the
    step norm (see ``core/chb.py``'s original docstring). Stateful across
    the whole cohort (the EMA update consumes every worker's delta), so it
    cannot run in the asynchronous event runtime.
    """

    adaptive: float
    decay: float = 0.9
    supports_event_runtime: ClassVar[bool] = False

    def init(self, num_workers: int):
        return jnp.zeros((num_workers,), jnp.float32)

    def decide(self, ema, delta_sq, step_sq):
        warm = ema > 0
        mask = jnp.where(warm,
                         (delta_sq > self.adaptive * ema)
                         .astype(jnp.float32), 1.0)
        new_ema = jnp.where(warm,
                            self.decay * ema
                            + (1 - self.decay) * delta_sq, delta_sq)
        return mask, new_ema

    def client_decide(self, round_index, worker, delta_sq, step_sq):
        raise NotImplementedError(
            "adaptive censoring needs the whole cohort's deltas; it cannot "
            "run in the event-driven fed runtime")

    def decide_ids(self, ema, delta_sq, step_sq, worker_ids):
        # the EMA test is elementwise per worker, so a shard holding its
        # own EMA slice delegates cleanly (ids unused)
        return self.decide(ema, delta_sq, step_sq)

    def metrics(self, ema) -> dict:
        return {"ema_mean": jnp.mean(ema), "ema_max": jnp.max(ema)}


@dataclasses.dataclass(frozen=True)
class StochasticCensor:
    """CSGD-style stochastic censoring (Li et al., arXiv:1909.03631).

    CSGD censors against a geometrically decaying threshold sequence
    ``tau_k = tau0 * decay^k`` (novelty demanded of an upload shrinks as
    the iterates converge). We apply it stochastically: worker m draws
    ``u_m ~ U(0,1)`` per round and transmits iff
    ``||delta_m||^2 > u_m * tau_k`` — transmit probability
    ``min(1, ||delta||^2 / tau_k)``, so large deltas always ship and small
    ones ship with probability proportional to their novelty (which keeps
    the bank live even when ``tau0`` overshoots the problem's scale).

    The per-(round, worker) uniforms are derived by key folding, so the
    batched ``decide`` and the event runtime's ``client_decide`` see the
    *same* draws — a synchronous edge schedule reproduces the simulator
    exactly. ``tau0`` may be traced (sweepable); ``decay``/``seed`` are
    static. State is the round counter k.
    """

    tau0: Any
    decay: float = 0.99
    seed: int = 0
    supports_event_runtime: ClassVar[bool] = True

    def init(self, num_workers: int):
        return jnp.zeros((), jnp.int32)

    def _tau(self, k) -> jax.Array:
        return (jnp.asarray(self.tau0).astype(jnp.float32)
                * jnp.asarray(self.decay, jnp.float32) ** k)

    def _uniform(self, k, worker) -> jax.Array:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), k)
        return jax.random.uniform(jax.random.fold_in(key, worker))

    def decide(self, k, delta_sq, step_sq):
        workers = jnp.arange(delta_sq.shape[0])
        return self.decide_ids(k, delta_sq, step_sq, workers)

    def client_decide(self, round_index, worker, delta_sq, step_sq):
        u = self._uniform(round_index, worker)
        return delta_sq > u * self._tau(round_index)

    def decide_ids(self, k, delta_sq, step_sq, worker_ids):
        # folding the shard's ABSOLUTE ids (not a local arange) makes the
        # draws identical under any split of the population across shards
        u = jax.vmap(lambda i: self._uniform(k, i))(worker_ids)
        mask = (delta_sq > u * self._tau(k)).astype(jnp.float32)
        return mask, k + 1

    def metrics(self, k) -> dict:
        # k is the post-step round counter, so tau is the threshold the
        # NEXT round will test against (the decayed sequence, observable)
        return {"tau": self._tau(k), "round": k}

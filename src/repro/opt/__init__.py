"""repro.opt: the composable federated-optimizer protocol.

Algorithm 1 and its whole literature neighborhood decompose into three
pluggable stages — a censor policy (who uploads), a transport (what the
upload carries), and a server update (how theta advances). A
:class:`ComposedOptimizer` glues one of each together; the string-keyed
registry names the useful compositions and round-trips them to/from JSON
config dicts for sweeps, CLI flags, and benchmark artifacts.

    from repro import opt
    o = opt.make("chb", alpha=0.05, num_workers=9)     # by name
    o = opt.ComposedOptimizer(                          # or by hand
        censor=opt.Eq8Censor(0.4), transport=opt.DenseTransport(),
        server=opt.HeavyBall(0.05, beta=0.4), num_workers=9)
    hist = simulator.run(o, task, 1000)                 # runs everywhere

Every consumer (``core.simulator``, ``repro.sweep``, ``repro.fed``, the
trainer) is written against the :class:`FedOptimizer` protocol and also
still accepts the deprecated ``core.chb.FedOptConfig`` facade. See
``docs/opt_api.md`` for the stage anatomy and the add-your-own-algorithm
tutorial.
"""
from .api import FedOptimizer, OptState, ShardStepStats, StepStats, \
    static_pos
from .censor import (AdaptiveCensor, CensorPolicy, Eq8Censor, NeverCensor,
                     StochasticCensor)
from .compat import as_optimizer, from_config
from .optimizer import BACKENDS, ComposedOptimizer
from .registry import (CENSOR_KINDS, SERVER_KINDS, TRANSPORT_KINDS,
                       from_spec, make, make_for_point, make_transport,
                       names, register, to_spec, transport_names)
from .server import GradientDescent, HeavyBall, ServerUpdate
from .transport import (DenseTransport, Int8Transport, LowRankTransport,
                        TopKTransport, Transport)

__all__ = [
    "FedOptimizer", "OptState", "StepStats", "ShardStepStats",
    "static_pos",
    "CensorPolicy", "NeverCensor", "Eq8Censor", "AdaptiveCensor",
    "StochasticCensor",
    "Transport", "DenseTransport", "Int8Transport", "TopKTransport",
    "LowRankTransport",
    "ServerUpdate", "GradientDescent", "HeavyBall",
    "ComposedOptimizer", "BACKENDS",
    "register", "make", "make_for_point", "names", "to_spec", "from_spec",
    "make_transport", "transport_names",
    "CENSOR_KINDS", "TRANSPORT_KINDS", "SERVER_KINDS",
    "from_config", "as_optimizer",
]

"""The `repro.opt` federated-optimizer protocol.

The paper's Algorithm 1 is a *composition* of three orthogonal decisions:

  1. a **censor policy** — which workers upload this round (eq. 8, or any
     other novelty test: adaptive EMA thresholds, CSGD-style stochastic
     decaying thresholds, ...),
  2. a **transport** — what bits the upload carries (dense deltas, int8
     with error feedback, ...),
  3. a **server update** — how theta advances from the aggregate (plain
     gradient descent, or the eq.-(4) heavy-ball recursion).

A :class:`FedOptimizer` is anything with ``init``/``step``; the concrete
implementation shipped here (``optimizer.ComposedOptimizer``) glues one
choice of each stage together. New algorithms from the censoring literature
are new *compositions*, not new forks of the step function — see
``docs/opt_api.md`` for the 20-line tutorial.

State/stats layouts are shared with the legacy ``core.chb`` facade so the
two remain bit-interchangeable (the facade delegates here).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, NamedTuple, Optional, Protocol, \
    runtime_checkable

import jax

if TYPE_CHECKING:   # annotation only: keeps this module import-cycle-free
    from ..core.accounting import CommStats


class OptState(NamedTuple):
    """Optimizer state threaded through every iteration of Algorithm 1.

    Attributes:
      prev_params: theta^{k-1} (the eq.-(4) momentum anchor).
      ghat: (M, ...) stale-gradient bank — worker m's last transmitted
        gradient (eq. 5 unrolled; see ``core/chb.py`` module docstring).
      err: transport state — the (M, ...) quantization error-feedback bank
        for int8, or empty leaves for dense transport.
      comm: precision-safe uplink/downlink counters (``core/accounting``).
      censor: censor-policy state — () for stateless policies (eq. 8),
        the (M,) EMA for the adaptive policy, the round counter for the
        stochastic (CSGD) policy.
    """
    prev_params: Any
    ghat: Any
    err: Any
    comm: "CommStats"
    censor: Any = ()


class StepStats(NamedTuple):
    """Per-iteration diagnostics returned by ``FedOptimizer.step``."""
    mask: jax.Array             # (M,) 1 = worker transmitted
    delta_sq: jax.Array         # (M,) ||delta_m||^2
    step_sq: jax.Array          # () ||theta^k - theta^{k-1}||^2
    agg_grad_sqnorm: jax.Array  # () ||grad_k||^2 (paper's NN metric, squared)


class ShardStepStats(NamedTuple):
    """Per-round diagnostics from ``ComposedOptimizer.shard_step``.

    All arrays are shard-local ``(M_local,)`` rows; the sharded fed runtime
    (``repro.fed.mesh``) reduces them to the scalars its quorum fold ships
    (arrived counts, loss partials). ``mask`` is the raw censor decision;
    ``attempted`` adds the participation gate (what actually hit the air —
    the comm/energy basis); ``delivered`` adds the channel gate (what the
    bank folded).
    """
    mask: jax.Array        # (M_local,) censor pass
    attempted: jax.Array   # (M_local,) censor AND participate (bytes basis)
    delivered: jax.Array   # (M_local,) attempted AND channel pass (bank fold)
    delta_sq: jax.Array    # (M_local,) ||delta_m||^2
    step_sq: jax.Array     # () ||theta^k - theta^{k-1}||^2


@runtime_checkable
class FedOptimizer(Protocol):
    """The ``repro.opt`` protocol every consumer is written against.

    ``core.simulator`` (and the trainer's scan) drive an optimizer through
    these two methods alone, so any implementation runs there. The stage
    hosts go further: ``repro.fed``'s event runtime calls the censor's
    ``client_decide`` and the transport's row entry points, and
    ``repro.sweep`` rebinds stage hyperparameters per grid point — both
    therefore require a ``ComposedOptimizer`` (or something exposing the
    same ``censor``/``transport``/``server`` attributes) and reject
    anything else with a clear error.
    """

    num_workers: int

    def init(self, params) -> OptState:
        """Build the iteration-0 state (zero bank, theta^{-1} = theta^0)."""
        ...

    def step(self, state: OptState, params, worker_grads
             ) -> tuple[OptState, Any, StepStats]:
        """One server iteration: fold censored uploads, advance theta.

        Args:
          state: current optimizer state.
          params: theta^k.
          worker_grads: pytree stacked with leading axis M — each worker's
            local gradient at theta^k.
        Returns:
          ``(new_state, new_params, stats)``.
        """
        ...


def static_pos(x) -> Optional[bool]:
    """``bool(x > 0)`` for static scalars; ``None`` when ``x`` is traced.

    The stages use this to keep *structural* decisions (does a state buffer
    exist? which censor branch compiles?) out of traced code while still
    letting hyperparameter *values* be traced by the sweep engine.
    """
    if isinstance(x, jax.core.Tracer):
        return None
    return bool(x > 0)

"""String-keyed algorithm registry + config-dict round-tripping.

The registry replaces ``core.baselines.ALGORITHMS``: an algorithm *name*
maps to a builder that composes stages. Sweeps, the CLI trainer, and
benchmark artifacts all go through it, so a registered name is runnable
everywhere a built-in one is.

    from repro import opt
    o = opt.make("chb", alpha=0.05, num_workers=9)
    spec = opt.to_spec(o)                  # JSON-able config dict
    assert opt.from_spec(spec) == o        # round-trips exactly

Builders take ``(alpha, num_workers, **hyper)``. To be sweepable via
``GridPoint(algo=...)`` a builder should accept (a subset of) the grid's
keywords — ``beta``, ``eps1``, ``quantize``, ``seed`` — the engine filters
its keyword set by the builder's signature (``make_for_point``), so a
builder that ignores an axis simply never sees it.

Register your own in ~20 lines — see ``docs/opt_api.md``.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Optional

import jax.numpy as jnp

from ..core.censoring import paper_eps1
from .censor import (AdaptiveCensor, Eq8Censor, NeverCensor,
                     StochasticCensor)
from .optimizer import ComposedOptimizer
from .server import GradientDescent, HeavyBall
from .transport import (DenseTransport, Int8Transport, LowRankTransport,
                        TopKTransport, Transport)

Builder = Callable[..., ComposedOptimizer]

_ALGORITHMS: dict[str, Builder] = {}

# stage-kind tables: the spec vocabulary for to_spec/from_spec
CENSOR_KINDS: dict[str, type] = {
    "never": NeverCensor,
    "eq8": Eq8Censor,
    "adaptive": AdaptiveCensor,
    "stochastic": StochasticCensor,
}
TRANSPORT_KINDS: dict[str, type] = {
    "dense": DenseTransport,
    "int8": Int8Transport,
    "topk": TopKTransport,
    "lowrank": LowRankTransport,
}
SERVER_KINDS: dict[str, type] = {
    "gd": GradientDescent,
    "hb": HeavyBall,
}


def register(name: str) -> Callable[[Builder], Builder]:
    """Decorator: add a builder to the registry under ``name``."""
    def deco(fn: Builder) -> Builder:
        _ALGORITHMS[name] = fn
        return fn
    return deco


def names() -> tuple[str, ...]:
    """The registered algorithm names, sorted."""
    return tuple(sorted(_ALGORITHMS))


def _unknown(name: str) -> ValueError:
    listing = "\n".join(f"  {n}" for n in names())
    return ValueError(
        f"unknown algorithm {name!r}; valid names:\n{listing}")


def make(name: str, alpha, num_workers: int, **hyper) -> ComposedOptimizer:
    """Build a registered algorithm by name.

    Args:
      name: a key in ``names()``; unknown names raise with the valid list
        (same contract as ``benchmarks/run.py --only``).
      alpha: server step size (may be traced).
      num_workers: M (static).
      **hyper: builder-specific hyperparameters (beta, eps1, tau0, ...).
    """
    if name not in _ALGORITHMS:
        raise _unknown(name)
    return _ALGORITHMS[name](alpha, num_workers, **hyper)


def make_for_point(name: str, alpha, num_workers: int, **hyper
                   ) -> ComposedOptimizer:
    """``make`` with ``hyper`` filtered by the builder's signature.

    The sweep engine calls every named point with its full keyword set
    (beta, eps1, quantize, seed); builders only receive the ones they
    declare, so e.g. ``gd`` never sees ``beta``.
    """
    if name not in _ALGORITHMS:
        raise _unknown(name)
    fn = _ALGORITHMS[name]
    params = inspect.signature(fn).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        kw = hyper
    else:
        kw = {k: v for k, v in hyper.items() if k in params}
    return fn(alpha, num_workers, **kw)


def transport_names() -> tuple[str, ...]:
    """The registered transport kinds, sorted (the ``quantize`` /
    ``transport`` vocabulary of grids and builders)."""
    return tuple(sorted(TRANSPORT_KINDS))


def make_transport(kind: Optional[str], **hyper) -> Transport:
    """Build a registered transport by kind.

    Args:
      kind: a ``TRANSPORT_KINDS`` key, or ``None`` for the dense
        passthrough (legacy ``quantize=None``).
      **hyper: transport hyperparameters (``k`` for topk, ``rank`` for
        lowrank); passing one to a transport without that knob raises.
    """
    if kind is None:
        kind = "dense"
    if kind not in TRANSPORT_KINDS:
        raise ValueError(f"unknown quantize mode {kind!r} "
                         f"(expected None or one of {transport_names()})")
    return TRANSPORT_KINDS[kind](**hyper)


def _transport(quantize: Optional[str]):
    return make_transport(quantize)


def _resolve_transport(quantize, transport, k, rank) -> Transport:
    """The transport a builder's keywords describe.

    ``transport`` may be a kind string, a ready :class:`Transport`
    instance (hyperparameters already bound, e.g. a task-scaled topk), or
    ``None``; ``quantize`` is the legacy alias for the kind string. ``k``
    and ``rank`` forward to the matching transport's constructor.
    """
    if transport is not None and not isinstance(transport, str):
        if quantize is not None or k is not None or rank is not None:
            raise ValueError(
                "a Transport instance already binds its hyperparameters; "
                "do not also pass quantize/k/rank")
        return transport
    kind = transport if transport is not None else quantize
    if transport is not None and quantize is not None \
            and transport != quantize:
        raise ValueError(
            f"conflicting transport={transport!r} and quantize={quantize!r} "
            "(quantize is the legacy alias; pass one)")
    hyper = {}
    if k is not None:
        hyper["k"] = k
    if rank is not None:
        hyper["rank"] = rank
    return make_transport(kind, **hyper)


# ------------------------------------------------------ built-in algorithms
@register("gd")
def _gd(alpha, num_workers, *, quantize=None, transport=None, k=None,
        rank=None, granularity="global", bank_dtype=None,
        backend="reference") -> ComposedOptimizer:
    """Classical distributed gradient descent (every worker transmits)."""
    return ComposedOptimizer(
        censor=NeverCensor(),
        transport=_resolve_transport(quantize, transport, k, rank),
        server=GradientDescent(alpha), num_workers=num_workers,
        granularity=granularity, bank_dtype=bank_dtype, backend=backend)


@register("hb")
def _hb(alpha, num_workers, *, beta=0.4, quantize=None, transport=None,
        k=None, rank=None, granularity="global", bank_dtype=None,
        backend="reference") -> ComposedOptimizer:
    """Classical heavy ball (eq. 2); paper default beta=0.4."""
    return ComposedOptimizer(
        censor=NeverCensor(),
        transport=_resolve_transport(quantize, transport, k, rank),
        server=HeavyBall(alpha, beta), num_workers=num_workers,
        granularity=granularity, bank_dtype=bank_dtype, backend=backend)


@register("lag")
def _lag(alpha, num_workers, *, eps1=None, eps1_scale=0.1, quantize=None,
         transport=None, k=None, rank=None, granularity="global",
         bank_dtype=None, backend="reference") -> ComposedOptimizer:
    """Censoring-based GD (LAG-WK, ref. [54]) with the shared eq. (8)."""
    if eps1 is None:
        eps1 = paper_eps1(alpha, num_workers, eps1_scale)
    return ComposedOptimizer(
        censor=Eq8Censor(eps1),
        transport=_resolve_transport(quantize, transport, k, rank),
        server=GradientDescent(alpha), num_workers=num_workers,
        granularity=granularity, bank_dtype=bank_dtype, backend=backend)


@register("chb")
def _chb(alpha, num_workers, *, beta=0.4, eps1=None, eps1_scale=0.1,
         quantize=None, transport=None, k=None, rank=None,
         granularity="global", bank_dtype=None,
         backend="reference") -> ComposedOptimizer:
    """The paper's algorithm with its Sec.-IV default constants."""
    if eps1 is None:
        eps1 = paper_eps1(alpha, num_workers, eps1_scale)
    return ComposedOptimizer(
        censor=Eq8Censor(eps1),
        transport=_resolve_transport(quantize, transport, k, rank),
        server=HeavyBall(alpha, beta), num_workers=num_workers,
        granularity=granularity, bank_dtype=bank_dtype, backend=backend)


@register("csgd")
def _csgd(alpha, num_workers, *, tau0=None, decay=0.99, eps1=None, seed=0,
          quantize=None, transport=None, k=None, rank=None,
          granularity="global", bank_dtype=None,
          backend="reference") -> ComposedOptimizer:
    """CSGD-style stochastically censored GD (Li et al., arXiv:1909.03631).

    Registered purely through composition — the payoff of the stage API:
    a new censor policy + the existing transport/server stages, zero edits
    inside any of them. ``tau0`` is the initial squared-norm threshold
    (``eps1`` is accepted as an alias so the sweep grid's eps axis sweeps
    it); ``tau0 = 0`` transmits unconditionally, degenerating to gd.
    """
    if tau0 is None:
        tau0 = eps1 if eps1 is not None else 0.0
    return ComposedOptimizer(
        censor=StochasticCensor(tau0=tau0, decay=decay, seed=seed),
        transport=_resolve_transport(quantize, transport, k, rank),
        server=GradientDescent(alpha),
        num_workers=num_workers, granularity=granularity,
        bank_dtype=bank_dtype, backend=backend)


# --------------------------------------------------------- spec round-trip
def _kind_of(stage, table: dict[str, type], what: str) -> str:
    for kind, cls in table.items():
        if type(stage) is cls:
            return kind
    raise ValueError(
        f"{what} stage {type(stage).__name__} is not in the spec "
        f"vocabulary {sorted(table)}; register it to make it serializable")


def _stage_spec(stage, table: dict[str, type], what: str) -> dict:
    spec = {"kind": _kind_of(stage, table, what)}
    for f in dataclasses.fields(stage):
        v = getattr(stage, f.name)
        if hasattr(v, "item"):          # 0-d device array -> Python scalar
            v = v.item()
        spec[f.name] = v
    return spec


def _stage_from_spec(spec: dict, table: dict[str, type], what: str):
    spec = dict(spec)
    kind = spec.pop("kind", None)
    if kind not in table:
        raise ValueError(f"unknown {what} kind {kind!r}; "
                         f"valid kinds: {sorted(table)}")
    return table[kind](**spec)


def to_spec(o: ComposedOptimizer) -> dict:
    """The full, JSON-serializable composition of an optimizer.

    Everything needed to rebuild ``o`` exactly — so a benchmark artifact
    carrying specs is reproducible without the code that built it.
    """
    return {
        "num_workers": o.num_workers,
        "granularity": o.granularity,
        "backend": o.backend,
        "bank_dtype": (None if o.bank_dtype is None
                       else jnp.dtype(o.bank_dtype).name),
        "censor": _stage_spec(o.censor, CENSOR_KINDS, "censor"),
        "transport": _stage_spec(o.transport, TRANSPORT_KINDS, "transport"),
        "server": _stage_spec(o.server, SERVER_KINDS, "server"),
    }


def from_spec(spec: dict) -> ComposedOptimizer:
    """Rebuild a ``ComposedOptimizer`` from a ``to_spec`` dict.

    ``from_spec(to_spec(o)) == o`` for every registered composition
    (pinned by tests/test_opt.py).
    """
    bank_dtype = spec.get("bank_dtype")
    return ComposedOptimizer(
        censor=_stage_from_spec(spec["censor"], CENSOR_KINDS, "censor"),
        transport=_stage_from_spec(spec["transport"], TRANSPORT_KINDS,
                                   "transport"),
        server=_stage_from_spec(spec["server"], SERVER_KINDS, "server"),
        num_workers=int(spec["num_workers"]),
        granularity=spec.get("granularity", "global"),
        bank_dtype=None if bank_dtype is None else jnp.dtype(bank_dtype),
        backend=spec.get("backend", "reference"),
    )

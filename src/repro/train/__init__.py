from . import trainer

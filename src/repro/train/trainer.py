"""CHB training loop at LLM scale.

Composes: model zoo (repro.models) + CHB optimizer family (repro.core) +
sharded data pipeline (repro.data.lm_data) + checkpointing. Algorithm
selectable per paper Sec. IV: gd | hb | lag | chb (+ optional int8 deltas).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import opt
from ..configs.base import ModelConfig
from ..core import distributed
from ..core.chb import FedOptConfig
from ..checkpoint import checkpoint as ckpt
from ..data import lm_data
from ..launch import sharding as shr
from ..launch.mesh import dp_axes
from ..models import model


@dataclasses.dataclass
class TrainConfig:
    algorithm: str = "chb"           # gd | hb | lag | chb
    strategy: str = "scan"           # scan | pod
    num_workers: int = 4
    alpha: float = 3e-2
    beta: float = 0.4
    eps1_scale: float = 0.1
    quantize: Optional[str] = None
    global_batch: int = 16
    seq_len: int = 256
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_path: str = "checkpoints/run"
    seed: int = 0
    remat: str = "none"
    moe_mode: str = "scan"
    # donate (params, state) into the jitted step so XLA reuses their
    # buffers across iterations (halves the parameter-state footprint).
    # Safe by construction: distributed.init_scan_state /
    # FedOptimizer.init copy prev_params up front, so the step never
    # reads a buffer it also overwrites. Set False to keep pre-step
    # (params, state) values alive for debugging.
    donate: bool = True


def _worker_count(tc: TrainConfig, mesh=None) -> int:
    return mesh.shape["pod"] if (tc.strategy == "pod" and mesh is not None) \
        else tc.num_workers


def make_optimizer(tc: TrainConfig, mesh=None) -> opt.ComposedOptimizer:
    """Resolve ``tc.algorithm`` through the ``repro.opt`` registry.

    Any registered name is accepted, but the distributed execution
    strategies (``core/distributed``) only realize eq.-(8)/uncensored
    policies with dense or int8 transport — anything else raises here
    rather than silently running uncensored.
    """
    m = _worker_count(tc, mesh)
    kw = {"quantize": tc.quantize}
    if tc.algorithm == "hb":
        kw["beta"] = tc.beta
    if tc.algorithm in ("lag", "chb"):
        kw["eps1_scale"] = tc.eps1_scale
    o = opt.make(tc.algorithm, tc.alpha, m, **kw)
    if not isinstance(o.censor, (opt.NeverCensor, opt.Eq8Censor)):
        raise NotImplementedError(
            f"algorithm {tc.algorithm!r} uses censor policy "
            f"{type(o.censor).__name__}, which the scan/pod training "
            "strategies do not realize (eq.-8 / uncensored only)")
    return o


def make_fed_config(tc: TrainConfig, mesh=None) -> FedOptConfig:
    """DEPRECATED: the legacy-config view of ``make_optimizer``."""
    o = make_optimizer(tc, mesh)
    return FedOptConfig(alpha=o.alpha, num_workers=o.num_workers,
                        beta=o.beta, eps1=o.eps1, quantize=o.quantize)


def train(cfg: ModelConfig, tc: TrainConfig, mesh=None, verbose=True):
    """Returns (params, state, history list of metric dicts)."""
    fcfg = make_optimizer(tc, mesh)
    m = fcfg.num_workers

    act = None
    if mesh is not None:
        # inside the pod-manual region only auto axes may appear in
        # sharding constraints
        axes = ("data",) if tc.strategy == "pod" else dp_axes(mesh)
        act = NamedSharding(mesh, P(axes))

    def loss_fn(params, batch):
        return model.train_loss(params, cfg, batch, moe_mode=tc.moe_mode,
                                remat=tc.remat, act_spec=act)[0]

    params = model.init_params(jax.random.PRNGKey(tc.seed), cfg)
    if mesh is not None:
        shardings = shr.params_shardings(
            jax.eval_shape(lambda: params), mesh,
            fsdp_axes=dp_axes(mesh) if tc.strategy == "scan" else ("data",),
            gather_safe=(tc.strategy == "pod"))
        params = jax.tree_util.tree_map(jax.device_put, params,
                                        shardings)

    if tc.strategy == "pod":
        assert mesh is not None and "pod" in mesh.axis_names
        state = distributed.init_pod_state(fcfg, params, mesh)
        step_fn = distributed.make_pod_step(fcfg, loss_fn, mesh)
        workers_for_data = None
    else:
        state = distributed.init_scan_state(fcfg, params)
        step_fn = distributed.make_scan_step(fcfg, loss_fn)
        workers_for_data = m

    step_fn = jax.jit(step_fn,
                      donate_argnums=(0, 1) if tc.donate else ())
    data = lm_data.batch_iterator(cfg, global_batch=tc.global_batch,
                                  seq_len=tc.seq_len,
                                  num_workers=workers_for_data, seed=tc.seed)
    history = []
    t0 = time.time()
    for step in range(tc.steps):
        batch = next(data)
        params, state, metrics = step_fn(params, state, batch)
        if step % tc.log_every == 0 or step == tc.steps - 1:
            rec = {k: float(v) for k, v in metrics.items()}
            rec.update(step=step,
                       comms=int(state.comm.total_uplinks),
                       comm_savings=float(state.comm.savings_vs_dense()),
                       wall_s=round(time.time() - t0, 1))
            history.append(rec)
            if verbose:
                print(f"step {step:5d} loss={rec['loss']:.4f} "
                      f"tx={rec['transmitted']:.0f}/{m} "
                      f"comms={rec['comms']} "
                      f"saved={rec['comm_savings']*100:.1f}%")
        if tc.ckpt_every and step and step % tc.ckpt_every == 0:
            ckpt.save(f"{tc.ckpt_path}_step{step}",
                      {"params": params},
                      metadata={"step": step, "arch": cfg.name})
    return params, state, history

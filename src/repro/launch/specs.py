"""Input specs (jax.ShapeDtypeStruct stand-ins) and step builders for every
(architecture x input-shape x mesh) dry-run case. No device allocation.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..core import distributed
from ..core.chb import FedOptConfig
from ..models import kvcache, model
from . import sharding as shr
from .mesh import dp_axes


# The four assigned input shapes.
INPUT_SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4_096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32_768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524_288, global_batch=1,
                        long=True),
}


class DryRunCase(NamedTuple):
    fn: Callable                     # jit-able step function
    args: tuple                      # ShapeDtypeStructs (sharding attached)
    donate: tuple                    # argnums to donate
    note: str


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, shardings_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def _stacked_shardings(params_shapes, mesh, leading: Optional[str],
                       fsdp_axes=None):
    """Shardings for a leading-M/pod-stacked copy of the params tree."""

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        base = shr.param_spec(pstr, leaf.shape[1:], mesh, fsdp_axes=fsdp_axes)
        return NamedSharding(mesh, P(leading, *base))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def _scalar_sh(mesh):
    return NamedSharding(mesh, P())


def fed_config(cfg: ModelConfig, mesh, strategy: str,
               num_workers: Optional[int] = None,
               quantize: Optional[str] = None) -> FedOptConfig:
    """CHB constants for LLM-scale training (paper Sec. IV style: beta=0.4,
    eps1=0.1/(alpha^2 M^2) with the LLM step size)."""
    if strategy == "pod":
        m = mesh.shape["pod"]
    else:
        m = num_workers or 4
    alpha = 1e-3
    return FedOptConfig(alpha=alpha, beta=0.4,
                        eps1=0.1 / (alpha ** 2 * m ** 2),
                        num_workers=m, quantize=quantize,
                        bank_dtype=jnp.bfloat16
                        if cfg.dtype == "bfloat16" else None)


def enc_shape(cfg: ModelConfig, batch: int):
    return (batch, cfg.num_frontend_tokens, cfg.d_frontend)


# ------------------------------------------------------------------ train
def build_train_case(cfg: ModelConfig, shape_name: str, mesh, *,
                     strategy: str = "scan",
                     num_workers: Optional[int] = None,
                     quantize: Optional[str] = None,
                     remat: str = "full",
                     moe_mode: str = "scan") -> DryRunCase:
    info = INPUT_SHAPES[shape_name]
    assert info["kind"] == "train"
    seq, gb = info["seq_len"], info["global_batch"]
    fcfg = fed_config(cfg, mesh, strategy, num_workers, quantize)
    m = fcfg.num_workers
    long_mode = bool(info.get("long")) and cfg.long_context_window is not None
    # inside the pod-manual region only auto axes may appear in constraints
    act_axes = ("data",) if strategy == "pod" else dp_axes(mesh)
    act = NamedSharding(mesh, P(act_axes))

    def loss_fn(params, batch):
        return model.train_loss(params, cfg, batch, moe_mode=moe_mode,
                                remat=remat, act_spec=act)[0]

    params_shapes = jax.eval_shape(
        functools.partial(model.init_params, cfg=cfg), jax.random.PRNGKey(0))
    fsdp = dp_axes(mesh) if strategy == "scan" else ("data",)
    p_sh = shr.params_shardings(params_shapes, mesh, fsdp_axes=fsdp,
                                gather_safe=(strategy == "pod"))
    params_sds = _tree_sds(params_shapes, p_sh)

    if strategy == "scan":
        state_shapes = jax.eval_shape(
            functools.partial(distributed.init_scan_state, fcfg),
            params_shapes)
        ghat_sh = _stacked_shardings(state_shapes.ghat, mesh, None,
                                     fsdp_axes=fsdp)
        step_fn = distributed.make_scan_step(fcfg, loss_fn)
        batch_shape = (m, gb // m, seq)
        bspec = P(None, dp_axes(mesh))
        enc_spec = P(None, dp_axes(mesh))
        enc_shp = (m, gb // m) + enc_shape(cfg, 1)[1:]
    else:
        state_shapes = jax.eval_shape(
            functools.partial(distributed.init_pod_state, fcfg, mesh=mesh),
            params_shapes)
        ghat_sh = _stacked_shardings(state_shapes.ghat, mesh, "pod",
                                     fsdp_axes=fsdp)
        step_fn = distributed.make_pod_step(fcfg, loss_fn, mesh)
        batch_shape = (gb, seq)
        bspec = P(("pod", "data"))
        enc_spec = P(("pod", "data"))
        enc_shp = (gb,) + enc_shape(cfg, 1)[1:]

    err_sh = ghat_sh if fcfg.quantize else ()
    nabla_sh = p_sh if strategy == "pod" else ()
    comm_sh = jax.tree_util.tree_map(lambda _: _scalar_sh(mesh),
                                     state_shapes.comm)
    state_sh = distributed.DistFedState(
        prev_params=p_sh, ghat=ghat_sh, nabla=nabla_sh, err=err_sh,
        comm=comm_sh, step=_scalar_sh(mesh))
    state_sds = _tree_sds(state_shapes, state_sh)

    batch = {"tokens": _sds(batch_shape, jnp.int32, mesh, bspec),
             "labels": _sds(batch_shape, jnp.int32, mesh, bspec)}
    if cfg.frontend:
        batch["enc_embeddings"] = _sds(enc_shp, cfg.jnp_dtype, mesh, enc_spec)

    def fn(params, state, batch):
        return step_fn(params, state, batch)

    return DryRunCase(fn=fn, args=(params_sds, state_sds, batch),
                      donate=(0, 1),
                      note=f"strategy={strategy} M={m} remat={remat} "
                           f"quant={quantize} long_mode={long_mode}")


# ---------------------------------------------------------------- prefill
def build_prefill_case(cfg: ModelConfig, shape_name: str, mesh, *,
                       moe_mode: str = "scan") -> DryRunCase:
    info = INPUT_SHAPES[shape_name]
    seq, gb = info["seq_len"], info["global_batch"]
    long_mode = bool(info.get("long")) and cfg.long_context_window is not None
    act = NamedSharding(mesh, P(dp_axes(mesh)))

    params_shapes = jax.eval_shape(
        functools.partial(model.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_sh = shr.params_shardings(params_shapes, mesh)
    params_sds = _tree_sds(params_shapes, p_sh)
    tokens = _sds((gb, seq), jnp.int32, mesh, shr.batch_spec(gb, mesh))
    args = [params_sds, tokens]

    if cfg.frontend:
        enc = _sds(enc_shape(cfg, gb), cfg.jnp_dtype, mesh,
                   shr.batch_spec(gb, mesh))
        args.append(enc)

        def fn(params, tokens, enc):
            return model.prefill(params, cfg, tokens, enc, cache_len=seq,
                                 long_mode=long_mode, moe_mode=moe_mode,
                                 act_spec=act)
    else:
        def fn(params, tokens):
            return model.prefill(params, cfg, tokens, cache_len=seq,
                                 long_mode=long_mode, moe_mode=moe_mode,
                                 act_spec=act)

    return DryRunCase(fn=fn, args=tuple(args), donate=(),
                      note=f"long_mode={long_mode}")


# ----------------------------------------------------------------- decode
def build_decode_case(cfg: ModelConfig, shape_name: str, mesh, *,
                      moe_mode: str = "scan") -> DryRunCase:
    info = INPUT_SHAPES[shape_name]
    seq, gb = info["seq_len"], info["global_batch"]
    long_mode = bool(info.get("long")) and cfg.long_context_window is not None

    params_shapes = jax.eval_shape(
        functools.partial(model.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_sh = shr.params_shardings(params_shapes, mesh)
    params_sds = _tree_sds(params_shapes, p_sh)

    cache_shapes = jax.eval_shape(
        functools.partial(kvcache.init_cache, cfg, gb, seq,
                          long_mode=long_mode))
    c_sh = shr.cache_shardings(cache_shapes, mesh, gb)
    cache_sds = _tree_sds(cache_shapes, c_sh)

    tokens = _sds((gb, 1), jnp.int32, mesh, shr.batch_spec(gb, mesh))
    pos = _sds((), jnp.int32, mesh, P())

    def fn(params, cache, tokens, pos):
        return model.serve_step(params, cfg, cache, tokens, pos,
                                long_mode=long_mode, moe_mode=moe_mode)

    return DryRunCase(fn=fn, args=(params_sds, cache_sds, tokens, pos),
                      donate=(1,),
                      note=f"cache_len={seq} long_mode={long_mode}")


def build_case(cfg: ModelConfig, shape_name: str, mesh, **kw) -> DryRunCase:
    kind = INPUT_SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train_case(cfg, shape_name, mesh, **kw)
    if kind == "prefill":
        kw.pop("strategy", None)
        return build_prefill_case(cfg, shape_name, mesh, **kw)
    kw.pop("strategy", None)
    return build_decode_case(cfg, shape_name, mesh, **kw)

"""Batched decode server driver: prefill a batch of prompts, then decode.

  PYTHONPATH=src python -m repro.launch.serve --arch chb-paper-lm-124m \
      --reduced --batch 4 --prompt-len 64 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get
from ..data.lm_data import MarkovLM
from ..models import model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chb-paper-lm-124m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    lm = MarkovLM(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(lm.sample(rng, args.batch,
                                    args.prompt_len)[:, :-1])
    prefix = cfg.num_frontend_tokens if cfg.frontend == "audio" else 0
    kwargs = {}
    if cfg.frontend:
        kwargs["enc_embeddings"] = jnp.asarray(
            0.3 * rng.standard_normal((args.batch, cfg.num_frontend_tokens,
                                       cfg.d_frontend)), cfg.jnp_dtype)
    cache_len = prefix + args.prompt_len + args.gen + 1
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, cfg, t, cache_len=cache_len, **kwargs)
    )(params, prompts)
    step = jax.jit(lambda p, c, t, pos: model.serve_step(p, cfg, c, t, pos))
    toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [toks]
    for i in range(args.gen - 1):
        logits, cache = step(params, cache,
                             toks, jnp.asarray(prefix + args.prompt_len + i))
        toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(toks)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print("generated:", np.asarray(gen)[:2])
    print(f"batch={args.batch} gen={args.gen} wall={dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

"""Parameter / activation / cache sharding rules (DESIGN.md §5).

Generic rule: for a weight leaf, the LAST dim is tensor-parallel ("model"),
the SECOND-TO-LAST is FSDP ("data", plus "pod" for the scan strategy on the
multi-pod mesh) — each applied only when divisible by the mesh axis size.
Leaves under "blocks" carry a leading superblock-stack axis that is never
sharded. 1-D leaves (norms, biases, dt_bias, ...) are replicated.

The pod strategy overrides fsdp_axes=("data",) so params stay replicated
across pods (the federated-worker boundary).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(dim: int, axes, mesh):
    if axes and dim % _axis_size(mesh, axes) == 0:
        if isinstance(axes, str):
            return axes
        # canonicalize 1-tuples to the bare name (newer jax does this inside
        # PartitionSpec; older jax keeps the tuple — normalize for both)
        return axes[0] if len(axes) == 1 else tuple(axes)
    return None


def param_spec(path: str, shape: tuple, mesh, *, fsdp_axes=None,
               tp_axis: str = "model", gather_safe: bool = False) -> P:
    """PartitionSpec for one parameter leaf identified by its tree path.

    gather_safe: keep gather-consumed tables (embeddings) single-axis
    sharded — XLA's SPMD partitioner CHECK-fails on a 2-axis-sharded gather
    operand inside a partial-manual (shard_map over "pod") region.
    """
    if fsdp_axes is None:
        fsdp_axes = dp_axes(mesh)
    fsdp_axes = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    stacked = "blocks" in path
    nd = len(shape)
    eff = nd - (1 if stacked else 0)       # dims after the stack axis
    spec = [None] * nd
    if eff >= 2:
        spec[-1] = _maybe(shape[-1], tp_axis, mesh)
        if not (gather_safe and "embed" in path):
            spec[-2] = _maybe(shape[-2], fsdp_axes, mesh)
    return P(*spec)


def params_shardings(params_shapes: Any, mesh, *, fsdp_axes=None,
                     gather_safe: bool = False) -> Any:
    """NamedSharding pytree matching a params (shape) pytree."""

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        return NamedSharding(mesh, param_spec(pstr, leaf.shape, mesh,
                                              fsdp_axes=fsdp_axes,
                                              gather_safe=gather_safe))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_spec(batch_size: int, mesh) -> P:
    """Leading-axis sharding for a (B, ...) batch."""
    dp = dp_axes(mesh)
    if batch_size % _axis_size(mesh, dp) == 0:
        return P(dp)
    if "data" in mesh.axis_names and batch_size % mesh.shape["data"] == 0:
        return P("data")
    return P()


def worker_batch_spec(mesh) -> P:
    """(M, B/M, L) worker-chunked batch for the scan strategy."""
    return P(None, dp_axes(mesh))


def cache_shardings(cache_shapes: Any, mesh, batch_size: int) -> Any:
    """Sharding for decode caches.

    kv leaves: (S, B, C, K, hd); ssm: (S, B, H, N, P); conv: (S, B, W-1, ch).
    Prefer batch over dp; fall back to sequence/head dims for B=1
    (long_500k) or non-divisible head counts.
    """
    dp = dp_axes(mesh)
    dp_ok = batch_size % _axis_size(mesh, dp) == 0

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        bdim = 1                       # (S, B, ...)
        if dp_ok:
            spec[bdim] = dp
        if "ssm" in pstr:              # (S,B,H,N,P)
            if shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
            if not dp_ok and shape[2] % _axis_size(mesh, dp + ("model",)) == 0:
                spec[2] = dp + ("model",)
        elif "conv" in pstr:           # (S,B,W-1,ch)
            if shape[3] % mesh.shape["model"] == 0:
                spec[3] = "model"
        else:                          # kv: (S,B,C,K,hd)
            if shape[3] % mesh.shape["model"] == 0:
                spec[3] = "model"      # heads over tensor axis
                if not dp_ok and shape[2] % _axis_size(mesh, dp) == 0:
                    spec[2] = dp       # sequence over dp when B=1
            elif shape[2] % _axis_size(mesh, dp + ("model",)) == 0 and not dp_ok:
                spec[2] = dp + ("model",)
            elif shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"      # sequence over tensor axis
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def activation_spec(mesh) -> P:
    """(B, L, D) activations: batch over dp."""
    return P(dp_axes(mesh))


# ----------------------------------------------------------- client axis
# Helpers for the sharded federated runtime (repro.fed.mesh): client banks
# (momentum ghat, EF residual, censor state, per-client metrics) carry a
# leading client axis sharded over the 1-D ("clients",) mesh from
# launch.mesh.make_client_mesh. The round programs run per shard (one jit
# per device over its contiguous client block); these helpers move data
# between the per-device views and the global mesh-sharded arrays without
# any resharding collectives.

def client_shard_sizes(num_clients: int, mesh, axis: str = "clients") -> int:
    """Per-shard client count, validating divisibility loudly.

    The K-invariance anchor (docs/fed_scaling.md) relies on every shard
    holding a contiguous, equally-sized client block, so ``num_clients``
    must divide evenly; a ragged split would silently change which clients
    share a vmapped program and is refused here.
    """
    k = int(mesh.shape[axis])
    if num_clients % k != 0:
        raise ValueError(
            f"num_clients={num_clients} is not divisible by the "
            f"'{axis}' mesh axis size {k}; pad the population or pick a "
            "shard count that divides it (see docs/fed_scaling.md)")
    return num_clients // k


def client_spec(ndim: int, axis: str = "clients") -> P:
    """Leading-axis client sharding for an ``(M, ...)`` bank leaf."""
    return P(axis, *([None] * (ndim - 1)))


def client_shardings(tree: Any, mesh, axis: str = "clients") -> Any:
    """NamedSharding pytree: leading client axis sharded, rest replicated."""
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, client_spec(x.ndim, axis)), tree)


def stack_shards(pieces: list, mesh, axis: str = "clients") -> Any:
    """Assemble per-shard outputs into one mesh-sharded global pytree.

    ``pieces[i]`` is the pytree produced on ``mesh`` device ``i`` (each
    leaf a single-device array, every piece the same shapes/dtypes); the
    result's leaves are global ``(K*local, ...)`` arrays sharded
    ``P(axis)`` with NO data movement — each piece stays on the device
    that computed it (the fold collective then runs over the mesh axis).
    """
    devices = list(mesh.devices.flat)
    if len(pieces) != len(devices):
        raise ValueError(
            f"stack_shards got {len(pieces)} pieces for a {len(devices)}"
            f"-device '{axis}' mesh")

    def one(*leaves):
        shape = (len(devices) * leaves[0].shape[0],) + leaves[0].shape[1:]
        sharding = NamedSharding(mesh, client_spec(leaves[0].ndim, axis))
        return jax.make_array_from_single_device_arrays(
            shape, sharding, [jax.device_put(leaf, dev)
                              for leaf, dev in zip(leaves, devices)])

    return jax.tree_util.tree_map(one, *pieces)


def per_device_views(tree: Any, mesh) -> list:
    """Split a mesh-sharded (or replicated) pytree into per-device pytrees.

    Inverse of ``stack_shards`` for sharded leaves; for replicated leaves
    every device yields the full array. ``result[i]`` holds the
    addressable shard living on mesh device ``i`` — the zero-copy handle
    the per-shard jitted programs consume.
    """
    devices = list(mesh.devices.flat)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    per_leaf = []
    for leaf in leaves:
        by_dev = {sh.device: sh.data for sh in leaf.addressable_shards}
        per_leaf.append([by_dev[d] for d in devices])
    return [treedef.unflatten([col[i] for col in per_leaf])
            for i in range(len(devices))]


def replicated_sharding(mesh) -> NamedSharding:
    """Fully-replicated NamedSharding (server state: params, theta_prev)."""
    return NamedSharding(mesh, P())

"""Production meshes. Functions, not module constants — importing this module
never touches jax device state (dryrun.py must set XLA_FLAGS first).

Audited against the pinned jax (0.4.x, see requirements-dev.txt): the old
``axis_types=(AxisType.Auto, ...)`` compatibility branch was dead code
(``jax.sharding.AxisType`` does not exist on 0.4.x, and 0.4.x meshes are
implicitly Auto), so ``make_auto_mesh`` now calls ``jax.make_mesh``
directly. Every constructor checks the requested shape against the real
device count and raises with the fix spelled out — a mesh request that
cannot be satisfied must never silently degrade to fewer devices.
"""
from __future__ import annotations

import math

import jax


def _require_devices(needed: int, what: str) -> None:
    """Loud failure when a mesh wants more devices than the process has.

    ``jax.make_mesh`` also errors, but with a generic message; this one
    names the XLA_FLAGS escape hatch used by every multi-device test/bench
    in this repo (they run in subprocesses — see tests/test_distributed.py).
    """
    have = jax.device_count()
    if needed > have:
        raise ValueError(
            f"{what} needs {needed} devices but only {have} are visible; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{needed} (in a fresh process, before jax initializes) or "
            "request a smaller mesh")


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with a loud device-count check (axes stay Auto —
    the 0.4.x default; there is no axis_types argument to pass)."""
    _require_devices(math.prod(shape), f"mesh {tuple(shape)}x{tuple(axes)}")
    return jax.make_mesh(shape, axes)


def make_client_mesh(num_shards: int):
    """1-D ``("clients",)`` mesh for the sharded federated runtime.

    The client axis of every bank pytree (``launch/sharding.py``
    ``client_*`` helpers) and the ``repro.fed.mesh`` round programs shard
    over this mesh. ``num_shards`` must not exceed the visible device
    count — requesting more errors loudly instead of degrading.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    _require_devices(num_shards, f"client mesh ({num_shards} shards)")
    return jax.make_mesh((num_shards,), ("clients",))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1, *, pods: int = 1):
    """Mesh over whatever devices exist (CPU tests / small runs)."""
    n = jax.device_count()
    if n % (model_parallel * pods) != 0:
        raise ValueError(
            f"device count {n} is not divisible by model_parallel="
            f"{model_parallel} * pods={pods}; adjust the factors or the "
            "forced host device count")
    if pods > 1:
        shape = (pods, n // (model_parallel * pods), model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (n // model_parallel, model_parallel)
        axes = ("data", "model")
    return make_auto_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Axes used for batch/FSDP sharding (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)

"""Production meshes. Functions, not module constants — importing this module
never touches jax device state (dryrun.py must set XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_auto_mesh(shape, axes):
    """jax.make_mesh with Auto axis types across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) appeared after 0.4.x;
    older jax meshes are implicitly Auto, so passing nothing is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1, *, pods: int = 1):
    """Mesh over whatever devices exist (CPU tests / small runs)."""
    n = jax.device_count()
    assert n % (model_parallel * pods) == 0, (n, model_parallel, pods)
    if pods > 1:
        shape = (pods, n // (model_parallel * pods), model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (n // model_parallel, model_parallel)
        axes = ("data", "model")
    return make_auto_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Axes used for batch/FSDP sharding (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)

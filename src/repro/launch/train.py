"""CLI training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch chb-paper-lm-124m \
      --algorithm chb --steps 200 --global-batch 16 --seq-len 256
"""
import argparse

from ..configs import ARCHS, get
from ..train.trainer import TrainConfig, train
from .mesh import make_local_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chb-paper-lm-124m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny smoke variant of the arch")
    ap.add_argument("--algorithm", default="chb",
                    choices=["gd", "hb", "lag", "chb"])
    ap.add_argument("--strategy", default="scan", choices=["scan", "pod"])
    ap.add_argument("--num-workers", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=3e-2)
    ap.add_argument("--beta", type=float, default=0.4)
    ap.add_argument("--eps1-scale", type=float, default=0.1)
    ap.add_argument("--quantize", default=None, choices=["int8"])
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--use-mesh", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.use_mesh or args.strategy == "pod":
        mesh = make_local_mesh(args.model_parallel, pods=args.pods
                               if args.strategy == "pod" else 1)
    tc = TrainConfig(algorithm=args.algorithm, strategy=args.strategy,
                     num_workers=args.num_workers, alpha=args.alpha,
                     beta=args.beta, eps1_scale=args.eps1_scale,
                     quantize=args.quantize, global_batch=args.global_batch,
                     seq_len=args.seq_len, steps=args.steps,
                     ckpt_every=args.ckpt_every)
    ctx = mesh if mesh is not None else _null()
    with ctx:
        train(cfg, tc, mesh=mesh)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()

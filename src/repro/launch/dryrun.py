import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh): .lower().compile() the step
function against ShapeDtypeStruct inputs (no allocation), print/record
memory_analysis() + cost_analysis(), and parse the compiled HLO for
collective traffic (the §Roofline collective term).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out out.json
"""
import argparse
import json
import re
import time
import traceback

import jax

from ..configs import ARCHS, ASSIGNED
from . import hlo_analysis
from .mesh import make_production_mesh
from .specs import INPUT_SHAPES, build_case

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}

_COLL_RE = re.compile(
    r"=\s*(?P<ty>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective traffic by op kind, from post-SPMD local shapes.

    Ring-traffic weights: all-reduce 2x result, all-gather 1x result,
    reduce-scatter ~1x operand (= k x result; approximated by the matching
    operand shape when present, else result), all-to-all / permute 1x.
    """
    out = {k: {"count": 0, "bytes": 0} for k in
           ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")}
    for m in _COLL_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue  # paired with -start
        op = m.group("op")
        b = _shape_bytes(m.group("ty"))
        w = 2 if op == "all-reduce" else 1
        out[op]["count"] += 1
        out[op]["bytes"] += w * b
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def run_case(arch: str, shape: str, multi_pod: bool, strategy: str = None,
             opts=(), **case_kw) -> dict:
    from ..models import tuning
    for o in opts:
        tuning.set_flags(**{o: True})
    cfg = ARCHS[arch]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if opts:
        tuning.set_mesh(mesh)
    if strategy is None:
        # NOTE: the shard_map("pod") strategy trips an XLA SPMD-partitioner
        # CHECK (spmd_partitioner_util.cc:504) when a while loop coexists
        # with model-axis sharding at this mesh factorization (512 host
        # devices). Minimal repro preserved in launch/hlo_analysis.py's
        # module docstring. The scan strategy
        # also shards the pod axis (batch + stale-gradient bank FSDP over
        # ("pod","data")), so the multi-pod dry-run uses it; the pod
        # strategy is exercised on small meshes in tests/test_distributed.py.
        strategy = "scan"
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "opts": list(opts),
           "strategy": strategy if INPUT_SHAPES[shape]["kind"] == "train"
           else "-"}
    t0 = time.time()
    try:
        case = build_case(cfg, shape, mesh, strategy=strategy, **case_kw)
        with mesh:
            jitted = jax.jit(case.fn, donate_argnums=case.donate)
            lowered = jitted.lower(*case.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        ana = hlo_analysis.analyze(hlo)
        rec.update(
            ok=True, note=case.note,
            lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            # loop-aware per-device totals (launch/hlo_analysis.py)
            flops=ana["flops"],
            hbm_bytes=ana["hbm_bytes"],
            collective_bytes=ana["collective_bytes"],
            collectives=ana["collectives"],
            # raw XLA numbers (loop bodies counted once) for reference
            xla_flops=cost.get("flops", 0.0),
            xla_bytes_accessed=cost.get("bytes accessed", 0.0),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
            ),
            hlo_bytes=len(hlo),
        )
        print(f"[OK] {arch} {shape} {rec['mesh']} "
              f"compile={rec['compile_s']}s flops/dev={rec['flops']:.3e} "
              f"hbm/dev={rec['hbm_bytes']:.3e}B "
              f"coll/dev={rec['collective_bytes']:.3e}B "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[FAIL] {arch} {shape} {rec['mesh']}: {rec['error']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--strategy", default=None, choices=["scan", "pod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--quantize", default=None, choices=["int8"])
    ap.add_argument("--remat", default="full")
    ap.add_argument("--num-workers", type=int, default=None)
    ap.add_argument("--moe-mode", default=None, choices=["scan","grouped"])
    ap.add_argument("--opt", action="append", default=[],
                    help="enable a tuning flag (repeatable); see "
                         "repro/models/tuning.py")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    kw = {}
    if args.quantize:
        kw["quantize"] = args.quantize
    if args.moe_mode:
        kw["moe_mode"] = args.moe_mode
    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                skw = dict(kw)
                if INPUT_SHAPES[shape]["kind"] == "train":
                    skw["remat"] = args.remat
                    if args.num_workers:
                        skw["num_workers"] = args.num_workers
                records.append(run_case(arch, shape, mp,
                                        strategy=args.strategy,
                                        opts=tuple(args.opt), **skw))
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(records, f, indent=1)
    n_ok = sum(r["ok"] for r in records)
    print(f"\n{n_ok}/{len(records)} cases compiled successfully")
    if n_ok < len(records):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

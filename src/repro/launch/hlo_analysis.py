"""Trip-count-aware analysis of compiled (post-SPMD, scheduled) HLO text.

Why this exists: XLA's HloCostAnalysis (what compiled.cost_analysis()
reports) counts a while-loop body ONCE, but our programs put all heavy
compute inside lax.scan loops (workers x superblocks x flash blocks x MoE
experts). This module parses the HLO text, reconstructs the call graph,
resolves canonical while-loop trip counts from their condition computations,
and reports loop-aware totals (per device):

  * flops            — 2 * prod(result) * prod(contracted) per dot op
  * hbm_bytes        — operand+result bytes of top-level (unfused) ops in
                       control computations (entry / while bodies)
  * collectives      — per-kind count and ring-traffic bytes
                       (all-reduce 2x, others 1x result bytes)

All values are per-device: post-partitioning HLO shapes are local shapes.

Known SPMD-partitioner CHECK-failure (why ``core.distributed`` refuses
partial-manual shard_map on jax 0.4.x, and why the multi-pod dry-run uses
the scan strategy — see launch/dryrun.py):

    F spmd_partitioner_util.cc:504 Check failed:
      partition_group_list.num_replica_groups() *
      partition_group_list.num_devices_per_group()
      == device_groups.num_devices_per_group()

Trigger: a lax.scan (while loop) whose body touches a MODEL-axis-sharded
array, inside a shard_map that is partial-manual over a "pod" axis, on a
(2,16,16) host-device mesh (CPU PJRT). The same program compiles fine on
a (2,2,2) mesh, without the while loop, and with data-axis-only sharding;
a pure-pjit vmap-over-pods variant crashes identically, so it is not
specific to shard_map. Minimal program (run with
``XLA_FLAGS=--xla_force_host_platform_device_count=512``)::

    mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
    W = device_put(ones((256, 256)), NamedSharding(mesh, P(None, "model")))
    x = device_put(ones((64, 256)), NamedSharding(mesh, P(("pod", "data"))))
    def inner(w, xx):
        h, _ = jax.lax.scan(lambda h, _: (jnp.tanh(h @ w), None),
                            xx, None, length=3)
        return jax.lax.psum(jnp.mean(h), "pod")
    f = shard_map(inner, mesh=mesh, in_specs=(P(), P("pod")),
                  out_specs=P(), axis_names={"pod"})
    jax.jit(f)(W, x)  # aborts in the SPMD partitioner
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "ragged-all-to-all")
_SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "after-all", "iota"}


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str                       # operands + attributes (raw tail)
    is_root: bool = False

    @property
    def operands(self) -> List[str]:
        # names before the first "),"-ish boundary; conservative: all %refs
        # in the call-arg segment (before any attr with '=')
        seg = self.rest.split("),")[0]
        return _OPERAND_RE.findall(seg)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_entry: bool = False

    def symbol_table(self) -> Dict[str, str]:
        return {i.name: i.result_type for i in self.instrs}

    def param_access_bytes(self) -> List[Optional[int]]:
        """For each parameter: bytes actually touched per call if the param
        is consumed ONLY through windowed reads (dynamic-slice / gather),
        else None (meaning: count the full operand).

        Used to avoid charging a scan body with its whole stacked-weights
        array when it dynamic-slices one layer per iteration."""
        params: Dict[int, str] = {}
        for i in self.instrs:
            if i.opcode == "parameter":
                m = re.match(r"(\d+)", i.rest)
                if m:
                    params[int(m.group(1))] = i.name
        users: Dict[str, List[Instr]] = {n: [] for n in params.values()}
        for i in self.instrs:
            for op in i.operands:
                if op in users:
                    users[op].append(i)
        out: List[Optional[int]] = []
        for idx in range(len(params)):
            name = params.get(idx)
            touched = 0
            windowed = bool(users.get(name))
            for u in users.get(name, []):
                if u.opcode in ("dynamic-slice", "gather") and \
                        u.operands and u.operands[0] == name:
                    touched += shape_bytes(u.result_type)
                elif u.opcode == "dynamic-update-slice" and \
                        len(u.operands) > 1 and u.operands[0] == name:
                    # in-place window write: read+write of the update only
                    touched += 0  # update operand charged separately
                else:
                    windowed = False
                    break
            out.append(touched if windowed else None)
        return out


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and ("->" in line):
                cur = Computation(name=m.group(1), instrs=[],
                                  is_entry=line.strip().startswith("ENTRY"))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(name=m.group(1), result_type=m.group(2),
                                    opcode=m.group(3), rest=m.group(4),
                                    is_root=line.lstrip().startswith("ROOT")))
    return comps


def _trip_count(cond: Computation) -> int:
    """Canonical jax scan loops compare the induction var against a constant
    upper bound; take the max scalar-int constant in the condition."""
    best = 1
    for i in cond.instrs:
        if i.opcode == "constant" and i.result_type.strip() in (
                "s32[]", "u32[]", "s64[]", "u64[]"):
            m = re.match(r"(\d+)", i.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(instr: Instr, symbols: Dict[str, str]) -> float:
    out = shape_dims(instr.result_type)
    ops = instr.operands
    if not ops:
        return 0.0
    lhs_type = symbols.get(ops[0], "")
    lhs = shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    contracted = 1
    if m and lhs:
        for d in m.group(1).split(","):
            if d:
                contracted *= lhs[int(d)]
    return 2.0 * math.prod(out or [1]) * contracted


def _instr_hbm_bytes(i: Instr, symbols: Dict[str, str],
                     comps: Dict[str, "Computation"]) -> int:
    """HBM traffic of one top-level instruction: result + operands, with
    windowed reads (dynamic-slice/gather, incl. inside fusions) charged at
    slice size instead of full-buffer size."""
    ops = i.operands
    if i.opcode == "dynamic-slice":
        return 2 * shape_bytes(i.result_type)
    if i.opcode == "gather":
        idx = shape_bytes(symbols.get(ops[1], "")) if len(ops) > 1 else 0
        return 2 * shape_bytes(i.result_type) + idx
    if i.opcode == "dynamic-update-slice":
        upd = shape_bytes(symbols.get(ops[1], "")) if len(ops) > 1 else 0
        return 2 * upd
    if i.opcode == "scatter":
        upd = shape_bytes(symbols.get(ops[2], "")) if len(ops) > 2 else 0
        idx = shape_bytes(symbols.get(ops[1], "")) if len(ops) > 1 else 0
        return 2 * upd + idx
    b = shape_bytes(i.result_type)
    if i.opcode == "fusion":
        cm = re.search(r"calls=%([\w.\-]+)", i.rest)
        if cm and cm.group(1) in comps:
            callee = comps[cm.group(1)]
            # fusion rooted at dynamic-update-slice writes only the window
            root = next((x for x in callee.instrs if x.is_root), None)
            if root is not None and root.opcode == "dynamic-update-slice":
                upd = root.operands[1] if len(root.operands) > 1 else None
                st = callee.symbol_table()
                b = 2 * shape_bytes(st.get(upd, "")) if upd else b
            access = callee.param_access_bytes()
            for pos, op in enumerate(ops):
                win = access[pos] if pos < len(access) else None
                b += win if win is not None else \
                    shape_bytes(symbols.get(op, ""))
            return b
    for op in ops:
        b += shape_bytes(symbols.get(op, ""))
    return b


def analyze(text: str) -> dict:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {}}

    # Call-graph edges: (caller, callee, trip_multiplier, keeps_control).
    # Multipliers are ADDITIVE over call sites and multiplicative down the
    # graph; computed in topological order below.
    edges: Dict[str, List[tuple]] = {c: [] for c in comps}
    for comp in comps.values():
        for i in comp.instrs:
            if i.opcode == "while":
                bm = _BODY_RE.search(i.rest)
                cm = _COND_RE.search(i.rest)
                trips = _trip_count(comps[cm.group(1)]) if cm and \
                    cm.group(1) in comps else 1
                if bm and bm.group(1) in comps:
                    edges[comp.name].append((bm.group(1), trips, True))
                if cm and cm.group(1) in comps:
                    edges[comp.name].append((cm.group(1), trips, False))
            else:
                keeps = i.opcode in ("call", "conditional", "while")
                for callee in _CALLS_RE.findall(i.rest):
                    if callee in comps:
                        edges[comp.name].append((callee, 1, keeps))

    # topological order via DFS from entry
    order: List[str] = []
    seen: set = set()

    def topo(name: str):
        if name in seen:
            return
        seen.add(name)
        for callee, _, _ in edges[name]:
            topo(callee)
        order.append(name)

    topo(entry.name)
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    control: set = {entry.name}
    mult[entry.name] = 1.0
    for name in reversed(order):
        for callee, trips, keeps in edges[name]:
            mult[callee] += mult[name] * trips
            if name in control and keeps:
                control.add(callee)

    flops = 0.0
    hbm = 0.0
    coll = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVE_OPS}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symbols = comp.symbol_table()
        for i in comp.instrs:
            if i.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(i, symbols)
            base = i.opcode.rstrip("-start").replace("-start", "")
            for k in COLLECTIVE_OPS:
                if i.opcode in (k, k + "-start"):
                    b = shape_bytes(i.result_type)
                    w = 2 if k == "all-reduce" else 1
                    coll[k]["count"] += m
                    coll[k]["bytes"] += m * w * b
            if cname in control and i.opcode not in _SKIP_BYTES_OPS \
                    and not i.opcode.endswith("-done") \
                    and i.opcode != "while":
                b = _instr_hbm_bytes(i, symbols, comps)
                hbm += m * b
    coll_total = sum(v["bytes"] for v in coll.values())
    return {"flops": flops, "hbm_bytes": hbm,
            "collectives": coll, "collective_bytes": coll_total}

from . import mesh, sharding, specs

"""The CHB-skip-transmission condition (paper eq. 8) and parameter feasibility.

A worker m transmits its gradient delta at iteration k iff

    || grad_m(theta^k) - grad_m(theta_hat_m^{k-1}) ||^2  >  eps1 * || theta^k - theta^{k-1} ||^2

Both sides are *global* squared l2 norms over the whole parameter pytree,
matching the paper's single-vector view of theta.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .util import tree_sqnorm


def _eps_cast(eps1, step_sqnorm: jax.Array):
    """Pin eps1 to the norms' dtype (f32) before the eq.-(8) product.

    A static Python-float eps1 weakly promotes to the f32 of the norms, so
    the test runs in f32; a traced eps1 arrives as a strong f64 scalar
    under x64 and would silently promote the product (and the decision) to
    f64 — a different censor boundary. Casting first makes the traced and
    static paths decide identically, which the sweep engine's bit-exactness
    contract depends on.
    """
    return jnp.asarray(eps1).astype(step_sqnorm.dtype)


def skip_condition(delta_sqnorm: jax.Array, step_sqnorm: jax.Array,
                   eps1) -> jax.Array:
    """True where the worker is CENSORED (does not transmit). Eq. (8).

    ``eps1`` may be a Python float or a traced scalar; either way the test
    is evaluated in the norms' (f32) precision.
    """
    return delta_sqnorm <= _eps_cast(eps1, step_sqnorm) * step_sqnorm


def transmit_mask(delta_sqnorm: jax.Array, step_sqnorm: jax.Array,
                  eps1) -> jax.Array:
    """1.0 where the worker transmits, 0.0 where censored. Shape (M,).

    ``eps1`` may be a Python float or a traced scalar; either way the test
    is evaluated in the norms' (f32) precision.
    """
    return (delta_sqnorm > _eps_cast(eps1, step_sqnorm)
            * step_sqnorm).astype(jnp.float32)


def delta_sqnorms(delta_stacked) -> jax.Array:
    """Per-worker global squared norms of a leading-M stacked delta pytree."""
    leaves = jax.tree_util.tree_leaves(delta_stacked)
    m = leaves[0].shape[0]
    acc = jnp.zeros((m,), jnp.float32)
    for x in leaves:
        acc = acc + jnp.sum(
            jnp.square(x.astype(jnp.float32)).reshape(m, -1), axis=1)
    return acc


def paper_eps1(alpha: float, num_workers: int, scale: float = 0.1) -> float:
    """The paper's practical choice eps1 = scale/(alpha^2 M^2) (Sec. IV)."""
    return scale / (alpha ** 2 * num_workers ** 2)


@dataclasses.dataclass(frozen=True)
class FeasibleParams:
    """A parameter triple inside the theoretical region (10)-(12)."""
    alpha: float
    beta: float
    eps1: float
    rate: float  # guaranteed contraction factor c(alpha, beta, eps1)


def theoretical_params(L: float, mu: float, num_workers: int,
                       delta: float = 0.5, rho3: float = 1.0) -> FeasibleParams:
    """Corner of the feasible region from Appendix C eq. (55).

    With rho3=1, alpha=(1-delta)/L, eta1=(1-alpha L)/(2 alpha):
      beta  = 0.5 * sqrt((1-alpha L)(1-alpha mu))
      eps1  = (1-alpha L)(1-alpha mu) / (4 alpha^2 M^2)
    giving c = alpha*mu = (1-delta)/(L/mu) — the same order as classical HB.
    """
    if not 0.0 < delta < 1.0:
        raise ValueError("delta must be in (0,1)")
    alpha = (1.0 - delta) / L
    al = alpha * L
    am = alpha * mu
    beta = 0.5 * math.sqrt((1.0 - al) * (1.0 - am))
    eps1 = (1.0 - al) * (1.0 - am) / (4.0 * alpha ** 2 * num_workers ** 2)
    return FeasibleParams(alpha=alpha, beta=beta, eps1=eps1, rate=am)


def check_feasible(alpha: float, beta: float, eps1: float, L: float,
                   num_workers: int, rho3: float = 1.0) -> bool:
    """Check the simplified condition (14)/(43) with eta1=(1-alpha L)/(2 alpha).

    alpha <= 1/L,  beta^2 (1+1/rho3) <= 1 - alpha L,
    eps1 <= ((1-alpha L) - beta^2 (1+1/rho3)) / (alpha^2 (1+rho3) M^2)
    (conservatively using |M_c^k| <= M).
    """
    if alpha > 1.0 / L:
        return False
    slack = (1.0 - alpha * L) - beta ** 2 * (1.0 + 1.0 / rho3)
    if slack < 0:
        return False
    bound = slack / (alpha ** 2 * (1.0 + rho3) * num_workers ** 2)
    return eps1 <= bound + 1e-12


def step_sqnorm(params, prev_params) -> jax.Array:
    """|| theta^k - theta^{k-1} ||^2 over the whole pytree."""
    diff = jax.tree_util.tree_map(jnp.subtract, params, prev_params)
    return tree_sqnorm(diff)

"""CHB core: the paper's contribution as a composable JAX module."""
from . import accounting, baselines, censoring, chb, quantize, simulator, util
from .chb import FedOptConfig, FedOptState, StepInfo, init, step
from .baselines import ALGORITHMS, chb as make_chb, gd as make_gd, hb as make_hb, lag as make_lag

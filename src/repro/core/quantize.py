"""Beyond-paper: int8 quantization of transmitted deltas with error feedback.

The paper (Sec. V) notes CHB "can potentially be applied along with other
complementary techniques such as quantization" — this module does exactly
that. Each worker keeps a local error accumulator e_m. When it transmits,
the payload is q = Q(delta + e_m) and the residual e_m <- delta + e_m - q is
kept locally. The server (and the worker's own stale-gradient copy) advance
by q, so worker and server views never diverge. Error feedback guarantees the
quantization noise telescopes instead of accumulating.

Quantizer: symmetric per-tensor int8 with a float32 scale. Payload size is
1 byte/element + 4 bytes/tensor, i.e. ~2x smaller than bf16 and ~4x smaller
than f32 uplinks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q_int8, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    """Q(x) as the value the receiver reconstructs (same dtype as x)."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.dtype)


def tree_quantize_roundtrip(tree):
    """Per-leaf int8 round-trip of a delta pytree."""
    return jax.tree_util.tree_map(quantize_roundtrip, tree)


def tree_quantize_roundtrip_per_worker(tree):
    """Int8 round-trip of a leading-M stacked delta pytree, one scale per
    worker slice — each worker quantizes its *own* delta, as it must in a
    real deployment (a shared cross-worker scale is unrealizable)."""
    return jax.tree_util.tree_map(
        lambda x: jax.vmap(quantize_roundtrip)(x), tree)


def payload_bytes_int8(tree) -> int:
    """Uplink bytes for one quantized transmission of this pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(x.size for x in leaves) + 4 * len(leaves)


def payload_bytes_dense(tree) -> int:
    """Uplink bytes for one unquantized transmission."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))

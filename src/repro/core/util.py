"""Pytree utilities shared by the CHB core."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_sqnorm(tree) -> jax.Array:
    """Global squared l2 norm over every leaf of a pytree (scalar)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_stack_zeros(tree, m: int):
    """Zeros pytree with an extra leading axis of size ``m``."""
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((m,) + x.shape, x.dtype), tree
    )


def tree_count_params(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_worker_slice(tree, m):
    """Select worker ``m`` from a pytree whose leaves have leading axis M."""
    return jax.tree_util.tree_map(lambda x: x[m], tree)


def tree_sum_leading(tree):
    """Sum each leaf over its leading (worker) axis."""
    return jax.tree_util.tree_map(lambda x: jnp.sum(x, axis=0), tree)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)

"""Federated M-worker simulator running exact Algorithm 1 semantics.

This is the harness behind every paper-reproduction experiment: it owns no
model-specific logic, only (a) per-worker gradient evaluation via vmap and
(b) the CHB-family server update. Everything is jitted with a lax.scan over
iterations, so thousands of iterations of the paper's small problems run in
milliseconds on CPU.

``trajectory`` is the pure scan (no jit), reused by ``repro.sweep`` to run
whole configuration grids as one compiled program; ``run`` is the one-point
convenience wrapper that jits it. For grids of more than a couple of points,
prefer ``repro.sweep.run_sweep`` — it compiles once for the entire grid
instead of once per point.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

if TYPE_CHECKING:   # annotations only; runtime opt imports are lazy so the
    # core <-> opt import graph stays acyclic
    from ..opt.api import FedOptimizer, OptState
    from .chb import FedOptConfig

    OptLike = Union["FedOptimizer", "FedOptConfig"]
else:
    OptLike = Any


class FedTask(NamedTuple):
    """A distributed optimization problem f(theta) = sum_m f_m(theta).

    worker_data leaves are stacked with leading axis M; grad_fn/loss_fn
    operate on ONE worker's slice. The simulator vmaps them.
    """
    init_params: Any
    grad_fn: Callable[[Any, Any], Any]   # (params, data_m) -> grad/subgrad
    loss_fn: Callable[[Any, Any], jax.Array]  # (params, data_m) -> f_m
    worker_data: Any
    name: str = "task"


class History(NamedTuple):
    """Per-iteration trajectory of one Algorithm-1 run.

    Attributes:
      objective: (K,) f(theta^k) recorded *before* iteration k's update.
      comm_cum: (K,) cumulative uplink transmissions after iteration k
        (sum over workers of ``mask`` up to and including k).
      mask: (K, M) per-iteration transmit indicators (1 = worker uploaded).
        Under ``granularity="per_tensor"`` a 1 means "any tensor shipped".
      agg_grad_sqnorm: (K,) ||sum_m ghat_m^k||^2 — the paper's nonconvex
        progress metric, measured on the post-update bank.
      final_params: theta^K pytree.
      final_state: the full ``repro.opt.OptState`` after iteration K,
        including the stale-gradient bank and the precision-safe
        ``CommStats`` (exact uplink/downlink counts and payload bytes).
      metrics: ``()`` unless the run collected metrics
        (``collect_metrics=True``), else a ``repro.obs`` MetricBag of
        stacked per-iteration series — ``{name: (K,) array}`` (censor
        rate, exact uplink bytes, bank/gradient norms, stage-hook
        observables). Collection is read-only: every other field is
        bit-identical to a metrics-off run.
    """
    objective: jax.Array
    comm_cum: jax.Array
    mask: jax.Array
    agg_grad_sqnorm: jax.Array
    final_params: Any
    final_state: "OptState"
    metrics: Any = ()


def global_loss(task: FedTask, params) -> jax.Array:
    """f(theta) = sum_m f_m(theta)."""
    per_worker = jax.vmap(task.loss_fn, in_axes=(None, 0))(params,
                                                           task.worker_data)
    return jnp.sum(per_worker)


def trajectory(cfg: OptLike, task: FedTask, num_iters: int,
               collect_metrics: bool = False) -> History:
    """Pure (un-jitted) Algorithm-1 scan — the traceable core of ``run``.

    Args:
      cfg: a ``repro.opt`` optimizer (any ``FedOptimizer``), or the
        deprecated ``FedOptConfig`` facade. Scalar stage hyperparameters
        (alpha, beta, eps1, tau0, ...) may be traced, which is how
        ``repro.sweep`` maps one compiled program over a whole
        configuration grid; structural choices (num_workers, stage
        classes, quantize, ...) must be static.
      task: the distributed problem; ``init_params``/``worker_data`` leaves
        may themselves be traced (e.g. gathered out of a stacked task bank).
      num_iters: K, the static scan length.
      collect_metrics: also record a per-iteration ``repro.obs`` MetricBag
        in ``History.metrics`` (static — changes the scan's outputs, so it
        is part of the compiled program's identity). The bag rides
        *alongside* the optimizer state: every state-carried value is
        bit-identical to a metrics-off run (tests/test_obs.py pins this
        against the golden fingerprints).
    Returns:
      The full ``History`` of the run (see its docstring).

    The (params, state) scan carry is threaded through ``lax.scan``, so
    XLA reuses the carry buffers across iterations automatically — the
    per-iteration state never reallocates. Donating ``init_params`` into
    the *enclosing* jit (``run(donate=True)``, ``train/trainer.py``)
    extends that reuse to the input buffers themselves; it is safe
    because every optimizer ``init`` copies ``prev_params`` before the
    scan starts (theta^{-1} never aliases a donated theta^0).
    """
    from ..obs import compile_log
    from ..opt.compat import as_optimizer
    opt = as_optimizer(cfg)
    worker_grads_fn = jax.vmap(task.grad_fn, in_axes=(None, 0))
    # host-side tick at trace time only: how many scan programs were built
    compile_log.record("simulator", "trajectory")

    def one_iter(carry, _):
        params, state = carry
        grads = worker_grads_fn(params, task.worker_data)
        new_state, new_params, info = opt.step(state, params, grads)
        rec = (global_loss(task, params),
               new_state.comm.total_uplinks,
               info.mask,
               info.agg_grad_sqnorm)
        if collect_metrics:
            from ..obs.metrics import step_metrics
            bag_fn = getattr(opt, "metrics", None) or \
                (lambda st, sc: step_metrics(opt, st, sc))
            rec = rec + (bag_fn(new_state, info),)
        return (new_params, new_state), rec

    state0 = opt.init(task.init_params)
    (params, state), recs = jax.lax.scan(
        one_iter, (task.init_params, state0), None, length=num_iters)
    obj, comms, mask, gsq = recs[:4]
    bags = recs[4] if collect_metrics else ()
    return History(objective=obj, comm_cum=comms, mask=mask,
                   agg_grad_sqnorm=gsq, final_params=params,
                   final_state=state, metrics=bags)


def run(cfg: OptLike, task: FedTask, num_iters: int,
        jit: bool = True, collect_metrics: bool = False,
        donate: bool = False) -> History:
    """Run Algorithm 1 for ``num_iters`` iterations on one configuration.

    Args:
      cfg: one optimizer — a ``repro.opt`` composition (``opt.make`` /
        ``opt.ComposedOptimizer`` / any ``FedOptimizer``) or a deprecated
        ``FedOptConfig``.
      task: the distributed problem (see ``FedTask``).
      num_iters: number of server iterations K.
      jit: compile the scan (default); ``False`` runs eagerly for debugging.
      collect_metrics: record a per-round ``repro.obs`` MetricBag in
        ``History.metrics`` (see ``trajectory``). Off by default; turning
        it on does not change any other History field's bits.
      donate: donate ``task.init_params`` to the compiled scan so XLA can
        reuse its buffers for the scan carry (halves the peak footprint of
        the parameter-sized state). Off by default because the donated
        array is invalidated — only enable when the caller owns the task
        and will not reuse ``init_params`` afterwards. Donation never
        changes bits: ``FedOptimizer.init`` copies ``prev_params`` before
        the first step (the same guard as
        ``core.distributed.init_scan_state``), so theta^{-1} cannot alias
        a donated theta^0.
    Returns:
      ``History`` — per-iteration trajectory plus the final optimizer state.

    Note: each call traces and compiles afresh. Batched experiments should
    go through ``repro.sweep.run_sweep``, which reproduces these
    trajectories bit-exactly while compiling once for the whole grid.
    """
    def scan_all(params0):
        return trajectory(cfg, task._replace(init_params=params0), num_iters,
                          collect_metrics=collect_metrics)

    if jit:
        fn = jax.jit(scan_all, donate_argnums=(0,) if donate else ())
    else:
        fn = scan_all
    return fn(task.init_params)


def estimate_fstar(task: FedTask, alpha: float, num_iters: int = 20000,
                   beta: float = 0.9) -> jax.Array:
    """Estimate f(theta^*) by running (uncensored) heavy ball to convergence."""
    from ..opt.censor import NeverCensor
    from ..opt.optimizer import ComposedOptimizer
    from ..opt.server import HeavyBall
    from ..opt.transport import DenseTransport
    opt = ComposedOptimizer(
        censor=NeverCensor(), transport=DenseTransport(),
        server=HeavyBall(alpha, beta),
        num_workers=jax.tree_util.tree_leaves(
            task.worker_data)[0].shape[0])
    hist = run(opt, task, num_iters)
    return jnp.minimum(jnp.min(hist.objective),
                       global_loss(task, hist.final_params))


def iterations_to_accuracy(history: History, fstar, tol: float) -> int:
    """First iteration k with f(theta^k) - f* < tol, or -1."""
    err = history.objective - fstar
    hit = jnp.nonzero(err < tol, size=1, fill_value=-1)[0][0]
    return int(hit)


def comms_to_accuracy(history: History, fstar, tol: float) -> int:
    """Cumulative uplink communications when accuracy tol is first reached."""
    k = iterations_to_accuracy(history, fstar, tol)
    if k < 0:
        return -1
    return int(history.comm_cum[k])

"""Federated M-worker simulator running exact Algorithm 1 semantics.

This is the harness behind every paper-reproduction experiment: it owns no
model-specific logic, only (a) per-worker gradient evaluation via vmap and
(b) the CHB-family server update. Everything is jitted with a lax.scan over
iterations, so thousands of iterations of the paper's small problems run in
milliseconds on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import chb
from .chb import FedOptConfig


class FedTask(NamedTuple):
    """A distributed optimization problem f(theta) = sum_m f_m(theta).

    worker_data leaves are stacked with leading axis M; grad_fn/loss_fn
    operate on ONE worker's slice. The simulator vmaps them.
    """
    init_params: Any
    grad_fn: Callable[[Any, Any], Any]   # (params, data_m) -> grad/subgrad
    loss_fn: Callable[[Any, Any], jax.Array]  # (params, data_m) -> f_m
    worker_data: Any
    name: str = "task"


class History(NamedTuple):
    objective: jax.Array       # (K,) f(theta^k)
    comm_cum: jax.Array        # (K,) cumulative uplink transmissions
    mask: jax.Array            # (K, M) per-iteration transmit indicators
    agg_grad_sqnorm: jax.Array  # (K,) ||grad_k||^2
    final_params: Any
    final_state: chb.FedOptState


def global_loss(task: FedTask, params) -> jax.Array:
    """f(theta) = sum_m f_m(theta)."""
    per_worker = jax.vmap(task.loss_fn, in_axes=(None, 0))(params,
                                                           task.worker_data)
    return jnp.sum(per_worker)


def run(cfg: FedOptConfig, task: FedTask, num_iters: int,
        jit: bool = True) -> History:
    """Run Algorithm 1 for num_iters iterations and record the trajectory."""

    worker_grads_fn = jax.vmap(task.grad_fn, in_axes=(None, 0))

    def one_iter(carry, _):
        params, state = carry
        grads = worker_grads_fn(params, task.worker_data)
        new_params, new_state, info = chb.step(cfg, state, params, grads)
        rec = (global_loss(task, params),
               new_state.comm.total_uplinks,
               info.mask,
               info.agg_grad_sqnorm)
        return (new_params, new_state), rec

    def scan_all(params0):
        state0 = chb.init(cfg, params0)
        (params, state), (obj, comms, mask, gsq) = jax.lax.scan(
            one_iter, (params0, state0), None, length=num_iters)
        return obj, comms, mask, gsq, params, state

    fn = jax.jit(scan_all) if jit else scan_all
    obj, comms, mask, gsq, params, state = fn(task.init_params)
    return History(objective=obj, comm_cum=comms, mask=mask,
                   agg_grad_sqnorm=gsq, final_params=params,
                   final_state=state)


def estimate_fstar(task: FedTask, alpha: float, num_iters: int = 20000,
                   beta: float = 0.9) -> jax.Array:
    """Estimate f(theta^*) by running (uncensored) heavy ball to convergence."""
    cfg = FedOptConfig(alpha=alpha, beta=beta, eps1=0.0,
                       num_workers=jax.tree_util.tree_leaves(
                           task.worker_data)[0].shape[0])
    hist = run(cfg, task, num_iters)
    return jnp.minimum(jnp.min(hist.objective),
                       global_loss(task, hist.final_params))


def iterations_to_accuracy(history: History, fstar, tol: float) -> int:
    """First iteration k with f(theta^k) - f* < tol, or -1."""
    err = history.objective - fstar
    hit = jnp.nonzero(err < tol, size=1, fill_value=-1)[0][0]
    return int(hit)


def comms_to_accuracy(history: History, fstar, tol: float) -> int:
    """Cumulative uplink communications when accuracy tol is first reached."""
    k = iterations_to_accuracy(history, fstar, tol)
    if k < 0:
        return -1
    return int(history.comm_cum[k])

"""Communication accounting.

The paper's headline metric is the number of worker->server (uplink)
transmissions. On TPU the censoring is realized as a masked collective (see
DESIGN.md §3), so the wire traffic that *would* occur in a federated
deployment is tracked here as explicit counters carried through the jitted
step. Counts are exact (per worker); bytes assume each transmission carries
the full delta payload (optionally quantized).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CommStats(NamedTuple):
    """Carried inside optimizer state; all fields are jnp arrays."""
    uplink_count: jax.Array     # (M,) cumulative transmissions per worker
    uplink_bytes: jax.Array     # () cumulative uplink payload bytes
    downlink_count: jax.Array   # () cumulative server broadcasts (1/iter)
    iterations: jax.Array       # () iterations taken

    @classmethod
    def init(cls, num_workers: int) -> "CommStats":
        return cls(
            uplink_count=jnp.zeros((num_workers,), jnp.int32),
            uplink_bytes=jnp.zeros((), jnp.int64)
            if jax.config.read("jax_enable_x64") else jnp.zeros((), jnp.float32),
            downlink_count=jnp.zeros((), jnp.int32),
            iterations=jnp.zeros((), jnp.int32),
        )

    def update(self, mask: jax.Array, payload_bytes) -> "CommStats":
        """mask: (M,) float/bool transmit indicators for this iteration."""
        mask_i = mask.astype(jnp.int32)
        pb = jnp.asarray(payload_bytes, self.uplink_bytes.dtype)
        return CommStats(
            uplink_count=self.uplink_count + mask_i,
            uplink_bytes=self.uplink_bytes
            + jnp.sum(mask.astype(self.uplink_bytes.dtype)) * pb,
            downlink_count=self.downlink_count + 1,
            iterations=self.iterations + 1,
        )

    @property
    def total_uplinks(self) -> jax.Array:
        return jnp.sum(self.uplink_count)

    def savings_vs_dense(self) -> jax.Array:
        """Fraction of uplinks censored vs. transmit-every-iteration."""
        m = self.uplink_count.shape[0]
        dense = self.iterations.astype(jnp.float32) * m
        return 1.0 - self.total_uplinks.astype(jnp.float32) / jnp.maximum(dense, 1.0)

"""Communication accounting.

The paper's headline metric is the number of worker->server (uplink)
transmissions. On TPU the censoring is realized as a masked collective (see
DESIGN.md §3), so the wire traffic that *would* occur in a federated
deployment is tracked here as explicit counters carried through the jitted
step. Counts are exact (per worker).

Byte accounting is precision-safe without x64: a single float32 cell loses
integer precision past 2^24 bytes (~16 MiB) of accumulated payload, after
which small increments silently stop registering. Instead the cumulative
payload is carried as a split int32 pair (whole MiB, remainder bytes) with
an explicit carry at every update — exact up to 2^31 MiB (2 PiB) on any
backend. ``uplink_bytes`` is a derived property for reporting; use
``uplink_bytes_exact()`` outside jit when the exact integer matters.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

MIB = 1 << 20


def split_bytes(nbytes: int) -> tuple[int, int]:
    """Split a static (Python int) byte count into (whole_mib, rem_bytes)."""
    return divmod(int(nbytes), MIB)


def carry_bytes(mib: jax.Array, rem: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Normalize a split counter so that 0 <= rem < MIB (jit-safe)."""
    c = rem // MIB
    return mib + c, rem - c * MIB


class CommStats(NamedTuple):
    """Carried inside optimizer state; all fields are jnp arrays."""
    uplink_count: jax.Array     # (M,) cumulative transmissions per worker
    uplink_mib: jax.Array       # () whole MiB of cumulative uplink payload
    uplink_rem: jax.Array       # () remainder bytes (< MIB) of the payload
    downlink_count: jax.Array   # () cumulative server broadcasts (1/iter)
    iterations: jax.Array       # () iterations taken

    @classmethod
    def init(cls, num_workers: int) -> "CommStats":
        return cls(
            uplink_count=jnp.zeros((num_workers,), jnp.int32),
            uplink_mib=jnp.zeros((), jnp.int32),
            uplink_rem=jnp.zeros((), jnp.int32),
            downlink_count=jnp.zeros((), jnp.int32),
            iterations=jnp.zeros((), jnp.int32),
        )

    def update(self, mask: jax.Array, payload_bytes) -> "CommStats":
        """mask: (M,) float/bool transmit indicators for this iteration.

        ``payload_bytes`` is the per-transmission payload size. It is a
        static Python int on every in-repo call path, which keeps the split
        accounting exact; a traced value is accepted as a fallback but is
        only exact while it stays below 2^31 bytes.
        """
        mask_i = mask.astype(jnp.int32)
        # jnp.sum promotes ints to the default int dtype under x64; the
        # split counters are pinned to int32 so the scan carry is stable
        n_tx = jnp.sum(mask_i).astype(jnp.int32)
        if isinstance(payload_bytes, (int, np.integer)):
            pb_mib, pb_rem = split_bytes(payload_bytes)
        else:
            pb = jnp.asarray(payload_bytes, jnp.int32)
            pb_mib, pb_rem = pb // MIB, pb % MIB
        mib, rem = carry_bytes(self.uplink_mib + n_tx * pb_mib,
                               self.uplink_rem + n_tx * pb_rem)
        return CommStats(
            uplink_count=self.uplink_count + mask_i,
            uplink_mib=mib,
            uplink_rem=rem,
            downlink_count=self.downlink_count + 1,
            iterations=self.iterations + 1,
        )

    def add_bytes_split(self, mib_inc: jax.Array,
                        rem_inc: jax.Array) -> "CommStats":
        """Fold a pre-split (mib, rem) byte increment (per-tensor path)."""
        mib, rem = carry_bytes(self.uplink_mib + mib_inc,
                               self.uplink_rem + rem_inc)
        return self._replace(uplink_mib=mib, uplink_rem=rem)

    @property
    def uplink_bytes(self) -> jax.Array:
        """Cumulative uplink payload bytes (float, for reporting).

        Exact whenever the float mantissa covers the total; the stored
        split counters are always exact — see ``uplink_bytes_exact``.
        """
        ftype = jnp.float64 if jax.config.read("jax_enable_x64") \
            else jnp.float32
        return self.uplink_mib.astype(ftype) * MIB \
            + self.uplink_rem.astype(ftype)

    def uplink_bytes_exact(self) -> int:
        """Exact cumulative byte count as a Python int (host-side only)."""
        return int(self.uplink_mib) * MIB + int(self.uplink_rem)

    @property
    def total_uplinks(self) -> jax.Array:
        return jnp.sum(self.uplink_count)

    def metrics(self) -> dict:
        """The counters as a flat ``repro.obs`` MetricBag fragment.

        Read-only derived scalars (jit-safe): exact cumulative uplink
        bytes via the split counters' float view, plus the raw counts.
        """
        return {
            "comm/uplink_total": self.total_uplinks,
            "comm/uplink_bytes": self.uplink_bytes,
            "comm/downlink_count": self.downlink_count,
            "comm/iterations": self.iterations,
        }

    def savings_vs_dense(self) -> jax.Array:
        """Fraction of uplinks censored vs. transmit-every-iteration."""
        m = self.uplink_count.shape[0]
        dense = self.iterations.astype(jnp.float32) * m
        return 1.0 - self.total_uplinks.astype(jnp.float32) / jnp.maximum(dense, 1.0)

"""Named constructors for the algorithm family benchmarked in the paper.

All four share the FedOptConfig/step machinery in core/chb.py, which makes
the comparisons in benchmarks/ apples-to-apples: identical gradient
computation, identical accounting, only (beta, eps1) differ.
"""
from __future__ import annotations

from .chb import FedOptConfig
from .censoring import paper_eps1


def gd(alpha: float, num_workers: int, **kw) -> FedOptConfig:
    """Classical distributed gradient descent (every worker transmits)."""
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=0.0, eps1=0.0, **kw)


def hb(alpha: float, num_workers: int, beta: float = 0.4, **kw) -> FedOptConfig:
    """Classical heavy ball (eq. 2); paper default beta=0.4."""
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=beta, eps1=0.0, **kw)


def lag(alpha: float, num_workers: int, eps1: float | None = None,
        eps1_scale: float = 0.1, **kw) -> FedOptConfig:
    """Censoring-based GD (LAG-WK, ref. [54]) with the shared condition (8)."""
    if eps1 is None:
        eps1 = paper_eps1(alpha, num_workers, eps1_scale)
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=0.0, eps1=eps1, **kw)


def chb(alpha: float, num_workers: int, beta: float = 0.4,
        eps1: float | None = None, eps1_scale: float = 0.1, **kw) -> FedOptConfig:
    """The paper's algorithm with its Sec.-IV default constants."""
    if eps1 is None:
        eps1 = paper_eps1(alpha, num_workers, eps1_scale)
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=beta, eps1=eps1, **kw)


ALGORITHMS = {"gd": gd, "hb": hb, "lag": lag, "chb": chb}

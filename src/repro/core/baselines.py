"""DEPRECATED named constructors for the paper's algorithm family.

Superseded by the ``repro.opt`` registry — ``opt.make("chb", alpha, M)``
returns the composed optimizer directly, ``opt.names()`` lists everything
registered (including algorithms beyond the paper's four, e.g. ``csgd``).

These shims remain so existing scripts keep working: each returns the
legacy ``FedOptConfig`` facade (whose construction emits the
``DeprecationWarning``), and the facade builds a composition bit-identical
to the registry's (pinned by tests/test_opt.py).
"""
from __future__ import annotations

from .chb import FedOptConfig
from .censoring import paper_eps1


def gd(alpha: float, num_workers: int, **kw) -> FedOptConfig:
    """DEPRECATED: use ``repro.opt.make("gd", alpha, num_workers)``."""
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=0.0, eps1=0.0, **kw)


def hb(alpha: float, num_workers: int, beta: float = 0.4, **kw) -> FedOptConfig:
    """DEPRECATED: use ``repro.opt.make("hb", alpha, num_workers)``."""
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=beta, eps1=0.0, **kw)


def lag(alpha: float, num_workers: int, eps1: float | None = None,
        eps1_scale: float = 0.1, **kw) -> FedOptConfig:
    """DEPRECATED: use ``repro.opt.make("lag", alpha, num_workers)``."""
    if eps1 is None:
        eps1 = paper_eps1(alpha, num_workers, eps1_scale)
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=0.0, eps1=eps1, **kw)


def chb(alpha: float, num_workers: int, beta: float = 0.4,
        eps1: float | None = None, eps1_scale: float = 0.1, **kw) -> FedOptConfig:
    """DEPRECATED: use ``repro.opt.make("chb", alpha, num_workers)``."""
    if eps1 is None:
        eps1 = paper_eps1(alpha, num_workers, eps1_scale)
    return FedOptConfig(alpha=alpha, num_workers=num_workers,
                        beta=beta, eps1=eps1, **kw)


# DEPRECATED: superseded by the repro.opt registry (opt.make / opt.names).
ALGORITHMS = {"gd": gd, "hb": hb, "lag": lag, "chb": chb}

"""CHB at datacenter scale: the two execution strategies (DESIGN.md §3).

scan strategy (pure pjit, any mesh)
-----------------------------------
Federated workers are M logical batch groups. A lax.scan iterates workers;
each iteration computes that worker's gradient on the FULL mesh (params stay
FSDP+TP sharded by auto-SPMD), applies the eq.-(8) censor test, and folds the
(masked) delta into the running aggregate. The stale-gradient bank ghat is a
leading-M stacked pytree, FSDP-sharded like the params, so the extra state is
M*P/num_devices bytes per device.

pod strategy (shard_map manual over "pod")
------------------------------------------
Federated workers ARE pods. Everything inside a pod (data/model axes) stays
auto-SPMD; only the pod axis is manual. Per-pod gradients never cross the pod
boundary unless the censor test fires: the ONLY cross-pod collective is
`psum(masked delta, "pod")` — exactly eq. (5). The server aggregate `nabla`
is carried explicitly (replicated across pods), so this strategy implements
the paper's recursion literally, and the collective roofline term shrinks to
the censored-delta traffic (int8 if quantization is on).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .accounting import CommStats
# cfg arguments below accept either a legacy FedOptConfig or a repro.opt
# ComposedOptimizer: both expose the flat hyperparameter views
# (alpha/beta/eps1/quantize/num_workers/bank_dtype) these strategies read.
from .quantize import payload_bytes_dense, payload_bytes_int8, \
    quantize_roundtrip
from .util import tree_sqnorm


class DistFedState(NamedTuple):
    prev_params: Any
    ghat: Any          # scan: (M, ...) stacked; pod: per-pod (leading 1 inside)
    nabla: Any         # pod strategy only: eq.(5) server aggregate (else ())
    err: Any           # quantization error feedback (or ())
    comm: CommStats
    step: jax.Array


def _tree_cast_like(t, ref):
    return jax.tree_util.tree_map(lambda x, r: x.astype(r.dtype), t, ref)


def _check_realizable(cfg) -> None:
    """The scan/pod strategies realize censoring as ``dsq > eps1 * ssq``
    only. A composed optimizer with any other censor policy (adaptive,
    stochastic, custom) would silently run uncensored through the flat
    ``cfg.eps1`` view — refuse it loudly instead."""
    censor = getattr(cfg, "censor", None)
    if censor is None:
        return      # legacy FedOptConfig: eq-8 semantics by construction
    from ..opt.censor import Eq8Censor, NeverCensor
    if not isinstance(censor, (Eq8Censor, NeverCensor)):
        raise NotImplementedError(
            f"censor policy {type(censor).__name__} is not realizable by "
            "the scan/pod training strategies (eq.-8 / uncensored only); "
            "run it through core.simulator or repro.fed instead")


def _payload_bytes(cfg, params) -> int:
    # must stay a Python int: CommStats.update only takes the exact
    # split-counter path for ints (see accounting.py)
    if cfg.quantize == "int8":
        return payload_bytes_int8(params)
    return payload_bytes_dense(params)


def _shard_map(f, mesh, in_specs, out_specs, manual_axes):
    """shard_map across jax versions, split by manual-axis coverage.

    Full-manual (``manual_axes`` covers every mesh axis) works everywhere:
    on jax >= 0.5 via the top-level ``jax.shard_map``, on the pinned 0.4.x
    via ``jax.experimental.shard_map.shard_map`` with ``check_rep=False``
    (its replication checker predates several collectives we use; the
    out_specs still enforce the layout). This is the path the client-mesh
    fold (``make_client_fold``) takes.

    Partial-manual (some axes left auto, e.g. the pod strategy's manual
    "pod" over an auto data/model submesh) needs jax >= 0.5: the 0.4.x
    experimental ``shard_map(auto=...)`` hard-crashes the XLA SPMD
    partitioner for this program (process abort, no traceback — HLO repro
    preserved in launch/hlo_analysis.py's module docstring), so fail fast.
    """
    manual_axes = set(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=manual_axes, check_vma=False)
    if manual_axes == set(mesh.axis_names):
        from jax.experimental.shard_map import shard_map as _exp_shard_map
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)
    raise NotImplementedError(
        "partial-manual shard_map (manual "
        f"{sorted(manual_axes)} over auto "
        f"{sorted(set(mesh.axis_names) - manual_axes)}) needs the "
        "top-level jax.shard_map API (jax >= 0.5); the 0.4.x experimental "
        "shard_map trips an XLA SPMD-partitioner CHECK in partial-manual "
        "mode")


def make_client_fold(mesh, axis: str = "clients"):
    """Build the server-side quorum fold for a client mesh.

    Takes a pytree whose leaves are ``(K, ...)`` stacks of per-shard
    partial sums (one row per device on ``axis``, assembled with
    ``launch.sharding.stack_shards``) and returns the replicated total:
    each shard contributes its own row and a single ``psum`` over ``axis``
    folds them — the ONLY cross-shard collective in the sharded federated
    runtime, so it is what ``obs.hlo_report`` surfaces as the fold cost.

    The fold is a fixed-order K-term tree reduction, identical for every
    output element, which is what makes the K-invariance anchors in
    docs/fed_scaling.md hold to ulp-level (and bitwise at K=1, where the
    psum is the identity).
    """
    from jax.sharding import PartitionSpec as _P

    def fold_local(stacked):
        return jax.tree_util.tree_map(
            lambda v: jax.lax.psum(v[0], axis), stacked)

    return _shard_map(fold_local, mesh, in_specs=(_P(axis),),
                      out_specs=_P(), manual_axes={axis})


# ============================================================ scan strategy
def init_scan_state(cfg, params) -> DistFedState:
    bank_dt = cfg.bank_dtype
    bank = jax.tree_util.tree_map(
        lambda x: jnp.zeros((cfg.num_workers,) + x.shape,
                            bank_dt or x.dtype), params)
    err = jax.tree_util.tree_map(jnp.zeros_like, bank) if cfg.quantize else ()
    # copy: prev_params must not alias params (donation safety at step 0)
    prev = jax.tree_util.tree_map(jnp.copy, params)
    return DistFedState(prev_params=prev, ghat=bank, nabla=(), err=err,
                        comm=CommStats.init(cfg.num_workers),
                        step=jnp.zeros((), jnp.int32))


def make_scan_step(cfg,
                   loss_fn: Callable[[Any, Any], jax.Array]):
    """Build train_step(params, state, batch) for the scan strategy.

    loss_fn(params, worker_batch) -> scalar loss for ONE worker's chunk.
    batch: pytree with leading axis M (worker chunks).
    """
    _check_realizable(cfg)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, state: DistFedState, batch):
        ssq = tree_sqnorm(jax.tree_util.tree_map(
            jnp.subtract, params, state.prev_params))

        def per_worker(carry, xs):
            agg, n_tx, loss_sum = carry
            if cfg.quantize:
                mbatch, ghat_m, err_m = xs
            else:
                mbatch, ghat_m = xs
                err_m = None
            loss, g = grad_fn(params, mbatch)
            delta = jax.tree_util.tree_map(
                lambda gg, h: gg.astype(h.dtype) - h, g, ghat_m)
            if err_m is not None:
                delta = jax.tree_util.tree_map(jnp.add, delta, err_m)
            dsq = tree_sqnorm(delta)
            send = (dsq > cfg.eps1 * ssq).astype(jnp.float32) \
                if cfg.eps1 > 0 else jnp.ones((), jnp.float32)
            if cfg.quantize == "int8":
                payload = jax.tree_util.tree_map(quantize_roundtrip, delta)
                new_err = jax.tree_util.tree_map(
                    lambda d, q, e: send * (d - q) + (1 - send) * e,
                    delta, payload, err_m)
            else:
                payload = delta
                new_err = None
            ghat_new = jax.tree_util.tree_map(
                lambda h, q: h + send * q.astype(h.dtype), ghat_m, payload)
            agg = jax.tree_util.tree_map(
                lambda a, h: a + h.astype(a.dtype), agg, ghat_new)
            out = (ghat_new, new_err, send) if cfg.quantize else \
                (ghat_new, send)
            return (agg, n_tx + send, loss_sum + loss), out

        agg0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        xs = (batch, state.ghat, state.err) if cfg.quantize else \
            (batch, state.ghat)
        (agg, n_tx, loss_sum), outs = jax.lax.scan(
            per_worker, (agg0, jnp.zeros((), jnp.float32),
                         jnp.zeros((), jnp.float32)), xs)
        if cfg.quantize:
            new_ghat, new_err, mask = outs
        else:
            new_ghat, mask = outs
            new_err = ()

        new_params = jax.tree_util.tree_map(
            lambda t, a, tp: (t.astype(jnp.float32)
                              - cfg.alpha * a
                              + cfg.beta * (t.astype(jnp.float32)
                                            - tp.astype(jnp.float32))
                              ).astype(t.dtype),
            params, agg, state.prev_params)

        new_state = DistFedState(
            prev_params=params, ghat=new_ghat, nabla=(), err=new_err,
            comm=state.comm.update(mask, _payload_bytes(cfg, params)),
            step=state.step + 1)
        metrics = {"loss": loss_sum / cfg.num_workers, "transmitted": n_tx,
                   "step_sqnorm": ssq, "agg_grad_sqnorm": tree_sqnorm(agg)}
        return new_params, new_state, metrics

    return train_step


# ============================================================= pod strategy
def init_pod_state(cfg, params, mesh) -> DistFedState:
    """ghat/err get a leading pod axis sharded over "pod"."""
    npod = mesh.shape["pod"]
    assert cfg.num_workers == npod, (cfg.num_workers, npod)
    bank_dt = cfg.bank_dtype

    def stack(x):
        return jnp.zeros((npod,) + x.shape, bank_dt or x.dtype)

    bank = jax.tree_util.tree_map(stack, params)
    err = jax.tree_util.tree_map(stack, params) if cfg.quantize else ()
    nabla = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, bank_dt or x.dtype), params)
    # copy: prev_params must not alias params (donation safety at step 0)
    prev = jax.tree_util.tree_map(jnp.copy, params)
    return DistFedState(prev_params=prev, ghat=bank, nabla=nabla, err=err,
                        comm=CommStats.init(npod),
                        step=jnp.zeros((), jnp.int32))


def make_pod_step(cfg,
                  loss_fn: Callable[[Any, Any], jax.Array], mesh):
    """Build train_step for the pod strategy (multi-pod mesh required).

    batch: pytree with leading batch axis sharded P("pod", "data") — each pod
    sees its own shard; censoring gates the cross-pod psum of deltas.
    """
    _check_realizable(cfg)
    grad_fn = jax.value_and_grad(loss_fn)
    npod = mesh.shape["pod"]

    def inner(params, prev_params, ghat, nabla, err, batch):
        # leading pod axis was split by shard_map -> local block of size 1
        ghat = jax.tree_util.tree_map(lambda x: x[0], ghat)
        if cfg.quantize:
            err = jax.tree_util.tree_map(lambda x: x[0], err)
        loss, g = grad_fn(params, batch)
        loss_mean = jax.lax.psum(loss, "pod") / npod
        ssq = tree_sqnorm(jax.tree_util.tree_map(
            jnp.subtract, params, prev_params))
        delta = jax.tree_util.tree_map(
            lambda gg, h: gg.astype(h.dtype) - h, g, ghat)
        if cfg.quantize:
            delta = jax.tree_util.tree_map(
                lambda d, e: d + e.astype(d.dtype), delta, err)
        dsq = tree_sqnorm(delta)
        send = (dsq > cfg.eps1 * ssq).astype(jnp.float32) \
            if cfg.eps1 > 0 else jnp.ones((), jnp.float32)
        if cfg.quantize == "int8":
            payload = jax.tree_util.tree_map(quantize_roundtrip, delta)
            new_err = jax.tree_util.tree_map(
                lambda d, q, e: (send * (d - q) + (1 - send) * e.astype(d.dtype)
                                 ).astype(e.dtype), delta, payload, err)
        else:
            payload = delta
            new_err = ()
        masked = jax.tree_util.tree_map(
            lambda q: q * send.astype(q.dtype), payload)
        # >>> THE censored cross-pod collective (eq. 5) <<<
        summed = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, "pod"), masked)
        new_nabla = jax.tree_util.tree_map(
            lambda nb, s: nb + s.astype(nb.dtype), nabla, summed)
        new_ghat = jax.tree_util.tree_map(
            lambda h, q: h + send.astype(h.dtype) * q.astype(h.dtype),
            ghat, payload)
        new_params = jax.tree_util.tree_map(
            lambda t, nb, tp: (t.astype(jnp.float32)
                               - cfg.alpha * nb.astype(jnp.float32)
                               + cfg.beta * (t.astype(jnp.float32)
                                             - tp.astype(jnp.float32))
                               ).astype(t.dtype),
            params, new_nabla, prev_params)
        n_tx = jax.lax.psum(send, "pod")
        mask_all = jax.lax.all_gather(send, "pod")  # (npod,)
        dsq_mean = jax.lax.psum(dsq, "pod") / npod
        restack = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        return (new_params, new_nabla, restack(new_ghat),
                restack(new_err) if cfg.quantize else (),
                mask_all, n_tx, dsq_mean, ssq, loss_mean)

    pspec = P()  # params replicated over pod (data/model sharding is auto)
    in_specs = (pspec, pspec, P("pod"), pspec,
                P("pod") if cfg.quantize else P(), P("pod"))
    out_specs = (pspec, pspec, P("pod"),
                 P("pod") if cfg.quantize else P(), P(), P(), P(), P(), P())
    sharded = _shard_map(inner, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, manual_axes={"pod"})

    def train_step(params, state: DistFedState, batch):
        (new_params, new_nabla, new_ghat, new_err, mask, n_tx, dsq, ssq,
         loss) = sharded(params, state.prev_params, state.ghat, state.nabla,
                         state.err, batch)
        new_state = DistFedState(
            prev_params=params, ghat=new_ghat, nabla=new_nabla, err=new_err,
            comm=state.comm.update(mask, _payload_bytes(cfg, params)),
            step=state.step + 1)
        metrics = {"loss": loss, "transmitted": n_tx, "step_sqnorm": ssq,
                   "delta_sqnorm": dsq,
                   "agg_grad_sqnorm": tree_sqnorm(new_nabla)}
        return new_params, new_state, metrics

    return train_step

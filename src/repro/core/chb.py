"""Censored Heavy Ball (CHB) — the paper's Algorithm 1 as a pytree optimizer.

One parameterized implementation covers the whole algorithm family used in
the paper's experiments:

    GD      alpha>0, beta=0,   eps1=0
    HB      alpha>0, beta>0,   eps1=0      (eq. 2)
    LAG-WK  alpha>0, beta=0,   eps1>0      (censored GD, ref. [54], using the
                                            same skip condition (8))
    CHB     alpha>0, beta>0,   eps1>0      (eqs. 4,5,8)

Semantics are *exactly* Algorithm 1:
  * each worker m keeps the last gradient it transmitted, ghat_m
    (stacked pytree with leading axis M),
  * worker m transmits delta_m = g_m - ghat_m iff
    ||delta_m||^2 > eps1 * ||theta^k - theta^{k-1}||^2   (eq. 8),
  * the server aggregate is grad_k = sum_m ghat_m^k; we recompute it from the
    bank instead of carrying the eq. (5) recursion explicitly — algebraically
    identical, and saves one parameter-sized buffer (DESIGN.md §3),
  * server update theta^{k+1} = theta^k - alpha*grad_k + beta*(theta^k -
    theta^{k-1})  (eq. 4).

Optionally the transmitted deltas are int8-quantized with error feedback
(beyond paper; core/quantize.py).

Traced vs. static configuration fields
--------------------------------------
``alpha``, ``beta``, and ``eps1`` may be *traced* jax scalars instead of
Python floats — this is what lets ``repro.sweep`` run a whole ConfigGrid of
(alpha, beta, eps1) points as one jitted program (``step`` switches to a
``jnp.where``-based censor mask, which is algebraically identical to the
static branches). Everything that changes the *structure* of the program —
``num_workers``, ``quantize``, ``granularity``, ``bank_dtype``, ``adaptive``
— must stay a static Python value; ``step`` raises if it sees a tracer
where a static is required.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import accounting
from .accounting import CommStats
from .censoring import delta_sqnorms, step_sqnorm, transmit_mask
from .quantize import (payload_bytes_dense, payload_bytes_int8,
                       tree_quantize_roundtrip_per_worker)
from .util import tree_stack_zeros, tree_sqnorm, tree_sum_leading


@dataclasses.dataclass(frozen=True)
class FedOptConfig:
    """Configuration for the CHB family.

    ``alpha``/``beta``/``eps1`` may be traced scalars (see module docstring);
    all other fields must be static Python values.
    """
    alpha: float
    num_workers: int
    beta: float = 0.0
    eps1: float = 0.0
    quantize: Optional[str] = None  # None | "int8"
    # dtype for the stale-gradient bank (bf16 halves state memory at scale)
    bank_dtype: Any = None
    # BEYOND PAPER (the paper's Sec.-V open problem: "finding an optimal
    # approach to tune eps1"): when adaptive > 0, worker m transmits iff
    # ||delta_m||^2 > adaptive * EMA_m(||delta_m||^2) — a scale-free
    # relative-novelty test that needs no knowledge of L or the step norm
    # and keeps working in the stochastic-gradient regime. adaptive in
    # (0, 1): censors the below-usual-novelty fraction of rounds.
    adaptive: float = 0.0
    adaptive_decay: float = 0.9
    # BEYOND PAPER: censoring granularity. The paper treats theta as one
    # vector ("global"); "per_tensor" applies the eq.-(8) test per parameter
    # tensor — a worker uploads only the tensors whose delta is novel
    # (embeddings/heads churn differently from deep blocks in LLMs), with
    # bytes accounted per transmitted tensor.
    granularity: str = "global"    # "global" | "per_tensor"

    @property
    def name(self) -> str:
        ep, bp = _static_pos(self.eps1), _static_pos(self.beta)
        if ep is None or bp is None:
            return "swept"     # traced fields: the family is decided on-device
        if ep and bp:
            return "chb"
        if ep:
            return "lag"
        if bp:
            return "hb"
        return "gd"


def _static_pos(x) -> Optional[bool]:
    """``bool(x > 0)`` for static scalars; ``None`` when ``x`` is traced."""
    if isinstance(x, jax.core.Tracer):
        return None
    return bool(x > 0)


def _scal(s, leaf: jax.Array) -> jax.Array:
    """Pin a config scalar to a leaf's dtype before multiplying.

    A static Python float weakly promotes to the leaf dtype, but a traced
    scalar arrives strongly typed (f64 under x64) and would silently
    promote an f32 update to f64 and double-round — a different trajectory
    than the static path. Casting first keeps traced and static configs
    bit-identical for every param dtype (same contract as
    ``censoring._eps_cast``)."""
    return jnp.asarray(s).astype(leaf.dtype)


class FedOptState(NamedTuple):
    prev_params: Any          # theta^{k-1}
    ghat: Any                 # (M, ...) stale-gradient bank
    err: Any                  # (M, ...) quantization error feedback (zeros if off)
    comm: CommStats
    ema: Any = ()             # (M,) EMA of ||delta||^2 (adaptive mode)


class StepInfo(NamedTuple):
    mask: jax.Array           # (M,) 1=transmitted
    delta_sq: jax.Array       # (M,) ||delta_m||^2
    step_sq: jax.Array        # () ||theta^k - theta^{k-1}||^2
    agg_grad_sqnorm: jax.Array  # () ||grad_k||^2 (paper's NN metric, squared)


def init(cfg: FedOptConfig, params) -> FedOptState:
    """Build the iteration-0 state (zero bank, theta^{-1} = theta^0).

    Args:
      cfg: algorithm constants; ``num_workers``/``quantize``/``bank_dtype``/
        ``adaptive`` must be static here (they size the state buffers).
      params: theta^0 pytree.
    Returns:
      A FedOptState whose bank/error buffers have leading axis M.
    """
    if _static_pos(cfg.adaptive) is None:
        raise NotImplementedError(
            "cfg.adaptive cannot be traced: it decides whether the EMA "
            "state buffer exists. Sweep adaptive as a static axis instead.")
    bank = tree_stack_zeros(params, cfg.num_workers)
    if cfg.bank_dtype is not None:
        bank = jax.tree_util.tree_map(
            lambda x: x.astype(cfg.bank_dtype), bank)
    err = tree_stack_zeros(params, cfg.num_workers) if cfg.quantize else \
        jax.tree_util.tree_map(lambda x: jnp.zeros((0,), x.dtype), params)
    return FedOptState(
        prev_params=params,
        ghat=bank,
        err=err,
        comm=CommStats.init(cfg.num_workers),
        ema=jnp.zeros((cfg.num_workers,), jnp.float32)
        if cfg.adaptive > 0 else (),
    )


def _bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast per-worker mask (M,) against a leading-M leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


def step(cfg: FedOptConfig, state: FedOptState, params, worker_grads):
    """One iteration of Algorithm 1.

    Args:
      cfg: algorithm constants.
      state: optimizer state.
      params: theta^k.
      worker_grads: pytree stacked with leading axis M — grad of each
        worker's *local* objective f_m at theta^k.
    Returns:
      (new_params, new_state, StepInfo)
    """
    cast = lambda t, ref: jax.tree_util.tree_map(
        lambda x, r: x.astype(r.dtype), t, ref)
    # delta_m = g_m - ghat_m  (in the bank's dtype for exact server/worker sync)
    delta = jax.tree_util.tree_map(
        lambda g, h: g.astype(h.dtype) - h, worker_grads, state.ghat)
    if cfg.quantize:
        # pending correction = delta + error-feedback residual
        pending = jax.tree_util.tree_map(jnp.add, delta, cast(state.err, delta))
    else:
        pending = delta

    if cfg.granularity == "per_tensor":
        eps_pos = _static_pos(cfg.eps1)
        if eps_pos is None:
            raise NotImplementedError(
                "per_tensor censoring needs a static eps1 (its byte "
                "accounting divmods the payload host-side)")
        if eps_pos:
            return _step_per_tensor(cfg, state, params, pending)

    dsq = delta_sqnorms(pending)
    ssq = step_sqnorm(params, state.prev_params)
    adaptive_on = _static_pos(cfg.adaptive)
    if adaptive_on is None:
        raise NotImplementedError(
            "cfg.adaptive cannot be traced (see init); sweep it as a "
            "static axis instead")
    if adaptive_on:
        # relative-novelty censoring (beyond paper; see FedOptConfig)
        warm = state.ema > 0
        mask = jnp.where(warm,
                         (dsq > cfg.adaptive * state.ema)
                         .astype(jnp.float32), 1.0)
        new_ema = jnp.where(warm,
                            cfg.adaptive_decay * state.ema
                            + (1 - cfg.adaptive_decay) * dsq, dsq)
    else:
        eps_pos = _static_pos(cfg.eps1)
        if eps_pos is None:
            # traced eps1 (repro.sweep): branch-free select — eps1 > 0 runs
            # the eq.-(8) test, eps1 == 0 transmits unconditionally. Bitwise
            # identical to the static branches below for every concrete eps1.
            mask = jnp.where(jnp.asarray(cfg.eps1) > 0,
                             transmit_mask(dsq, ssq, cfg.eps1),
                             jnp.ones((cfg.num_workers,), jnp.float32))
        elif eps_pos:
            mask = transmit_mask(dsq, ssq, cfg.eps1)
        else:
            mask = jnp.ones((cfg.num_workers,), jnp.float32)
        new_ema = state.ema

    if cfg.quantize == "int8":
        # per-worker scales: worker m quantizes its own delta slice
        payload = tree_quantize_roundtrip_per_worker(pending)
        new_err = jax.tree_util.tree_map(
            lambda p, q, e: _bcast(mask, p) * (p - q)
            + (1.0 - _bcast(mask, p)) * e.astype(p.dtype),
            pending, payload, cast(state.err, pending))
        per_tx_bytes = payload_bytes_int8(params)
    else:
        payload = pending
        new_err = state.err
        per_tx_bytes = payload_bytes_dense(params)

    # server/worker synchronized advance of the stale bank
    new_ghat = jax.tree_util.tree_map(
        lambda h, q: h + _bcast(mask, h) * q.astype(h.dtype),
        state.ghat, payload)

    # grad_k = sum_m ghat_m^k  (== eq. (5) recursion unrolled)
    agg = tree_sum_leading(new_ghat)

    # eq. (4): theta^{k+1} = theta^k - alpha*grad_k + beta*(theta^k - theta^{k-1})
    new_params = jax.tree_util.tree_map(
        lambda t, g, tp: (t - _scal(cfg.alpha, t) * g.astype(t.dtype)
                          + _scal(cfg.beta, t) * (t - tp)).astype(t.dtype),
        params, agg, state.prev_params)

    info = StepInfo(mask=mask, delta_sq=dsq, step_sq=ssq,
                    agg_grad_sqnorm=tree_sqnorm(agg))
    new_state = FedOptState(
        prev_params=params,
        ghat=new_ghat,
        err=new_err,
        comm=state.comm.update(mask, per_tx_bytes),
        ema=new_ema,
    )
    return new_params, new_state, info


def _step_per_tensor(cfg: FedOptConfig, state: FedOptState, params, pending):
    """Per-tensor censoring (beyond paper; FedOptConfig.granularity).

    The eq.-(8) test is applied independently per parameter tensor:
    worker m transmits tensor t iff ||delta_m[t]||^2 > eps1*||dtheta[t]||^2.
    Quantization/error-feedback is not combined with this mode (kept simple);
    uplink bytes are accounted per transmitted tensor, uplink *count* counts
    a worker-iteration as transmitting if ANY of its tensors ships (so the
    headline count stays comparable with global censoring).
    """
    assert not cfg.quantize, "per_tensor + quantize not supported"
    leaves_delta, treedef = jax.tree_util.tree_flatten(pending)
    leaves_theta = treedef.flatten_up_to(params)
    leaves_prev = treedef.flatten_up_to(state.prev_params)
    leaves_ghat = treedef.flatten_up_to(state.ghat)

    m = cfg.num_workers
    new_ghat = []
    mib_up = jnp.zeros((), jnp.int32)
    rem_up = jnp.zeros((), jnp.int32)
    any_mask = jnp.zeros((m,), jnp.float32)
    for d, t, tp, h in zip(leaves_delta, leaves_theta, leaves_prev,
                           leaves_ghat):
        dsq_t = jnp.sum(jnp.square(d.astype(jnp.float32)).reshape(m, -1),
                        axis=1)                              # (M,)
        ssq_t = jnp.sum(jnp.square(t.astype(jnp.float32)
                                   - tp.astype(jnp.float32)))
        mask_t = (dsq_t > cfg.eps1 * ssq_t).astype(jnp.float32)
        any_mask = jnp.maximum(any_mask, mask_t)
        n_tx_t = jnp.sum(mask_t).astype(jnp.int32)
        # exact split-counter byte accounting (accounting.py): leaf payload
        # is static, so divmod happens in Python; carry per leaf keeps the
        # traced remainder below int32 range
        pb_mib, pb_rem = accounting.split_bytes(d[0].size * d.dtype.itemsize)
        mib_up, rem_up = accounting.carry_bytes(
            mib_up + n_tx_t * pb_mib, rem_up + n_tx_t * pb_rem)
        new_ghat.append(h + _bcast(mask_t, h) * d.astype(h.dtype))
    new_ghat = jax.tree_util.tree_unflatten(treedef, new_ghat)

    agg = tree_sum_leading(new_ghat)
    new_params = jax.tree_util.tree_map(
        lambda t, g, tp: (t - _scal(cfg.alpha, t) * g.astype(t.dtype)
                          + _scal(cfg.beta, t) * (t - tp)).astype(t.dtype),
        params, agg, state.prev_params)
    comm = CommStats(
        uplink_count=state.comm.uplink_count + any_mask.astype(jnp.int32),
        uplink_mib=state.comm.uplink_mib,
        uplink_rem=state.comm.uplink_rem,
        downlink_count=state.comm.downlink_count + 1,
        iterations=state.comm.iterations + 1,
    ).add_bytes_split(mib_up, rem_up)
    info = StepInfo(mask=any_mask,
                    delta_sq=delta_sqnorms(pending),
                    step_sq=step_sqnorm(params, state.prev_params),
                    agg_grad_sqnorm=tree_sqnorm(agg))
    new_state = FedOptState(prev_params=params, ghat=new_ghat,
                            err=state.err, comm=comm, ema=state.ema)
    return new_params, new_state, info

"""Censored Heavy Ball (CHB) — DEPRECATED facade over ``repro.opt``.

One parameterized config covers the algorithm family benchmarked in the
paper:

    GD      alpha>0, beta=0,   eps1=0
    HB      alpha>0, beta>0,   eps1=0      (eq. 2)
    LAG-WK  alpha>0, beta=0,   eps1>0      (censored GD, ref. [54])
    CHB     alpha>0, beta>0,   eps1>0      (eqs. 4,5,8)

Since the ``repro.opt`` redesign the actual Algorithm-1 math lives in
composable stages (``opt.censor`` / ``opt.transport`` / ``opt.server``
glued by ``opt.ComposedOptimizer``); a ``FedOptConfig`` merely *names* one
of those compositions:

    alpha, beta        -> opt.HeavyBall(alpha, beta)
    eps1 / adaptive    -> opt.Eq8Censor / opt.AdaptiveCensor / opt.NeverCensor
    quantize           -> opt.DenseTransport / opt.Int8Transport

``init``/``step`` here delegate to that composition, bit-exactly (golden
trajectories pinned by tests/test_opt.py), and constructing a
``FedOptConfig`` emits a ``DeprecationWarning`` pointing at the new API.
New code should compose via ``repro.opt`` (``opt.make(name, ...)`` or
``opt.ComposedOptimizer(...)``) — every consumer (simulator, sweep, fed,
trainer) accepts either object.

Traced vs. static configuration fields (unchanged contract): ``alpha``,
``beta``, ``eps1`` may be traced jax scalars; ``num_workers``,
``quantize``, ``granularity``, ``bank_dtype``, ``adaptive`` must stay
static Python values.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax


def _static_pos(x) -> Optional[bool]:
    """``bool(x > 0)`` for static scalars; ``None`` when ``x`` is traced.

    Duplicated from ``repro.opt.api.static_pos`` (3 lines) so this module
    needs no import-time dependency on ``repro.opt`` — core and opt import
    each other's *submodules* lazily to stay cycle-free.
    """
    if isinstance(x, jax.core.Tracer):
        return None
    return bool(x > 0)


def __getattr__(name):
    # `chb.FedOptState` / `chb.StepInfo` keep resolving for existing
    # callers; they ARE the repro.opt types now (the `ema` field of the
    # old state generalized into the policy-owned `censor` slot). Resolved
    # lazily to keep the core <-> opt import graph acyclic.
    if name in ("FedOptState", "StepInfo"):
        from ..opt.api import OptState, StepStats
        return OptState if name == "FedOptState" else StepStats
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class FedOptConfig:
    """DEPRECATED: flat-field description of one CHB-family composition.

    Prefer ``repro.opt`` (``opt.make`` / ``opt.ComposedOptimizer``); this
    facade remains so existing configs, checkpoints, and scripts keep
    working. ``alpha``/``beta``/``eps1`` may be traced scalars; all other
    fields must be static Python values. See the module docstring for the
    field -> stage mapping.
    """
    alpha: float
    num_workers: int
    beta: float = 0.0
    eps1: float = 0.0
    quantize: Optional[str] = None  # None | "int8"
    # dtype for the stale-gradient bank (bf16 halves state memory at scale)
    bank_dtype: Any = None
    # BEYOND PAPER: relative-novelty EMA censoring (opt.AdaptiveCensor)
    adaptive: float = 0.0
    adaptive_decay: float = 0.9
    # BEYOND PAPER: censoring granularity, "global" | "per_tensor"
    granularity: str = "global"

    def __post_init__(self):
        warnings.warn(
            "FedOptConfig is deprecated: compose optimizers via repro.opt "
            "instead (opt.make(name, alpha, num_workers, ...) or "
            "opt.ComposedOptimizer); FedOptConfig is now a thin facade "
            "that builds the same composition.",
            DeprecationWarning, stacklevel=3)

    @property
    def name(self) -> str:
        ep, bp = _static_pos(self.eps1), _static_pos(self.beta)
        if ep is None or bp is None:
            return "swept"     # traced fields: the family is decided on-device
        if ep and bp:
            return "chb"
        if ep:
            return "lag"
        if bp:
            return "hb"
        return "gd"

    def build(self):
        """The ``opt.ComposedOptimizer`` this config describes."""
        from ..opt.compat import from_config
        return from_config(self)


def init(cfg: FedOptConfig, params) -> "FedOptState":
    """DEPRECATED: ``cfg.build().init(params)`` (kept for callers)."""
    return cfg.build().init(params)


def step(cfg: FedOptConfig, state, params, worker_grads):
    """DEPRECATED: one Algorithm-1 iteration via the composed optimizer.

    Returns ``(new_params, new_state, StepInfo)`` — the legacy return
    order (the ``repro.opt`` protocol returns state first).
    """
    new_state, new_params, stats = cfg.build().step(
        state, params, worker_grads)
    return new_params, new_state, stats

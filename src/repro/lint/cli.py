"""CLI driver: ``python -m repro.lint [options] paths...``.

Exit status: 0 clean (every finding suppressed-with-reason or none at
all), 1 when unsuppressed findings exist, 2 on usage errors — the same
contract as ``benchmarks/run.py --only`` / ``tools/bench_diff.py``.
"""
from __future__ import annotations

import argparse
import sys

from . import registry
from .engine import run_paths
from .findings import make_artifact, write_artifact


def _list_rules() -> str:
    lines = ["repro-lint rules (select/ignore/suppress by name):", ""]
    for name, doc in registry.docs().items():
        lines.append(f"  {name}")
        lines.append(f"      {doc}")
    lines += ["", "suppression syntax (reason is required):",
              "  # repro-lint: disable=<rule>[,<rule>] -- <reason>",
              "  # repro-lint: disable-file=<rule> -- <reason>"]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-aware static analysis for the exactness "
                    "invariants (rule catalog: docs/lint.md)")
    ap.add_argument("paths", nargs="*",
                    help="files and/or directories to lint")
    ap.add_argument("--json", action="store_true",
                    help="emit the findings artifact as JSON on stdout "
                         "instead of human-readable lines")
    ap.add_argument("--json-file", metavar="PATH",
                    help="also write the findings artifact to PATH")
    ap.add_argument("--select", metavar="RULES",
                    help="comma-separated rule names to run (default all)")
    ap.add_argument("--ignore", metavar="RULES",
                    help="comma-separated rule names to skip")
    ap.add_argument("--root", metavar="DIR",
                    help="project root override (default: nearest "
                         "pyproject.toml above the first path)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given (try: python -m repro.lint src "
              "benchmarks tests)", file=sys.stderr)
        return 2

    try:
        findings = run_paths(args.paths, root=args.root,
                             select=args.select, ignore=args.ignore)
    except ValueError as e:          # unknown rule names, etc.
        print(f"error: {e}", file=sys.stderr)
        return 2

    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    artifact = make_artifact(
        findings, rules=sorted(registry.resolve_selection(
            args.select, args.ignore)), paths=args.paths)
    if args.json_file:
        write_artifact(artifact, args.json_file)
    if args.json:
        write_artifact(artifact, None)
    else:
        for f in findings:
            print(f.render())
        print(f"{len(active)} finding(s), {len(suppressed)} "
              f"suppressed-with-reason")
    return 1 if active else 0


if __name__ == "__main__":          # pragma: no cover - module entry
    raise SystemExit(main())

"""String-keyed rule registry (the ``repro.opt`` registry idiom).

A *file rule* checks one parsed source file; a *project rule* checks
cross-file invariants (it runs once per lint invocation and sees the whole
file set plus the project root). Both register under a kebab-case name that
is the vocabulary of ``--select`` / ``--ignore`` and of inline
``# repro-lint: disable=<name>`` suppressions.
"""
from __future__ import annotations

from typing import Callable, Iterable

from .suppress import META_RULES

# name -> (checker, one-line doc). File rules take (ctx, src) and yield
# findings; project rules take (ctx) and yield findings.
_FILE_RULES: dict[str, tuple[Callable, str]] = {}
_PROJECT_RULES: dict[str, tuple[Callable, str]] = {}


def ensure_loaded() -> None:
    """Import the built-in rule modules (idempotent).

    Rules live in ``repro.lint.rules`` and register themselves on import;
    deferring that import keeps ``registry`` free of cycles while letting
    ``names()``/``docs()`` always reflect the full catalog.
    """
    from . import rules  # noqa: F401  (import side effect registers rules)


def rule(name: str, doc: str) -> Callable:
    """Decorator: register a per-file rule under ``name``."""
    def deco(fn: Callable) -> Callable:
        if name in _FILE_RULES or name in _PROJECT_RULES:
            raise ValueError(f"duplicate lint rule {name!r}")
        _FILE_RULES[name] = (fn, doc)
        return fn
    return deco


def project_rule(name: str, doc: str) -> Callable:
    """Decorator: register a whole-project rule under ``name``."""
    def deco(fn: Callable) -> Callable:
        if name in _FILE_RULES or name in _PROJECT_RULES:
            raise ValueError(f"duplicate lint rule {name!r}")
        _PROJECT_RULES[name] = (fn, doc)
        return fn
    return deco


def names() -> tuple[str, ...]:
    """Every selectable rule name, sorted (meta-rules included)."""
    ensure_loaded()
    return tuple(sorted({**_FILE_RULES, **_PROJECT_RULES,
                         **{k: None for k in META_RULES}}))


def docs() -> dict[str, str]:
    """name -> one-line doc for ``--list-rules``."""
    ensure_loaded()
    out = {n: d for n, (_, d) in _FILE_RULES.items()}
    out.update({n: d for n, (_, d) in _PROJECT_RULES.items()})
    out.update(META_RULES)
    return dict(sorted(out.items()))


def file_rules(selected: Iterable[str]) -> list[tuple[str, Callable]]:
    return [(n, fn) for n, (fn, _) in sorted(_FILE_RULES.items())
            if n in selected]


def project_rules(selected: Iterable[str]) -> list[tuple[str, Callable]]:
    return [(n, fn) for n, (fn, _) in sorted(_PROJECT_RULES.items())
            if n in selected]


def resolve_selection(select: str | None, ignore: str | None
                      ) -> set[str]:
    """The active rule set from ``--select`` / ``--ignore`` comma lists.

    Unknown names raise with the valid list — the same contract as
    ``opt.make`` and ``benchmarks/run.py --only``.
    """
    all_names = set(names())

    def split(arg: str | None) -> set[str]:
        vals = {v.strip() for v in (arg or "").split(",") if v.strip()}
        unknown = sorted(vals - all_names)
        if unknown:
            listing = "\n".join(f"  {n}" for n in sorted(all_names))
            raise ValueError(
                f"unknown rule(s) {unknown}; valid rules:\n{listing}")
        return vals

    chosen = split(select) or all_names
    return chosen - split(ignore)

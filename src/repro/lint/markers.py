"""Source markers the lint rules key on.

This module is intentionally dependency-free (stdlib only, no jax): hot-path
modules (``sweep/engine.py``, ``fed/runner.py``, ``opt/transport.py``) import
it at module load, so it must never pull the analysis engine — or anything
heavier — into the import graph.
"""
from __future__ import annotations

from typing import Callable, TypeVar

F = TypeVar("F", bound=Callable)


def draw_exact(fn: F) -> F:
    """Mark a function as a draw-exact path.

    Draw-exact paths are the ones the bit-exactness anchors are pinned on:
    the same computation run per-row (one client, one grid point) and
    batched must produce *bit-identical* values, so a censor threshold
    comparison (eq. 8) lands on the same side either way. ``jax.vmap`` and
    gather-style batching regroup float reductions and change XLA's matmul
    lowering by ~1 ulp — enough to flip a transmit/suppress decision near
    the threshold — so the ``vmap-in-draw-exact`` lint rule forbids them
    inside marked functions (``lax.map`` and explicit per-slice loops are
    the compliant batching forms; see docs/lint.md).

    Runtime behavior is untouched: the decorator only sets an attribute.
    """
    fn.__draw_exact__ = True
    return fn


#: Assign ``__draw_exact__ = True`` at module top level to mark a whole
#: module as a draw-exact path (every function in it is then checked).
MODULE_MARKER = "__draw_exact__"

"""Rule ``interpret-not-routed`` — the PR 4 silent-interpreter bug class.

History: before the backend axis landed, some kernels defaulted
``interpret=True`` — calling them directly on a real TPU silently ran the
Pallas *interpreter* instead of lowering through Mosaic, hundreds of times
slower with zero errors. PR 4 made ``kernels/common.interpret_default``
the single source of truth (interpret off on TPU backends, on elsewhere)
and every kernel resolves ``interpret=None`` through it.

Checks, in any file that calls ``pallas_call`` (i.e. defines kernels):

  * an ``interpret`` parameter must default to ``None`` — a literal
    ``True``/``False`` default hardwires the backend decision;
  * the ``interpret=`` argument of ``pallas_call`` must be an immediate
    ``resolve_interpret(...)`` / ``interpret_default()`` call — passing
    the raw parameter through skips the routing.

And everywhere outside ``tests/`` (oracle tests force interpret mode on
purpose): no call site may pass a literal ``interpret=True/False``.
"""
from __future__ import annotations

import ast

from ..asthelpers import is_bool_literal, keyword, terminal_name
from ..findings import Finding
from ..registry import rule

_RESOLVERS = {"resolve_interpret", "interpret_default"}


def _is_resolved(value: ast.expr) -> bool:
    if isinstance(value, ast.Call):
        return terminal_name(value.func) in _RESOLVERS
    return isinstance(value, ast.Constant) and value.value is None


def _is_test_file(path: str) -> bool:
    parts = path.replace("\\", "/").split("/")
    return any(p in ("tests", "lint_fixtures") for p in parts[:-1]) \
        or parts[-1].startswith("test_")


def _defines_pallas_kernels(src) -> bool:
    return any(isinstance(n, ast.Call)
               and terminal_name(n.func) == "pallas_call"
               for n in src.walk())


@rule("interpret-not-routed",
      "Pallas kernels must resolve interpret mode through "
      "kernels/common.interpret_default (param default None + "
      "resolve_interpret at the pallas_call); literal interpret=True/False "
      "silently forces the interpreter on TPU or Mosaic off it")
def check(ctx, src):
    in_tests = _is_test_file(src.path)
    kernel_file = _defines_pallas_kernels(src)

    for node in src.walk():
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and kernel_file:
            args = node.args
            all_args = args.posonlyargs + args.args + args.kwonlyargs
            defaults = ([None] * (len(args.posonlyargs + args.args)
                                  - len(args.defaults)) + list(args.defaults)
                        + list(args.kw_defaults))
            for a, d in zip(all_args, defaults):
                if a.arg == "interpret" and is_bool_literal(d):
                    yield Finding(
                        rule="interpret-not-routed", path=src.path,
                        line=a.lineno, col=a.col_offset,
                        message=f"{node.name}: interpret defaults to a "
                                "literal bool; default to None and resolve "
                                "via common.resolve_interpret so direct "
                                "calls and ops.py dispatch agree on every "
                                "backend")

        if not isinstance(node, ast.Call):
            continue
        fn = terminal_name(node.func)
        value = keyword(node, "interpret")
        if value is None:
            continue
        if fn == "pallas_call":
            if not (isinstance(value, ast.Call)
                    and terminal_name(value.func) in _RESOLVERS):
                yield Finding(
                    rule="interpret-not-routed", path=src.path,
                    line=value.lineno, col=value.col_offset,
                    message="pallas_call interpret= must be "
                            "resolve_interpret(interpret) (or "
                            "interpret_default()), not "
                            f"{ast.unparse(value)!r}: unrouted values "
                            "bypass the TPU-vs-interpreter rule")
        elif is_bool_literal(value) and not in_tests:
            yield Finding(
                rule="interpret-not-routed", path=src.path,
                line=value.lineno, col=value.col_offset,
                message=f"call to {fn or '<expr>'} hardwires "
                        f"interpret={value.value}: on TPU this silently "
                        "interprets (or on CPU silently Mosaic-lowers); "
                        "omit it (None routes through interpret_default)")

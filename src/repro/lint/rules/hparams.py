"""Rule ``baked-traced-hparam`` — the PR 4 retrace bug class.

History: the first Pallas wiring baked ``alpha``/``beta`` into the kernel
closure (``functools.partial``) and declared them ``static_argnames`` on
the jitted dispatch — every point of a hyperparameter grid recompiled every
kernel. The fix made them traced SMEM operands (one compile per kernel
across the whole grid; pinned by ``tests/test_kernels.py`` trace-count
regressions). This rule keeps it fixed:

  * no ``functools.partial`` may bind a sweepable hyperparameter keyword
    (alpha/beta/eps1/tau0/...) onto a kernel entry point — the kernel
    function set is cross-checked against the real signatures in
    ``src/repro/kernels/`` when linting inside the repo (a static fallback
    table keeps the rule alive on detached snippets);
  * no ``static_argnames`` (jit or pallas dispatch) may name a sweepable
    hyperparameter anywhere.
"""
from __future__ import annotations

import ast

from ..asthelpers import (dotted, keyword, str_elements, terminal_name)
from ..findings import Finding
from ..registry import rule

#: sweepable, array-valued hyperparameters that must stay traced operands
HPARAMS = {"alpha", "beta", "eps1", "eps1_scale", "tau0", "tau"}

#: fallback kernel entry points (used when ``src/repro/kernels`` is not
#: reachable from the lint root, e.g. on detached fixture snippets)
_FALLBACK_KERNEL_FNS = {
    "hb_update", "hb_param_update", "tree_hb_update",
    "censor_delta_sqnorm", "censor_delta_sqnorm_batched", "censor_select",
    "sqnorm_batched", "censor_bank_advance", "bank_advance",
    "quantize_ef_batched", "absmax_batched", "select_pack_ef_batched",
    "residual_ef_batched", "pallas_call",
}


def _kernel_fns(ctx) -> set[str]:
    """Kernel entry-point names, from the repo's own dispatch signatures.

    Parses every module under ``src/repro/kernels/`` at the lint root and
    collects the public function names whose signature takes at least one
    sweepable hyperparameter — the exact set a ``functools.partial`` could
    re-bake. Falls back to the static table off-repo.
    """
    cached = ctx._cache.get("__kernel_fns__")
    if cached is not None:
        return cached
    names: set[str] = set()
    for rel in ctx.project_glob("src/repro/kernels"):
        tree = ctx.read_project_file(rel)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                argnames = {a.arg for a in
                            args.posonlyargs + args.args + args.kwonlyargs}
                if argnames & HPARAMS:
                    names.add(node.name)
    names = (names | {"pallas_call"}) if names else set(_FALLBACK_KERNEL_FNS)
    ctx._cache["__kernel_fns__"] = names
    return names


def _is_partial(call: ast.Call) -> bool:
    return dotted(call.func) in ("functools.partial", "partial")


@rule("baked-traced-hparam",
      "functools.partial / static_argnames must not freeze array-valued "
      "hyperparameters (alpha/beta/eps1/...) at kernel call sites — they "
      "are traced SMEM operands, or every grid point recompiles")
def check(ctx, src):
    kernel_fns = None   # resolved lazily: most files have no partials
    for node in src.walk():
        if not isinstance(node, ast.Call):
            continue

        # -- static_argnames naming an hparam (any callable, any file) --
        sa = keyword(node, "static_argnames")
        if sa is not None:
            baked = sorted(str_elements(sa) & HPARAMS)
            if baked:
                yield Finding(
                    rule="baked-traced-hparam", path=src.path,
                    line=node.lineno, col=node.col_offset,
                    message=f"static_argnames bakes hyperparameter(s) "
                            f"{baked}: every distinct value recompiles; "
                            "pass them as traced operands (see "
                            "kernels/ops.py hparam contract)")

        # -- functools.partial binding an hparam keyword onto a kernel --
        if _is_partial(node) and node.args:
            target = terminal_name(node.args[0])
            bound = sorted({kw.arg for kw in node.keywords
                            if kw.arg in HPARAMS})
            if target and bound:
                if kernel_fns is None:
                    kernel_fns = _kernel_fns(ctx)
                if target in kernel_fns:
                    yield Finding(
                        rule="baked-traced-hparam", path=src.path,
                        line=node.lineno, col=node.col_offset,
                        message=f"functools.partial bakes {bound} into "
                                f"kernel entry point {target!r}: the value "
                                "becomes a compile-time constant and every "
                                "hyperparameter point retraces; pass it as "
                                "a traced operand instead")

"""Rule ``unseeded-randomness`` — reproducibility of every drawn number.

Every stochastic element in the repo is keyed: the CSGD censor folds its
draws from a seeded key chain (which is what makes the fed runtime's
per-client draws reproduce the batched step draw-for-draw), tasks
synthesize data from ``np.random.default_rng(seed)``, and sweeps partition
by seed. A single call into numpy's *global* RNG (or the stdlib one)
injects hidden mutable state: results change run-to-run, and inside a
jitted path the draw silently freezes at trace time — both break the
golden-fingerprint tests in ways that only show up later.

Flags:
  * legacy global-state numpy calls: ``np.random.rand/randn/seed/...``;
  * ``np.random.default_rng()`` with no seed argument;
  * stdlib ``random.<fn>()`` module-level calls.

Seeded generators (``np.random.default_rng(seed)``, ``Generator`` method
calls) and ``jax.random`` (which always takes a key) never fire.
"""
from __future__ import annotations

import ast

from ..asthelpers import dotted
from ..findings import Finding
from ..registry import rule

_NP_LEGACY = {
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "ranf", "sample", "uniform", "normal", "standard_normal", "choice",
    "shuffle", "permutation", "beta", "binomial", "poisson",
    "exponential", "gamma", "laplace", "lognormal", "get_state",
    "set_state",
}

_STDLIB_RANDOM = {
    "seed", "random", "randint", "randrange", "uniform", "choice",
    "choices", "shuffle", "sample", "gauss", "normalvariate",
    "betavariate", "expovariate",
}


@rule("unseeded-randomness",
      "no global-state RNG: np.random.<legacy> calls, unseeded "
      "np.random.default_rng(), and stdlib random.<fn>() draw from hidden "
      "mutable state — pass an explicit seed / Generator / jax PRNG key")
def check(ctx, src):
    for node in src.walk():
        if not isinstance(node, ast.Call):
            continue
        full = dotted(node.func)
        if full is None:
            continue

        if full in ("np.random.default_rng", "numpy.random.default_rng",
                    "random.default_rng", "default_rng"):
            if not node.args and not node.keywords:
                yield Finding(
                    rule="unseeded-randomness", path=src.path,
                    line=node.lineno, col=node.col_offset,
                    message="default_rng() without a seed draws OS "
                            "entropy: results change run-to-run; pass an "
                            "explicit seed")
            continue

        parts = full.split(".")
        fn = parts[-1]
        chain = ".".join(parts[:-1])
        if chain in ("np.random", "numpy.random") and fn in _NP_LEGACY:
            yield Finding(
                rule="unseeded-randomness", path=src.path,
                line=node.lineno, col=node.col_offset,
                message=f"{full} uses numpy's global RNG (hidden mutable "
                        "state; freezes at trace time under jit); use "
                        "np.random.default_rng(seed) or a jax PRNG key")
        elif chain == "random" and fn in _STDLIB_RANDOM:
            yield Finding(
                rule="unseeded-randomness", path=src.path,
                line=node.lineno, col=node.col_offset,
                message=f"stdlib {full} draws from process-global state; "
                        "use np.random.default_rng(seed) or a jax PRNG "
                        "key")

"""Rule ``float-byte-counter`` — the PR 1 byte-overflow bug class.

History: the seed carried cumulative uplink bytes in a float32 cell.  Past
2^24 accumulated bytes (~16 MiB) float32 spacing exceeds 1, so small
payload increments silently stopped registering — the bytes curve went
flat while transmissions kept happening. PR 1 replaced it with the split
int32 (whole-MiB, remainder-bytes) pair in ``core/accounting.py``, exact to
2 PiB on any backend.

The rule flags byte-counter *state* being created or accumulated in a
float dtype: an assignment (or augmented assignment) whose target is
byte-named and whose right-hand side mentions a float dtype
(``jnp.float32`` & co, or ``.astype(float)``). Derived float *views* for
reporting (a property returning ``mib * MIB + rem`` as float) are fine —
they are reads of exact integer state, not the state itself — and the rule
only looks at assignments, so it does not fire on them.
"""
from __future__ import annotations

import ast

from ..asthelpers import ident_tokens, terminal_name
from ..findings import Finding
from ..registry import rule

_BYTE_WORDS = {"bytes", "nbytes"}
_FLOAT_DTYPES = {"float32", "float64", "float16", "bfloat16"}


def _byte_named(target: ast.expr) -> str | None:
    name = terminal_name(target)
    if name is not None and (ident_tokens(name) & _BYTE_WORDS):
        return name
    return None


def _float_marker(tree: ast.AST) -> str | None:
    """A float-dtype mention inside an expression, if any."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in _FLOAT_DTYPES:
            return node.attr
        if isinstance(node, ast.Call):
            fn = terminal_name(node.func)
            if fn == "astype" and any(
                    isinstance(a, ast.Name) and a.id == "float"
                    for a in node.args):
                return "float"
    return None


@rule("float-byte-counter",
      "byte/comm counters must not be created or accumulated in a float "
      "dtype (float32 loses byte-resolution past 2^24); use the split "
      "int32 (MiB, remainder) idiom from core/accounting.py")
def check(ctx, src):
    for node in src.walk():
        if isinstance(node, ast.AugAssign):
            targets, value = [node.target], node.value
        elif isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        else:
            continue
        for t in targets:
            name = _byte_named(t)
            if name is None:
                continue
            marker = _float_marker(value)
            if marker is None:
                continue
            yield Finding(
                rule="float-byte-counter", path=src.path,
                line=node.lineno, col=node.col_offset,
                message=f"byte counter {name!r} built/accumulated via "
                        f"{marker}: float cells lose byte increments past "
                        "2^24; carry split int32 (MiB, remainder) counters "
                        "with carry_bytes (core/accounting.py)")

"""Rule ``mask-multiply-select`` — the PR 6 negative-zero bug class.

History: the first top-k packing draft selected kept entries as
``payload = keep * pending``. For a suppressed entry that multiply yields
``±0.0`` with the *sign of the payload* — and a later bitwise comparison
(or an exact-residual telescoping check) sees ``-0.0 != +0.0``. The shipped
kernel uses a ``where``-select precisely so ``-0.0`` survives
(``tests/transport_conformance.py`` salts negative zeros to pin it).

The rule flags multiplications where exactly one operand is mask-like and
the product is used *bare* (assigned, returned, passed along) — a select.
The two blessed blend forms stay silent, because their arithmetic is the
documented bit-alignment contract, not a select:

  * bank advance / additive blend: ``base + mask * delta``;
  * complementary blend: ``mask * a + (1 - mask) * b``

(both appear as operands of an enclosing ``+``/``-``, which is the
structural signal the rule keys on). Mask-AND products of two indicator
masks (``participate * censor_pass``) are also fine — both operands are
mask-like.
"""
from __future__ import annotations

import ast

from ..asthelpers import ident_tokens, terminal_name
from ..findings import Finding
from ..registry import rule

#: identifier words that make an operand mask-like
_MASK_WORDS = {"mask", "masks", "keep", "kp", "transmit", "send",
               "delivered", "participate"}

#: calls whose result is a broadcast mask
_MASK_CALLS = {"_bcast", "bcast", "broadcast_mask"}


def _is_masky(node: ast.expr) -> bool:
    name = terminal_name(node)
    if name is not None and (ident_tokens(name) & _MASK_WORDS):
        return True
    if isinstance(node, ast.Call):
        fn = terminal_name(node.func)
        if fn in _MASK_CALLS:
            return True
        # (x > t).astype(...) — a comparison turned indicator
        if fn == "astype" and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Compare):
            return True
    if isinstance(node, ast.Compare):
        return True
    return False


def _in_additive_context(src, node: ast.AST) -> bool:
    """True when the multiply is an operand of a surrounding +/- chain."""
    parent = src.parent(node)
    while isinstance(parent, ast.BinOp):
        if isinstance(parent.op, (ast.Add, ast.Sub)):
            return True
        parent = src.parent(parent)
    return False


@rule("mask-multiply-select",
      "bare `mask * payload` float selects lose the sign of suppressed "
      "entries (-0.0 becomes payload-signed zero); use "
      "jnp.where(mask != 0, x, zeros) — additive blends "
      "`base + mask * d` / `m*a + (1-m)*b` are exempt")
def check(ctx, src):
    for node in src.walk():
        if not (isinstance(node, ast.BinOp) and isinstance(node.op,
                                                           ast.Mult)):
            continue
        left_m, right_m = _is_masky(node.left), _is_masky(node.right)
        if left_m == right_m:       # neither (plain math) or both (AND)
            continue
        if _in_additive_context(src, node):
            continue
        mask_side = node.left if left_m else node.right
        mask_txt = terminal_name(mask_side) or "mask"
        yield Finding(
            rule="mask-multiply-select", path=src.path,
            line=node.lineno, col=node.col_offset,
            message=f"multiply-select by keep-mask {mask_txt!r}: "
                    "suppressed entries become payload-signed zeros "
                    "(-0.0 drift breaks bitwise anchors); select with "
                    "jnp.where(mask != 0, x, jnp.zeros_like(x))")

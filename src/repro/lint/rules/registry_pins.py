"""Rule ``registry-kind-unpinned`` — cross-file registry/test consistency.

The ``repro.opt`` registries are open: registering a new censor, transport,
or server kind instantly makes it reachable from every builder, sweep, and
JSON spec. The test suite pins behavior per *kind* — the transport
conformance suite parametrizes over ``TRANSPORT_KINDS`` at collection time
and the golden-fingerprint tables key hex fingerprints by kind string — so
a kind that exists in the registry but never appears in those files ships
unpinned: nothing fails when its numerics drift.

This project rule parses ``src/repro/opt/registry.py`` for the three
``*_KINDS`` dict literals and requires every key to appear as a string
literal in its pin files:

  * transport kinds -> ``tests/transport_conformance.py`` (the contract
    suite's kind vocabulary) AND ``tests/test_backend.py`` (the golden
    fingerprint tables);
  * censor + server kinds -> ``tests/test_opt.py`` (spec round-trip and
    golden tables).

It is a tripwire, not a coverage proof: the literal's presence is checked
textually (AST string constants), which is exactly the level at which the
"I registered a kind and forgot the goldens" mistake happens.  Outside a
repo with that layout the rule is silent.
"""
from __future__ import annotations

import ast

from ..asthelpers import dict_str_keys, str_constants
from ..findings import Finding
from ..registry import project_rule

_REGISTRY = "src/repro/opt/registry.py"
_PINS = {
    "TRANSPORT_KINDS": ("transport",
                        ("tests/transport_conformance.py",
                         "tests/test_backend.py")),
    "CENSOR_KINDS": ("censor", ("tests/test_opt.py",)),
    "SERVER_KINDS": ("server", ("tests/test_opt.py",)),
}


def _kind_tables(tree: ast.Module) -> dict[str, tuple[int, set[str]]]:
    """{table_name: (lineno, kind keys)} from the registry module."""
    out = {}
    for node in tree.body:
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        else:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and t.id in _PINS:
                keys = dict_str_keys(node.value)
                if keys:
                    out[t.id] = (node.lineno, keys)
    return out


@project_rule("registry-kind-unpinned",
              "every kind in the censor/transport/server registries must "
              "appear in the conformance-suite parametrization and the "
              "golden-fingerprint tables — an unpinned kind ships with no "
              "drift tripwire")
def check(ctx):
    registry_tree = ctx.read_project_file(_REGISTRY)
    if registry_tree is None:
        return
    tables = _kind_tables(registry_tree)
    pin_literals: dict[str, set[str] | None] = {}
    for _, (_, pin_files) in _PINS.items():
        for pf in pin_files:
            if pf not in pin_literals:
                tree = ctx.read_project_file(pf)
                pin_literals[pf] = None if tree is None \
                    else str_constants(tree)

    for table, (lineno, kinds) in tables.items():
        what, pin_files = _PINS[table]
        for kind in sorted(kinds):
            missing = [pf for pf in pin_files
                       if pin_literals.get(pf) is not None
                       and kind not in pin_literals[pf]]
            if missing:
                yield Finding(
                    rule="registry-kind-unpinned", path=_REGISTRY,
                    line=lineno, col=0,
                    message=f"{what} kind {kind!r} ({table}) is not "
                            f"pinned in {missing}: add it to the "
                            "conformance parametrization / golden tables "
                            "so numeric drift in it fails a test")

"""Rule ``vmap-in-draw-exact`` — the PR 2 ulp-drift bug class.

History: the sweep engine's first draft batched grid points with
``jax.vmap``; the batched gemms lower differently and drifted from
per-point ``simulator.run`` by ~1 ulp per iteration — enough to flip an
f32 censor decision near the eq.-(8) threshold and break the bit-exactness
anchor. The shipped engine maps points with ``lax.map`` (same per-point
subgraph, bit-identical) and offers ``vectorize=True`` as a *documented*
inexact opt-in. The low-rank transport later hit the same wall (vmapped
QR/orthonormalization) and uses explicit per-worker loops instead.

Functions marked ``@repro.lint.draw_exact`` (or modules setting
``__draw_exact__ = True``) carry that contract. Inside them the rule
forbids the batching forms known to drift:

  * ``jax.vmap`` (regroups reductions / relowers gemms);
  * gather-style batching: ``jnp.take``, ``jnp.take_along_axis``,
    ``jax.lax.gather`` (stacked-bank gathers perturb matmul lowering).

``lax.map`` and explicit per-slice Python loops are the compliant forms.
A deliberate exception (e.g. the engine's ``vectorize=True`` branch)
carries an inline suppression with its reason.
"""
from __future__ import annotations

import ast

from ..asthelpers import dotted, terminal_name
from ..findings import Finding
from ..registry import rule

_BANNED_CALLS = {
    "vmap": "jax.vmap regroups float reductions/matmuls (~1 ulp drift)",
    "take": "gather-style batching perturbs XLA lowering",
    "take_along_axis": "gather-style batching perturbs XLA lowering",
    "gather": "gather-style batching perturbs XLA lowering",
}


def _is_marked(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted(target) or ""
        if name == "draw_exact" or name.endswith(".draw_exact"):
            return True
    return False


def _module_marked(tree: ast.Module) -> bool:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__draw_exact__":
                    return True
    return False


@rule("vmap-in-draw-exact",
      "functions marked @repro.lint.draw_exact (and __draw_exact__ "
      "modules) must not use jax.vmap or gather-style batching — "
      "lax.map / explicit per-slice loops are the bit-exact forms")
def check(ctx, src):
    if src.tree is None:
        return
    module_wide = _module_marked(src.tree)
    roots = []
    if module_wide:
        roots = [src.tree]
    else:
        roots = [n for n in src.walk()
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and _is_marked(n)]
    seen: set[int] = set()
    for fn_node in roots:
        scope = getattr(fn_node, "name", src.path)
        for node in ast.walk(fn_node):
            if id(node) in seen or not isinstance(node, ast.Call):
                continue
            seen.add(id(node))
            name = terminal_name(node.func)
            if name not in _BANNED_CALLS:
                continue
            full = dotted(node.func) or name
            # bare-name take()/gather() of unrelated objects: require a
            # jax/jnp/lax chain for the gather family; vmap flags always
            if name != "vmap" and not any(
                    full.startswith(p) for p in ("jnp.", "jax.", "lax.",
                                                 "np.")):
                continue
            yield Finding(
                rule="vmap-in-draw-exact", path=src.path,
                line=node.lineno, col=node.col_offset,
                message=f"{full} inside draw-exact scope "
                        f"{scope!r}: {_BANNED_CALLS[name]}; use lax.map "
                        "or an explicit per-slice loop (docs/lint.md)")

"""Rule set: importing this package registers every built-in rule.

Each module encodes one hard-won repo invariant (the historical bug that
motivated it is documented in the module docstring and docs/lint.md).
"""
from . import (counters, draw_exact, hparams, interpret, masks,
               randomness, registry_pins)

__all__ = ["counters", "draw_exact", "hparams", "interpret", "masks",
           "randomness", "registry_pins"]

"""``python -m repro.lint`` entry point."""
import sys

from .cli import main

try:
    code = main()
    sys.stdout.flush()
except BrokenPipeError:
    # downstream pipe (e.g. `| head`) closed early; not a lint failure
    sys.stderr.close()
    code = 0
raise SystemExit(code)

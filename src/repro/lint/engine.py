"""Collection + execution: files in, findings out.

The engine parses each file once (AST + parent links + suppression
comments), hands the parse to every selected per-file rule, then runs the
project rules over the whole file set. Suppressions are applied last, so a
rule never needs to know about them.

Fixture hygiene: directory walks skip ``lint_fixtures`` directories (they
hold deliberately-bad snippets for tests/test_lint.py) along with caches;
explicitly-named files are always linted, which is how the fixture tests
lint the bad snippets on purpose.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, Optional

from . import registry, suppress
from .findings import Finding

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".ruff_cache",
              "node_modules", ".claude", "lint_fixtures"}


@dataclasses.dataclass
class SourceFile:
    """One parsed source file, shared by every rule."""
    path: str                       # as reported in findings (relative)
    abspath: str
    text: str
    tree: Optional[ast.AST]         # None when the file does not parse
    parents: dict                   # ast node -> parent node

    def walk(self):
        if self.tree is None:
            return
        yield from ast.walk(self.tree)

    def parent(self, node, levels: int = 1):
        for _ in range(levels):
            node = self.parents.get(node)
            if node is None:
                return None
        return node


@dataclasses.dataclass
class LintContext:
    """Everything the rules can see.

    Attributes:
      root: project root (auto-detected from a ``pyproject.toml``); rules
        that cross-check repo files (``registry-kind-unpinned``,
        ``baked-traced-hparam``'s kernel-signature table) resolve paths
        against it.
      files: every collected ``SourceFile``, in deterministic order.
    """
    root: str
    files: list = dataclasses.field(default_factory=list)
    _cache: dict = dataclasses.field(default_factory=dict)

    def read_project_file(self, relpath: str) -> Optional[ast.Module]:
        """Parse ``root``-relative ``relpath`` (cached); None if absent."""
        if relpath in self._cache:
            return self._cache[relpath]
        full = os.path.join(self.root, relpath)
        tree = None
        if os.path.isfile(full):
            try:
                with open(full) as fh:
                    tree = ast.parse(fh.read(), filename=full)
            except SyntaxError:
                tree = None
        self._cache[relpath] = tree
        return tree

    def project_glob(self, reldir: str) -> list[str]:
        """``root``-relative paths of the ``.py`` files under ``reldir``."""
        base = os.path.join(self.root, reldir)
        if not os.path.isdir(base):
            return []
        out = []
        for name in sorted(os.listdir(base)):
            if name.endswith(".py"):
                out.append(os.path.join(reldir, name))
        return out


def find_root(start: str) -> str:
    """Nearest ancestor of ``start`` holding a pyproject.toml (else start)."""
    cur = os.path.abspath(start)
    if os.path.isfile(cur):
        cur = os.path.dirname(cur)
    probe = cur
    while True:
        if os.path.isfile(os.path.join(probe, "pyproject.toml")):
            return probe
        parent = os.path.dirname(probe)
        if parent == probe:
            return cur
        probe = parent


def collect_files(paths: Iterable[str]) -> list[str]:
    """Expand files/directories into a sorted, de-duplicated .py file list."""
    out: list[str] = []
    seen: set[str] = set()

    def add(p: str) -> None:
        a = os.path.abspath(p)
        if a not in seen:
            seen.add(a)
            out.append(p)

    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in _SKIP_DIRS)
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        add(os.path.join(dirpath, fn))
        elif p.endswith(".py") or os.path.isfile(p):
            add(p)
    return out


def _parse(path: str, root: str) -> tuple[SourceFile, Optional[Finding]]:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    rel = os.path.relpath(os.path.abspath(path), root)
    rel = path if rel.startswith("..") else rel
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        src = SourceFile(path=rel, abspath=os.path.abspath(path),
                         text=text, tree=None, parents={})
        return src, Finding(rule="parse-error", path=rel,
                            line=e.lineno or 1, col=e.offset or 0,
                            message=f"syntax error: {e.msg}")
    parents: dict = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return SourceFile(path=rel, abspath=os.path.abspath(path), text=text,
                      tree=tree, parents=parents), None


def run_paths(paths: Iterable[str], *, root: Optional[str] = None,
              select: Optional[str] = None, ignore: Optional[str] = None
              ) -> list[Finding]:
    """Lint ``paths``; returns every finding (suppressed ones marked).

    Args:
      paths: files and/or directories (directories are walked for .py).
      root: project root override; default auto-detects via pyproject.toml.
      select/ignore: comma-separated rule names (see ``registry``).
    """
    from . import rules as _rules  # noqa: F401  (registers the rule set)
    file_list = collect_files(paths)
    if root is None:
        root = find_root(file_list[0] if file_list else os.getcwd())
    selected = registry.resolve_selection(select, ignore)
    known = set(registry.names())

    ctx = LintContext(root=os.path.abspath(root))
    findings: list[Finding] = []
    per_file: list[tuple[SourceFile, list[Finding]]] = []

    for path in file_list:
        src, parse_finding = _parse(path, ctx.root)
        ctx.files.append(src)
        file_findings: list[Finding] = []
        if parse_finding is not None:
            if "parse-error" in selected:
                file_findings.append(parse_finding)
        else:
            for name, fn in registry.file_rules(selected):
                file_findings.extend(fn(ctx, src))
        per_file.append((src, file_findings))

    project_findings: list[Finding] = []
    for name, fn in registry.project_rules(selected):
        project_findings.extend(fn(ctx))

    # attach project findings to their file's suppression table when the
    # file was part of this run; else they pass through unsuppressable
    by_path = {src.path: i for i, (src, _) in enumerate(per_file)}
    leftovers: list[Finding] = []
    for f in project_findings:
        i = by_path.get(f.path)
        if i is None:
            leftovers.append(f)
        else:
            per_file[i][1].append(f)

    for src, file_findings in per_file:
        sups, metas = suppress.parse(src.path, src.text, known)
        covered = suppress.apply(file_findings, sups)
        findings.extend(covered)
        findings.extend(m for m in metas if m.rule in selected
                        or m.rule in suppress.META_RULES)
    findings.extend(leftovers)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings

"""Inline suppression comments.

Grammar (one comment, trailing or standalone)::

    # repro-lint: disable=rule-a,rule-b -- <reason>
    # repro-lint: disable-file=rule-a -- <reason>

A trailing comment suppresses matching findings on its own physical line; a
standalone comment (nothing but whitespace before the ``#``) also
suppresses the next *code* line — intervening blank and comment-only lines
are skipped, so a wrapped explanation can sit between the directive and the
statement it covers. ``disable-file`` suppresses a rule for the whole file (put it at the
top). ``disable=all`` is deliberately not supported — suppressions are
per-rule so each one names the invariant it waives.

The reason is **required**: a suppression without the `` -- reason`` tail
is itself a finding (``suppression-missing-reason``), as is a suppression
naming a rule the registry doesn't know (``suppression-unknown-rule``).
Those meta-findings cannot be suppressed.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize

from .findings import Finding

# meta-rules emitted by this module (documented in --list-rules)
META_RULES = {
    "suppression-missing-reason":
        "a `# repro-lint: disable=` comment has no ` -- <reason>` tail; "
        "every waived invariant must say why it is safe to waive",
    "suppression-unknown-rule":
        "a suppression names a rule the registry doesn't know (typo, or "
        "the rule was renamed) — it would silently suppress nothing",
    "parse-error":
        "the file does not parse as Python; nothing in it was checked",
}

_COMMENT_RE = re.compile(
    r"#\s*repro-lint\s*:\s*(?P<kind>disable(?:-file)?)\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_,\s-]+?)\s*(?:--\s*(?P<reason>.*\S))?\s*$")


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One parsed suppression comment."""
    rules: frozenset[str]
    line: int               # physical line of the comment
    standalone: bool        # comment is alone on its line
    file_scope: bool        # disable-file
    reason: str
    target_line: int = 0    # next code line after a standalone comment


def parse(path: str, source: str, known_rules: set[str]
          ) -> tuple[list[Suppression], list[Finding]]:
    """All suppressions in ``source`` + meta-findings for malformed ones."""
    sups: list[Suppression] = []
    metas: list[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return [], []       # the engine reports parse-error separately
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _COMMENT_RE.search(tok.string)
        if m is None:
            continue
        line = tok.start[0]
        rules = frozenset(r.strip() for r in m.group("rules").split(",")
                          if r.strip())
        reason = m.group("reason")
        if not reason:
            metas.append(Finding(
                rule="suppression-missing-reason", path=path, line=line,
                col=tok.start[1],
                message="suppression must carry a reason: "
                        "`# repro-lint: disable=<rule> -- <why this is "
                        "safe>`"))
            continue
        unknown = sorted(rules - known_rules)
        if unknown:
            metas.append(Finding(
                rule="suppression-unknown-rule", path=path, line=line,
                col=tok.start[1],
                message=f"suppression names unknown rule(s) {unknown}; "
                        "see `python -m repro.lint --list-rules`"))
            rules = rules & known_rules
            if not rules:
                continue
        src_lines = source.splitlines()
        prefix = src_lines[line - 1][:tok.start[1]]
        standalone = not prefix.strip()
        target = 0
        if standalone:
            target = _next_code_line(src_lines, line)
            # comment-only lines between the directive and its code line
            # continue the reason (a wrapped explanation)
            for i in range(line, target - 1):
                cont = src_lines[i].strip().lstrip("#").strip()
                if cont:
                    reason = f"{reason} {cont}"
        sups.append(Suppression(
            rules=rules, line=line, standalone=standalone,
            file_scope=(m.group("kind") == "disable-file"),
            reason=reason, target_line=target))
    return sups, metas


def _next_code_line(lines: list[str], after: int) -> int:
    """First 1-based line past ``after`` that isn't blank or comment-only."""
    for i in range(after, len(lines)):
        stripped = lines[i].strip()
        if stripped and not stripped.startswith("#"):
            return i + 1
    return after + 1


def apply(findings: list[Finding], sups: list[Suppression]
          ) -> list[Finding]:
    """Mark findings covered by a suppression (returns a new list)."""
    by_line: dict[int, list[Suppression]] = {}
    file_wide: list[Suppression] = []
    for s in sups:
        if s.file_scope:
            file_wide.append(s)
            continue
        by_line.setdefault(s.line, []).append(s)
        if s.standalone:
            by_line.setdefault(s.target_line, []).append(s)

    out: list[Finding] = []
    for f in findings:
        hit = next(
            (s for s in by_line.get(f.line, []) + file_wide
             if f.rule in s.rules), None)
        if hit is not None:
            f = dataclasses.replace(f, suppressed=True, reason=hit.reason)
        out.append(f)
    return out

"""repro-lint: repo-aware static analysis for the exactness invariants.

CHB's censoring decision (eq. 8) is a threshold comparison: a single-ulp
drift or a flipped ``-0.0`` can change a transmit/suppress decision and
silently break the bit-exactness anchors the whole suite is pinned on.
This package turns the repo's postmortems (static-hparam retraces,
mask-multiply sign loss, float byte-counter overflow, vmap ulp drift,
silent interpret mode, unpinned registry kinds, unseeded RNG) into an
AST-based lint pass that fails CI before the bug lands.

CLI::

    python -m repro.lint [--json] [--select R1,R2] [--ignore R1] paths...
    python -m repro.lint --list-rules

Suppressions are inline, per-rule, and must carry a reason::

    x = keep * v  # repro-lint: disable=mask-multiply-select -- <why safe>

Public API: :func:`run_paths` (lint and get findings), :func:`draw_exact`
(marker decorator for the ``vmap-in-draw-exact`` rule), and the registry
(:func:`rule_names`, :func:`rule_docs`) mirroring the ``repro.opt`` idiom.
See docs/lint.md for the rule catalog.
"""
from .engine import LintContext, collect_files, find_root, run_paths
from .findings import (SCHEMA, Finding, load_artifact, make_artifact,
                       write_artifact)
from .markers import draw_exact
from .registry import docs as rule_docs
from .registry import names as rule_names
from .registry import project_rule, rule

__all__ = [
    "SCHEMA", "Finding", "LintContext", "collect_files", "draw_exact",
    "find_root", "load_artifact", "make_artifact", "project_rule", "rule",
    "rule_docs", "rule_names", "run_paths", "write_artifact",
]

"""Small shared AST utilities for the rule implementations."""
from __future__ import annotations

import ast
from typing import Optional


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def terminal_name(node: ast.AST) -> Optional[str]:
    """The last identifier of a Name/Attribute chain (``a.b.c`` -> ``c``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def ident_tokens(name: str) -> set[str]:
    """snake_case identifier -> its lowercase word set."""
    return {t for t in name.lower().split("_") if t}


def str_constants(tree: ast.AST) -> set[str]:
    """Every string literal in a tree (dict keys, parametrize args, ...)."""
    return {n.value for n in ast.walk(tree)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def keyword(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def is_bool_literal(node: Optional[ast.AST]) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, bool)


def str_elements(node: ast.AST) -> set[str]:
    """String elements of a tuple/list/set literal (or a lone string)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)}
    return set()


def dict_str_keys(node: ast.AST) -> set[str]:
    if not isinstance(node, ast.Dict):
        return set()
    return {k.value for k in node.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)}

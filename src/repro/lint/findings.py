"""Finding record + the JSON findings-artifact schema.

The artifact mirrors the ``repro.obs.bench`` idiom: schema-versioned JSON
with enough context to be diffed across commits (``tools/lint_diff.py``)
without the working tree that produced it.
"""
from __future__ import annotations

import dataclasses
import json
import sys
from typing import Optional

SCHEMA = "repro-lint-findings/v1"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location.

    Attributes:
      rule: registry name of the rule that fired (``repro.lint --list-rules``).
      path: file path, relative to the lint root when under it.
      line: 1-based source line of the offending node.
      col: 0-based column offset.
      message: human-readable description, specific to the call site.
      suppressed: True when an inline ``# repro-lint: disable=`` comment
        (with a reason) covers this finding; suppressed findings are
        reported in the artifact but do not fail the run.
      reason: the suppression's written reason (suppressed findings only).
    """
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False
    reason: Optional[str] = None

    def key(self) -> tuple:
        """Identity for cross-artifact diffing: line numbers shift under
        unrelated edits, so the key is (rule, path, message)."""
        return (self.rule, self.path, self.message)

    def to_dict(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "col": self.col, "message": self.message}
        if self.suppressed:
            d["suppressed"] = True
            d["reason"] = self.reason
        return d

    def render(self) -> str:
        tag = " [suppressed: {}]".format(self.reason) if self.suppressed \
            else ""
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.rule}: {self.message}{tag}"


def make_artifact(findings: list, *, rules: list, paths: list) -> dict:
    """The JSON findings artifact for a finished run."""
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    return {
        "schema": SCHEMA,
        "argv_paths": list(paths),
        "rules": sorted(rules),
        "counts": {
            "findings": len(active),
            "suppressed": len(suppressed),
            "by_rule": _by_rule(active),
        },
        "findings": [f.to_dict() for f in active],
        "suppressed": [f.to_dict() for f in suppressed],
    }


def _by_rule(findings: list) -> dict:
    out: dict[str, int] = {}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return dict(sorted(out.items()))


def load_artifact(path: str) -> dict:
    """Load + schema-check a findings artifact (lint_diff's entry point)."""
    with open(path) as fh:
        data = json.load(fh)
    if data.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: not a {SCHEMA} artifact "
            f"(schema={data.get('schema')!r})")
    for k in ("findings", "suppressed", "counts"):
        if k not in data:
            raise ValueError(f"{path}: artifact missing key {k!r}")
    return data


def write_artifact(artifact: dict, path: Optional[str]) -> None:
    text = json.dumps(artifact, indent=2, sort_keys=True)
    if path is None or path == "-":
        sys.stdout.write(text + "\n")
    else:
        with open(path, "w") as fh:
            fh.write(text + "\n")

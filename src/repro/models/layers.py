"""Transformer building blocks: norms, RoPE, GQA attention, MLPs.

Pure functional style: ``init_*`` builds a param dict, ``apply``-style
functions consume it. Everything is dtype-disciplined (params/activations in
cfg dtype, softmax/norm statistics in f32).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import tuning
from .flash import flash_attention

_NEG = -1e30


# ------------------------------------------------------------------ norms
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, H, d); positions: (L,) or broadcastable to x[...,:, 0, 0]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (L, half)
    cos = jnp.cos(ang)[..., None, :]  # (L, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, kh * hd)) * std).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, kh * hd)) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (h * hd, d))
               * (h * hd) ** -0.5).astype(dt),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_src):
    b, l, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, l, h, hd)
    k = (kv_src @ p["wk"]).reshape(b, kv_src.shape[1], kh, hd)
    v = (kv_src @ p["wv"]).reshape(b, kv_src.shape[1], kh, hd)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.rmsnorm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.rmsnorm_eps)
    return q, k, v


def attention(p: dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, *, window: Optional[int] = None,
              q_block: int = 512, kv_block: int = 512) -> jax.Array:
    """Causal self-attention over x: (B, L, D). positions: (L,)."""
    b, l, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # (B, H, L, d)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if tuning.enabled("attn_kv_replicate"):
        # q heads TP-sharded, kv heads replicated over model -> the flash kv
        # scan body is collective-free (§Perf hillclimb #2)
        def _q_spec(mesh):
            from jax.sharding import PartitionSpec as P
            dp = tuning.dp_axes_of(mesh)
            if "model" in mesh.axis_names and \
                    q.shape[1] % mesh.shape["model"] == 0:
                return P(dp, "model", None, None)
            return None

        def _kv_spec(mesh):
            from jax.sharding import PartitionSpec as P
            dp = tuning.dp_axes_of(mesh)
            return P(dp, None, None, None)

        q = tuning.constrain(q, _q_spec)
        k = tuning.constrain(k, _kv_spec)
        v = tuning.constrain(v, _kv_spec)
    o = flash_attention(q, k, v, causal=True, window=window,
                        q_block=q_block, kv_block=kv_block)
    o = o.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return o @ p["wo"]


def cross_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                    enc: jax.Array, q_block: int = 512,
                    kv_block: int = 512) -> jax.Array:
    """x: (B, L, D) queries; enc: (B, T, D) encoder states (projected)."""
    b, l, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, enc)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    o = flash_attention(q, k, v, causal=False, q_block=q_block,
                        kv_block=kv_block)
    o = o.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return o @ p["wo"]


def decode_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_pos: jax.Array, pos: jax.Array) -> jax.Array:
    """Single-token decode: x (B, 1, D) against a populated cache.

    k_cache/v_cache: (B, C, K, hd) — already contain the NEW token's k/v.
    cache_pos: (C,) absolute positions of each slot (-1 for empty).
    pos: () current absolute position.
    """
    b = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kh
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.rmsnorm_eps)
    q = rope(q, pos[None].astype(jnp.int32), cfg.rope_theta)
    q5 = q.reshape(b, 1, kh, g, hd).transpose(0, 2, 3, 1, 4)  # (B,K,G,1,hd)
    kt = k_cache.transpose(0, 2, 1, 3)[:, :, None]   # (B,K,1,C,hd)
    vt = v_cache.transpose(0, 2, 1, 3)[:, :, None]
    s = jnp.einsum("bkgqd,bkgcd->bkgqc", q5.astype(jnp.float32),
                   kt.astype(jnp.float32)) * hd ** -0.5
    valid = (cache_pos >= 0) & (cache_pos <= pos)
    s = jnp.where(valid[None, None, None, None, :], s, _NEG)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkgcd->bkgqd", pr, vt.astype(jnp.float32))
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * hd).astype(x.dtype)
    return o @ p["wo"]


def decode_cross_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                           k_cache: jax.Array, v_cache: jax.Array) -> jax.Array:
    """Decode-time cross-attention over static (encoder) KV: (B, T, K, hd)."""
    b = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kh
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    q5 = q.reshape(b, 1, kh, g, hd).transpose(0, 2, 3, 1, 4)
    kt = k_cache.transpose(0, 2, 1, 3)[:, :, None]
    vt = v_cache.transpose(0, 2, 1, 3)[:, :, None]
    s = jnp.einsum("bkgqd,bkgcd->bkgqc", q5.astype(jnp.float32),
                   kt.astype(jnp.float32)) * hd ** -0.5
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkgcd->bkgqd", pr, vt.astype(jnp.float32))
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * hd).astype(x.dtype)
    return o @ p["wo"]


def compute_kv(p: dict, cfg: ModelConfig, x: jax.Array,
               positions: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
    """k, v for cache fill: (B, L, K, hd); RoPE applied iff positions given."""
    b, l, _ = x.shape
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    k = (x @ p["wk"]).reshape(b, l, kh, hd)
    v = (x @ p["wv"]).reshape(b, l, kh, hd)
    if "k_norm" in p:
        k = rmsnorm(p["k_norm"], k, cfg.rmsnorm_eps)
    if positions is not None:
        k = rope(k, positions, cfg.rope_theta)
    return k, v


# -------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 3)
    std_in, std_out = d ** -0.5, f ** -0.5
    if cfg.activation == "swiglu":
        return {
            "wi": (jax.random.normal(ks[0], (d, f)) * std_in).astype(dt),
            "wg": (jax.random.normal(ks[1], (d, f)) * std_in).astype(dt),
            "wo": (jax.random.normal(ks[2], (f, d)) * std_out).astype(dt),
        }
    return {
        "wi": (jax.random.normal(ks[0], (d, f)) * std_in).astype(dt),
        "wo": (jax.random.normal(ks[2], (f, d)) * std_out).astype(dt),
    }


def mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(x @ p["wi"])
    else:
        raise ValueError(cfg.activation)
    if tuning.enabled("mlp_hidden_shard"):
        # pin the hidden to TP sharding — propagation around remat sometimes
        # replicates it and ARs full-width gradients (§Perf P2b)
        def _spec(mesh):
            from jax.sharding import PartitionSpec as P
            if "model" in mesh.axis_names and \
                    h.shape[-1] % mesh.shape["model"] == 0:
                return P(tuning.dp_axes_of(mesh), None, "model")
            return None
        h = tuning.constrain(h, _spec)
    return h @ p["wo"]

"""KV / SSM caches for decode.

Cache layout per sub-layer kind (stacked over superblocks for lax.scan):
  "A" full attention : {"k","v"}: (B, C, K, hd) with C = cache_len
  "S" sliding window : same with C = window (ring buffer, slot = pos % C)
  "M" mamba          : {"ssm": (B,H,N,P) f32, "conv": (B, W-1, conv_ch)}
  "X" cross-attn     : {"k","v"}: (B, T_enc, K, hd) — static after prefill

Slot-position bookkeeping is derived from the scalar `pos` (see slot_positions),
so no per-slot metadata is stored.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def effective_mixer(cfg: ModelConfig, mixer: str, long_mode: bool) -> tuple[str, int | None]:
    """Resolve (kind, window) given the long-context variant flag."""
    if mixer == "A":
        if long_mode and cfg.long_context_window:
            return "S", cfg.long_context_window
        return "A", None
    if mixer == "S":
        return "S", cfg.sliding_window
    return mixer, None


def slot_positions(pos: jax.Array, c: int) -> jax.Array:
    """Absolute position held by each of C ring slots given current pos.

    Slot i holds the latest q < pos with q % C == i; -1 if never written.
    """
    i = jnp.arange(c, dtype=jnp.int32)
    q = pos.astype(jnp.int32) - 1 - ((pos.astype(jnp.int32) - 1 - i) % c)
    return jnp.where(q >= 0, q, -1)


def _attn_cache(cfg: ModelConfig, b: int, c: int, dtype) -> dict:
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((b, c, kh, hd), dtype),
            "v": jnp.zeros((b, c, kh, hd), dtype)}


def _mamba_cache(cfg: ModelConfig, b: int, dtype) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state_dim
    return {"ssm": jnp.zeros((b, cfg.ssm_heads, cfg.ssm_state_dim,
                              cfg.ssm_head_dim), jnp.float32),
            "conv": jnp.zeros((b, cfg.ssm_conv_width - 1, conv_ch), dtype)}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               long_mode: bool = False):
    """Zeroed cache pytree, leaves stacked over superblocks (leading S axis)."""
    dtype = cfg.jnp_dtype
    plan = cfg.block_plan()

    def one_sublayer(mixer):
        kind, window = effective_mixer(cfg, mixer, long_mode)
        if kind == "A":
            return _attn_cache(cfg, batch, cache_len, dtype)
        if kind == "S":
            return _attn_cache(cfg, batch, min(window, cache_len), dtype)
        if kind == "M":
            return _mamba_cache(cfg, batch, dtype)
        if kind == "X":
            return _attn_cache(cfg, batch, max(cfg.num_frontend_tokens, 1),
                               dtype)
        raise ValueError(kind)

    block_cache = {f"l{i}": one_sublayer(mx) for i, (mx, _) in enumerate(plan)}
    s = cfg.num_superblocks
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (s,) + x.shape).copy(), block_cache)


def write_kv(cache: dict, k_new: jax.Array, v_new: jax.Array,
             pos: jax.Array) -> dict:
    """Write one token's k/v (B, 1, K, hd) at ring slot pos % C."""
    c = cache["k"].shape[1]
    slot = (pos % c).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    return {"k": k, "v": v}


def fill_from_prefill(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                      c: int) -> dict:
    """Arrange prefill k/v (B, L, K, hd) into a C-slot ring cache."""
    l = k.shape[1]
    i = jnp.arange(c, dtype=jnp.int32)
    src = l - 1 - ((l - 1 - i) % c)          # latest pos per slot
    src_c = jnp.clip(src, 0, l - 1)
    return {"k": jnp.take(k, src_c, axis=1),
            "v": jnp.take(v, src_c, axis=1)}

"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

TPU adaptation notes (DESIGN.md §3): the CUDA reference uses a fused
selective-scan kernel; here we implement the *chunked dual form*, which maps
the recurrence onto MXU-friendly matmuls: within-chunk attention-like
(Q x Q) blocks + an inter-chunk lax.scan over running states. Chunk length
is a config knob (`ssm_chunk`) chosen so the (Q, Q, H) score block fits VMEM
budgets on real hardware.

Single-group (G=1) B/C projections, per-head decay (standard Mamba-2).
Decode is the O(1) recurrent step with (state, conv) caches.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def init_mamba(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state_dim
    h = cfg.ssm_heads
    w = cfg.ssm_conv_width
    dt = cfg.jnp_dtype
    conv_ch = di + 2 * n                     # x, B, C share the causal conv
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        # SPLIT input projections (z / xBC / dt): math-identical to the
        # reference fused w_in, but each output is independently TP-sharded;
        # the fused layout slices at shard-misaligned offsets and XLA
        # re-gathers the full activation per layer (§Perf P3a).
        "w_z": (jax.random.normal(ks[0], (d, di)) * std).astype(dt),
        "w_xbc": (jax.random.normal(ks[4], (d, conv_ch)) * std).astype(dt),
        "w_dt": (jax.random.normal(ks[5], (d, h)) * std).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (w, conv_ch)) * w ** -0.5
                   ).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((h,), jnp.float32),       # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dt)},      # gated RMSNorm
        "w_out": (jax.random.normal(ks[3], (di, d)) * di ** -0.5).astype(dt),
    }


def _split_proj(p, cfg: ModelConfig, x):
    """Three shard-aligned projections (see init_mamba note)."""
    return x @ p["w_z"], x @ p["w_xbc"], x @ p["w_dt"]


def _causal_conv(p, x):
    """Depthwise causal conv over (B, L, C) with kernel (W, C)."""
    w = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i][None, None, :]
              for i in range(w))
    return out + p["conv_b"]


def _gated_rmsnorm(p, y, z, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    out = yf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(y.dtype)


def ssd_chunked(x, dt, a_neg, b_proj, c_proj, chunk: int):
    """Chunked SSD scan.

    x:  (B, L, H, P) inputs per head
    dt: (B, L, H)    positive step sizes
    a_neg: (H,)      negative per-head decay rate A
    b_proj, c_proj: (B, L, N)  shared across heads (G=1)
    Returns y: (B, L, H, P) and final state (B, H, N, P).
    """
    bsz, l_orig, h, p_dim = x.shape
    n = b_proj.shape[-1]
    q = min(chunk, l_orig)
    pad = (-l_orig) % q
    if pad:
        # zero-pad to a chunk multiple; dt=0 rows carry no state and their
        # outputs are sliced off below
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_proj = jnp.pad(b_proj, ((0, 0), (0, pad), (0, 0)))
        c_proj = jnp.pad(c_proj, ((0, 0), (0, pad), (0, 0)))
    l = l_orig + pad
    nc = l // q

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    la = dtf * a_neg[None, None, :]                        # log decay (B,L,H)

    def ck(t, shape_tail):  # reshape (B, L, ...) -> (B, nc, q, ...)
        return t.reshape((bsz, nc, q) + shape_tail)

    x_c = ck(xf, (h, p_dim))
    dt_c = ck(dtf, (h,))
    la_c = ck(la, (h,))
    b_c = ck(b_proj.astype(jnp.float32), (n,))
    c_c = ck(c_proj.astype(jnp.float32), (n,))

    lcum = jnp.cumsum(la_c, axis=2)                        # (B,nc,q,H)
    seg_total = lcum[:, :, -1, :]                          # (B,nc,H)

    # ---- within-chunk (attention-like) term
    scores = jnp.einsum("bcqn,bckn->bcqk", c_c, b_c)       # (B,nc,q,q)
    decay = jnp.exp(lcum[:, :, :, None, :] - lcum[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((q, q), jnp.float32))
    m = scores[..., None] * decay * causal[None, None, :, :, None]
    y_diag = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", m, dt_c, x_c)

    # ---- per-chunk end states
    w_state = jnp.exp(seg_total[:, :, None, :] - lcum) * dt_c  # (B,nc,q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", b_c, w_state, x_c)

    # ---- inter-chunk recurrence over nc
    chunk_decay = jnp.exp(seg_total)                       # (B,nc,H)

    def step(s_prev, inp):
        st, dec = inp                                      # (B,H,N,P), (B,H)
        s_out = s_prev                                     # state BEFORE chunk
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_out

    states_t = jnp.moveaxis(states, 1, 0)                  # (nc,B,H,N,P)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)              # (nc,B,H)
    s0 = jnp.zeros((bsz, h, n, p_dim), jnp.float32)
    s_final, s_prior = jax.lax.scan(step, s0, (states_t, decay_t))
    s_prior = jnp.moveaxis(s_prior, 0, 1)                  # (B,nc,H,N,P)

    # ---- off-chunk contribution
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                       c_c, jnp.exp(lcum), s_prior)
    y = (y_diag + y_off).reshape(bsz, l, h, p_dim)
    return y[:, :l_orig], s_final


def mamba_forward(p: dict, cfg: ModelConfig, x: jax.Array
                  ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence forward. x: (B, L, D). Returns (y, (ssm_state, conv_tail))."""
    bsz, l, _ = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dtr = _split_proj(p, cfg, x)
    xbc_pre = xbc                                           # pre-conv (for cache)
    xbc = jax.nn.silu(_causal_conv(p, xbc))
    xs = xbc[..., :di].reshape(bsz, l, h, pd)
    b_proj = xbc[..., di:di + n]
    c_proj = xbc[..., di + n:]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["a_log"])
    y, state = ssd_chunked(xs, dt, a_neg, b_proj, c_proj, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, di).astype(x.dtype)
    out = _gated_rmsnorm(p["norm"], y, z, cfg.rmsnorm_eps) @ p["w_out"]
    w = cfg.ssm_conv_width
    conv_tail = xbc_pre[:, l - (w - 1):, :]                # (B, W-1, conv_ch)
    return out, (state.astype(jnp.float32), conv_tail)


def mamba_decode_step(p: dict, cfg: ModelConfig, x: jax.Array,
                      ssm_state: jax.Array, conv_state: jax.Array
                      ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """O(1) recurrent decode. x: (B, 1, D).

    ssm_state: (B, H, N, P) f32; conv_state: (B, W-1, conv_ch) — the last
    W-1 *pre-conv* xBC rows.
    """
    bsz = x.shape[0]
    di, n, h, pd = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc_new, dtr = _split_proj(p, cfg, x)               # (B,1,*)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)  # (B,W,conv_ch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)                            # (B, conv_ch)
    xs = xbc[..., :di].reshape(bsz, h, pd)
    b_proj = xbc[..., di:di + n]                           # (B,N)
    c_proj = xbc[..., di + n:]
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * (-jnp.exp(p["a_log"]))[None, :])      # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, b_proj.astype(jnp.float32),
                     xs.astype(jnp.float32))
    new_state = ssm_state * a[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c_proj.astype(jnp.float32), new_state)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    out = _gated_rmsnorm(p["norm"], y, z, cfg.rmsnorm_eps) @ p["w_out"]
    new_conv = window[:, 1:, :]
    return out, (new_state, new_conv)

from . import flash, kvcache, layers, mamba2, model, moe

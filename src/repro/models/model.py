"""LM composition: embeddings -> pattern-driven blocks -> head.

Three entry points, matching the input shapes:
  * train_loss / train forward  — full sequence, flash attention, chunked CE
  * prefill                     — full sequence, returns (last_logits, cache)
  * serve_step                  — one token against a cache (decode shapes)

Layers are scanned over superblocks (cfg.scan_period sub-layers per scan
step) with optional remat, keeping HLO size O(period) instead of O(layers).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import kvcache, layers, mamba2, moe, tuning
from .kvcache import effective_mixer


# --------------------------------------------------------------- utilities
def _pick_block(l: int, target: int) -> int:
    for b in range(min(target, l), 0, -1):
        if l % b == 0:
            return b
    return 1


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ------------------------------------------------------------------- init
def _init_layer(key, cfg: ModelConfig, mixer: str, is_moe: bool) -> dict:
    dt = cfg.jnp_dtype
    k1, k2 = jax.random.split(key)
    p = {"norm1": layers.init_rmsnorm(cfg.d_model, dt),
         "norm2": layers.init_rmsnorm(cfg.d_model, dt)}
    if mixer in ("A", "S"):
        p["mixer"] = layers.init_attention(k1, cfg)
    elif mixer == "X":
        p["mixer"] = layers.init_attention(k1, cfg, cross=True)
    elif mixer == "M":
        p["mixer"] = mamba2.init_mamba(k1, cfg)
    else:
        raise ValueError(mixer)
    if is_moe:
        p["ffn"] = moe.init_moe(k2, cfg)
    elif cfg.d_ff > 0:
        p["ffn"] = layers.init_mlp(k2, cfg)
    else:
        del p["norm2"]  # pure-mixer block (e.g. mamba2 has no MLP)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    plan = cfg.block_plan()
    s = cfg.num_superblocks
    keys = jax.random.split(key, s + 3)
    dt = cfg.jnp_dtype
    emb = (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
           * cfg.d_model ** -0.5).astype(dt)
    params: dict[str, Any] = {
        "embed": emb,
        "final_norm": layers.init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
        ).astype(dt)
    if cfg.frontend:
        params["frontend_proj"] = (jax.random.normal(
            keys[2], (cfg.d_frontend, cfg.d_model))
            * cfg.d_frontend ** -0.5).astype(dt)

    def one_superblock(k):
        ks = jax.random.split(k, len(plan))
        return {f"l{i}": _init_layer(ks[i], cfg, mx, mo)
                for i, (mx, mo) in enumerate(plan)}

    blocks = [one_superblock(keys[3 + i]) for i in range(s)]
    params["blocks"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *blocks) if s > 1 else \
        jax.tree_util.tree_map(lambda x: x[None], blocks[0])
    return params


def param_count(cfg: ModelConfig) -> int:
    import math
    shapes = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    return sum(math.prod(x.shape)
               for x in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k of experts)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    # subtract inactive expert weights
    plan = cfg.layer_plan()
    n_moe = sum(1 for _, mo in plan if mo)
    per_expert = cfg.d_model * cfg.d_ff_expert * (
        3 if cfg.activation == "swiglu" else 2)
    inactive = n_moe * (cfg.num_experts - cfg.num_experts_per_tok) * per_expert
    return total - inactive


# ---------------------------------------------------------------- forward
def _apply_layer(lp: dict, cfg: ModelConfig, x, positions, enc, spec,
                 long_mode: bool, moe_mode: str):
    mixer, is_moe = spec
    kind, window = effective_mixer(cfg, mixer, long_mode)
    h = layers.rmsnorm(lp["norm1"], x, cfg.rmsnorm_eps)
    l = x.shape[1]
    qb = _pick_block(l, 512)
    if kind in ("A", "S"):
        mo = layers.attention(lp["mixer"], cfg, h, positions, window=window,
                              q_block=qb, kv_block=qb)
    elif kind == "X":
        mo = layers.cross_attention(lp["mixer"], cfg, h, enc, q_block=qb,
                                    kv_block=_pick_block(enc.shape[1], 512))
    elif kind == "M":
        mo, _ = mamba2.mamba_forward(lp["mixer"], cfg, h)
    else:
        raise ValueError(kind)
    x = x + mo
    if "ffn" not in lp:
        return x, jnp.zeros((), jnp.float32)
    h2 = layers.rmsnorm(lp["norm2"], x, cfg.rmsnorm_eps)
    if is_moe:
        f, aux = moe.moe_apply(lp["ffn"], cfg, h2, mode=moe_mode)
    else:
        f, aux = layers.mlp(lp["ffn"], cfg, h2), jnp.zeros((), jnp.float32)
    return x + f, aux


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
            enc_embeddings: Optional[jax.Array] = None, *,
            long_mode: bool = False, moe_mode: str = "scan",
            remat: str = "full", act_spec=None) -> jax.Array:
    """Returns final hidden states (B, L_total, D).

    audio frontends prepend projected frame embeddings as a prefix; vlm
    frontends feed cross-attention layers.
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    enc = None
    if cfg.frontend:
        enc = enc_embeddings @ params["frontend_proj"]
        if cfg.frontend == "audio":
            x = jnp.concatenate([enc.astype(x.dtype), x], axis=1)
            enc = None
    x = _constrain(x, act_spec)
    l_total = x.shape[1]
    positions = jnp.arange(l_total, dtype=jnp.int32)
    plan = cfg.block_plan()

    def superblock(carry, block_params):
        h, aux = carry
        h = _constrain(h, act_spec)
        if tuning.enabled("seq_parallel"):
            # Megatron-style sequence parallelism: residuals live L-sharded
            # over the model axis between blocks, turning per-layer dgrad
            # all-reduces into reduce-scatter+all-gather (§Perf P2c/P3c)
            def _sp(mesh):
                from jax.sharding import PartitionSpec as P
                if "model" in mesh.axis_names and \
                        h.shape[1] % mesh.shape["model"] == 0:
                    return P(tuning.dp_axes_of(mesh), "model", None)
                return None
            h = tuning.constrain(h, _sp)
        for i, spec in enumerate(plan):
            h, a = _apply_layer(block_params[f"l{i}"], cfg, h, positions,
                                enc, spec, long_mode, moe_mode)
            aux = aux + a
        return (h, aux), None

    if remat == "full":
        superblock = jax.checkpoint(
            superblock, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        superblock = jax.checkpoint(
            superblock,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    (x, aux), _ = jax.lax.scan(superblock, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    return x, aux


def _lm_head(params: dict, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_xent(x: jax.Array, w_head: jax.Array, labels: jax.Array,
                 chunk: int = 256) -> jax.Array:
    """Mean cross-entropy without materializing (B, L, V) logits."""
    b, l, d = x.shape
    ck = _pick_block(l, chunk)
    nc = l // ck
    xc = x.reshape(b, nc, ck, d).transpose(1, 0, 2, 3)       # (nc,B,ck,D)
    yc = labels.reshape(b, nc, ck).transpose(1, 0, 2)

    if tuning.enabled("xent_fused"):
        def _wspec(mesh):
            from jax.sharding import PartitionSpec as P
            if "model" in mesh.axis_names and \
                    w_head.shape[-1] % mesh.shape["model"] != 0:
                # tied head with non-divisible vocab: replicate the head
                # (one 150 MB gather) instead of AR-ing every full-logit
                # chunk (GBs per chunk; §Perf P2c/P3b)
                return P(None, None)
            return None
        w_head = tuning.constrain(w_head, _wspec)

    @jax.checkpoint
    def body(tot, xy):
        xb, yb = xy
        if tuning.enabled("xent_fused"):
            def _xspec(mesh):
                from jax.sharding import PartitionSpec as P
                return P(tuning.dp_axes_of(mesh), None, None)
            xb = tuning.constrain(xb, _xspec)
        logits = (xb @ w_head).astype(jnp.float32)           # (B,ck,V)
        if tuning.enabled("xent_fused"):
            def _spec(mesh):
                from jax.sharding import PartitionSpec as P
                dp = tuning.dp_axes_of(mesh)
                if "model" in mesh.axis_names and \
                        logits.shape[-1] % mesh.shape["model"] == 0:
                    return P(dp, None, "model")
                return None
            logits = tuning.constrain(logits, _spec)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gather-free gold pick: fused iota-compare + reduce. (A gather from
        # a (data x model)-sharded operand trips XLA's partitioner inside
        # partial-manual shard_map regions, and this is TP-vocab friendly.)
        v = logits.shape[-1]
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        gold = jnp.sum(jnp.where(iota == yb[..., None], logits, 0.0),
                       axis=-1)
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    return tot / (b * l)


def train_loss(params: dict, cfg: ModelConfig, batch: dict, *,
               moe_mode: str = "scan", remat: str = "full",
               act_spec=None) -> tuple[jax.Array, dict]:
    x, aux = forward(params, cfg, batch["tokens"],
                     batch.get("enc_embeddings"), moe_mode=moe_mode,
                     remat=remat, act_spec=act_spec,
                     long_mode=batch.get("long_mode", False))
    if cfg.frontend == "audio":          # loss only over the token region
        x = x[:, -batch["tokens"].shape[1]:]
    loss = chunked_xent(x, _lm_head(params, cfg), batch["labels"])
    total = loss + cfg.router_aux_coef * aux
    return total, {"xent": loss, "router_aux": aux}


# ---------------------------------------------------------------- prefill
def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            enc_embeddings: Optional[jax.Array] = None, *,
            cache_len: Optional[int] = None, long_mode: bool = False,
            moe_mode: str = "scan", act_spec=None):
    """Full-sequence pass that returns (last_token_logits, populated cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    enc = None
    if cfg.frontend:
        enc = enc_embeddings @ params["frontend_proj"]
        if cfg.frontend == "audio":
            x = jnp.concatenate([enc.astype(x.dtype), x], axis=1)
            enc = None
    x = _constrain(x, act_spec)
    l_total = x.shape[1]
    cache_len = cache_len or l_total
    positions = jnp.arange(l_total, dtype=jnp.int32)
    plan = cfg.block_plan()

    def superblock(h, block_params):
        h = _constrain(h, act_spec)
        caches = {}
        for i, (mixer, is_moe) in enumerate(plan):
            lp = block_params[f"l{i}"]
            kind, window = effective_mixer(cfg, mixer, long_mode)
            hn = layers.rmsnorm(lp["norm1"], h, cfg.rmsnorm_eps)
            qb = _pick_block(l_total, 512)
            if kind in ("A", "S"):
                mo = layers.attention(lp["mixer"], cfg, hn, positions,
                                      window=window, q_block=qb, kv_block=qb)
                k, v = layers.compute_kv(lp["mixer"], cfg, hn, positions)
                c = cache_len if kind == "A" else min(window, cache_len)
                caches[f"l{i}"] = kvcache.fill_from_prefill(cfg, k, v, c)
            elif kind == "X":
                mo = layers.cross_attention(lp["mixer"], cfg, hn, enc,
                                            q_block=qb)
                k, v = layers.compute_kv(lp["mixer"], cfg, enc, None)
                caches[f"l{i}"] = {"k": k, "v": v}
            elif kind == "M":
                mo, (ssm, conv) = mamba2.mamba_forward(lp["mixer"], cfg, hn)
                caches[f"l{i}"] = {"ssm": ssm, "conv": conv}
            h = h + mo
            if "ffn" in lp:
                h2 = layers.rmsnorm(lp["norm2"], h, cfg.rmsnorm_eps)
                if is_moe:
                    f, _ = moe.moe_apply(lp["ffn"], cfg, h2, mode=moe_mode)
                else:
                    f = layers.mlp(lp["ffn"], cfg, h2)
                h = h + f
        return h, caches

    x, cache = jax.lax.scan(superblock, x, params["blocks"])
    x = layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    last = x[:, -1, :] @ _lm_head(params, cfg)
    return last.astype(jnp.float32), cache


# ----------------------------------------------------------------- decode
def serve_step(params: dict, cfg: ModelConfig, cache, tokens: jax.Array,
               pos: jax.Array, *, long_mode: bool = False,
               moe_mode: str = "scan", act_spec=None):
    """One decode step. tokens: (B, 1) int32; pos: () current position.

    Returns (logits (B, V) f32, new_cache).
    """
    x = jnp.take(params["embed"], tokens, axis=0)      # (B,1,D)
    plan = cfg.block_plan()

    def superblock(h, xs):
        block_params, block_cache = xs
        new_cache = {}
        for i, (mixer, is_moe) in enumerate(plan):
            lp = block_params[f"l{i}"]
            cc = block_cache[f"l{i}"]
            kind, _ = effective_mixer(cfg, mixer, long_mode)
            hn = layers.rmsnorm(lp["norm1"], h, cfg.rmsnorm_eps)
            if kind in ("A", "S"):
                k, v = layers.compute_kv(lp["mixer"], cfg, hn,
                                         pos[None].astype(jnp.int32))
                cc = kvcache.write_kv(cc, k, v, pos)
                cpos = kvcache.slot_positions(pos + 1, cc["k"].shape[1])
                mo = layers.decode_attention(lp["mixer"], cfg, hn, cc["k"],
                                             cc["v"], cpos, pos)
            elif kind == "X":
                mo = layers.decode_cross_attention(lp["mixer"], cfg, hn,
                                                   cc["k"], cc["v"])
            elif kind == "M":
                mo, (ssm, conv) = mamba2.mamba_decode_step(
                    lp["mixer"], cfg, hn, cc["ssm"], cc["conv"])
                cc = {"ssm": ssm, "conv": conv}
            h = h + mo
            if "ffn" in lp:
                h2 = layers.rmsnorm(lp["norm2"], h, cfg.rmsnorm_eps)
                if is_moe:
                    f, _ = moe.moe_apply(lp["ffn"], cfg, h2, mode=moe_mode)
                else:
                    f = layers.mlp(lp["ffn"], cfg, h2)
                h = h + f
            new_cache[f"l{i}"] = cc
        return h, new_cache

    x, new_cache = jax.lax.scan(superblock, x, (params["blocks"], cache))
    x = layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = (x[:, 0, :] @ _lm_head(params, cfg)).astype(jnp.float32)
    return logits, new_cache

"""Performance-tuning switches (§Perf hillclimb; EXPERIMENTS.md).

Every optimization is default-OFF so the paper-faithful baseline lowering is
always reproducible; dryrun.py --opt <name> (or set_flags()) enables them.

Flags
-----
moe_bank_gather
    Pre-gather each MoE expert bank across the FSDP axis ONCE per layer
    (sharding constraint to P(None, None, "model") before the expert scan).
    Baseline lowering re-gathers the bank inside every expert-scan step:
    the qwen3-moe train_4k HLO shows ~1.3M collective ops from 94 layers x
    4 workers x 128 experts.

attn_kv_replicate
    Constrain q to head-sharded P(dp, None, "model", None) (when divisible)
    and k/v to model-replicated before flash attention, so the kv scan body
    is collective-free. Baseline lets XLA reshard per flash step when
    kv-heads % model != 0 (GQA).

xent_fused
    Keep the CE chunk's logits model-sharded end-to-end (constraint after
    the head matmul) instead of letting XLA gather logits per chunk.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

FLAGS = {
    "moe_bank_gather": False,
    "moe_expert_parallel": False,
    "attn_kv_replicate": False,
    "xent_fused": False,
    "mlp_hidden_shard": False,
    "seq_parallel": False,
}


def set_flags(**kw) -> None:
    for k, v in kw.items():
        if k not in FLAGS:
            raise KeyError(k)
        FLAGS[k] = bool(v)


@contextlib.contextmanager
def flags(**kw) -> Iterator[None]:
    old = dict(FLAGS)
    try:
        set_flags(**kw)
        yield
    finally:
        FLAGS.update(old)


def enabled(name: str) -> bool:
    return FLAGS[name]


# -------------------------------------------------------- mesh-aware helpers
_MESH = None


def set_mesh(mesh) -> None:
    """Register the mesh used to resolve tuning constraints (the classic
    `with mesh:` context does not populate jax.sharding.get_mesh())."""
    global _MESH
    _MESH = mesh


def current_mesh():
    """The registered mesh, or whatever the new-style getters expose."""
    if _MESH is not None:
        return _MESH
    import jax
    for getter in ("get_mesh", "get_abstract_mesh"):
        try:
            m = getattr(jax.sharding, getter)()
            if m is not None and getattr(m, "axis_names", ()):
                return m
        except Exception:
            continue
    return None


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, make_spec):
    """with_sharding_constraint(x, make_spec(mesh)) if a mesh is registered
    and make_spec returns a spec (None -> leave untouched)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = make_spec(mesh)
    if spec is None:
        return x
    if isinstance(spec, PartitionSpec):
        spec = NamedSharding(mesh, spec)
    return jax.lax.with_sharding_constraint(x, spec)

"""Blocked (flash) attention in pure JAX with a custom VJP.

Memory-bounded attention used for training/prefill at long sequence lengths:
never materializes the (Lq, S) score matrix; forward keeps only (O, LSE).
Backward recomputes per-block probabilities (FlashAttention-2 equations).

Supports GQA natively (q heads H = K kv-heads * G groups), causal masking and
sliding-window masking. This is also the reference semantics for the Pallas
TPU kernel in repro/kernels/flash_attention.py.

Shapes:
  q: (B, H, Lq, d)    k, v: (B, K, S, d)    out: (B, H, Lq, d)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

_NEG = -1e30


def _block_mask(qi, kj, bq, bk, q_offset, causal, window):
    """Bool mask (bq, bk) for query block qi vs kv block kj."""
    qpos = q_offset + qi * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        m = m & (kpos <= qpos)
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window, scale: float, bq: int, bk: int,
                q_offset: int):
    """Build a custom-vjp flash attention for fixed static settings."""

    def _sdot(a, b):
        # batched matmul that broadcasts the G=1 kv dim against q's G dim
        return jnp.einsum("...qd,...kd->...qk", a, b,
                          preferred_element_type=jnp.float32)

    def _fwd_blocks(q5, k, v):
        b, kh, g, lq, d = q5.shape
        s_len = k.shape[2]
        nq, nk = lq // bq, s_len // bk
        k5 = k[:, :, None]  # (B, K, 1, S, d)
        v5 = v[:, :, None]

        def per_qblock(qi):
            qblk = lax.dynamic_slice_in_dim(q5, qi * bq, bq, 3)

            def kv_step(carry, kj):
                acc, m_run, l_run = carry
                kblk = lax.dynamic_slice_in_dim(k5, kj * bk, bk, 3)
                vblk = lax.dynamic_slice_in_dim(v5, kj * bk, bk, 3)
                s = _sdot(qblk, kblk) * scale  # (B,K,G,bq,bk) f32
                mask = _block_mask(qi, kj, bq, bk, q_offset, causal, window)
                s = jnp.where(mask, s, _NEG)
                m_new = jnp.maximum(m_run, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m_run - m_new)
                l_new = l_run * alpha + p.sum(-1)
                acc = acc * alpha[..., None] + jnp.einsum(
                    "...qk,...kd->...qd", p, vblk.astype(jnp.float32))
                return (acc, m_new, l_new), None

            acc0 = jnp.zeros((b, kh, g, bq, d), jnp.float32)
            m0 = jnp.full((b, kh, g, bq), _NEG, jnp.float32)
            l0 = jnp.zeros((b, kh, g, bq), jnp.float32)
            (acc, m_run, l_run), _ = lax.scan(
                kv_step, (acc0, m0, l0), jnp.arange(nk))
            l_safe = jnp.maximum(l_run, 1e-37)
            o = acc / l_safe[..., None]
            lse = m_run + jnp.log(l_safe)
            return o.astype(q5.dtype), lse

        o, lse = lax.map(per_qblock, jnp.arange(nq))
        # o: (nq, B, K, G, bq, d) -> (B, K, G, Lq, d)
        o = jnp.moveaxis(o, 0, 3).reshape(b, kh, g, lq, d)
        lse = jnp.moveaxis(lse, 0, 3).reshape(b, kh, g, lq)
        return o, lse

    @jax.custom_vjp
    def flash(q5, k, v):
        return _fwd_blocks(q5, k, v)[0]

    def fwd(q5, k, v):
        o, lse = _fwd_blocks(q5, k, v)
        return o, (q5, k, v, o, lse)

    def bwd(res, do):
        q5, k, v, o, lse = res
        b, kh, g, lq, d = q5.shape
        s_len = k.shape[2]
        nq, nk = lq // bq, s_len // bk
        k5 = k[:, :, None]
        v5 = v[:, :, None]
        do_f = do.astype(jnp.float32)
        delta = jnp.sum(do_f * o.astype(jnp.float32), axis=-1)  # (B,K,G,Lq)

        def dq_block(qi):
            qblk = lax.dynamic_slice_in_dim(q5, qi * bq, bq, 3)
            doblk = lax.dynamic_slice_in_dim(do_f, qi * bq, bq, 3)
            lseblk = lax.dynamic_slice_in_dim(lse, qi * bq, bq, 3)
            dblk = lax.dynamic_slice_in_dim(delta, qi * bq, bq, 3)

            def kv_step(dq_acc, kj):
                kblk = lax.dynamic_slice_in_dim(k5, kj * bk, bk, 3)
                vblk = lax.dynamic_slice_in_dim(v5, kj * bk, bk, 3)
                s = _sdot(qblk, kblk) * scale
                mask = _block_mask(qi, kj, bq, bk, q_offset, causal, window)
                s = jnp.where(mask, s, _NEG)
                p = jnp.exp(s - lseblk[..., None])
                dp = jnp.einsum("...qd,...kd->...qk", doblk,
                                vblk.astype(jnp.float32))
                ds = p * (dp - dblk[..., None])
                dq_acc = dq_acc + scale * jnp.einsum(
                    "...qk,...kd->...qd", ds, kblk.astype(jnp.float32))
                return dq_acc, None

            dq0 = jnp.zeros((b, kh, g, bq, d), jnp.float32)
            dq_acc, _ = lax.scan(kv_step, dq0, jnp.arange(nk))
            return dq_acc

        dq = lax.map(dq_block, jnp.arange(nq))
        dq = jnp.moveaxis(dq, 0, 3).reshape(b, kh, g, lq, d).astype(q5.dtype)

        def dkv_block(kj):
            kblk = lax.dynamic_slice_in_dim(k5, kj * bk, bk, 3)
            vblk = lax.dynamic_slice_in_dim(v5, kj * bk, bk, 3)

            def q_step(carry, qi):
                dk_acc, dv_acc = carry
                qblk = lax.dynamic_slice_in_dim(q5, qi * bq, bq, 3)
                doblk = lax.dynamic_slice_in_dim(do_f, qi * bq, bq, 3)
                lseblk = lax.dynamic_slice_in_dim(lse, qi * bq, bq, 3)
                dblk = lax.dynamic_slice_in_dim(delta, qi * bq, bq, 3)
                s = _sdot(qblk, kblk) * scale
                mask = _block_mask(qi, kj, bq, bk, q_offset, causal, window)
                s = jnp.where(mask, s, _NEG)
                p = jnp.exp(s - lseblk[..., None])
                dv_acc = dv_acc + jnp.einsum("...qk,...qd->...kd", p, doblk)
                dp = jnp.einsum("...qd,...kd->...qk", doblk,
                                vblk.astype(jnp.float32))
                ds = p * (dp - dblk[..., None])
                dk_acc = dk_acc + scale * jnp.einsum(
                    "...qk,...qd->...kd", ds, qblk.astype(jnp.float32))
                return (dk_acc, dv_acc), None

            z = jnp.zeros((b, kh, g, bk, d), jnp.float32)
            (dk_acc, dv_acc), _ = lax.scan(q_step, (z, z), jnp.arange(nq))
            # sum over the q-group axis G -> kv gradient
            return dk_acc.sum(axis=2), dv_acc.sum(axis=2)

        dk, dv = lax.map(dkv_block, jnp.arange(nk))
        dk = jnp.moveaxis(dk, 0, 2).reshape(b, kh, s_len, d).astype(k.dtype)
        dv = jnp.moveaxis(dv, 0, 2).reshape(b, kh, s_len, d).astype(v.dtype)
        return dq, dk, dv

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    q_block: int = 512, kv_block: int = 512,
                    q_offset: int = 0):
    """Blocked attention. q: (B,H,Lq,d), k/v: (B,K,S,d), H = K*G."""
    b, h, lq, d = q.shape
    kh = k.shape[1]
    assert h % kh == 0, (h, kh)
    g = h // kh

    def _divisor(n: int, target: int) -> int:
        for cand in range(min(target, n), 0, -1):
            if n % cand == 0:
                return cand
        return 1

    bq = _divisor(lq, q_block)
    bk = _divisor(k.shape[2], kv_block)
    if scale is None:
        scale = d ** -0.5
    fn = _make_flash(causal, window, float(scale), bq, bk, int(q_offset))
    q5 = q.reshape(b, kh, g, lq, d)
    o = fn(q5, k, v)
    return o.reshape(b, h, lq, d)


def reference_attention(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        scale: float | None = None, q_offset: int = 0):
    """Naive O(L^2) oracle for tests."""
    b, h, lq, d = q.shape
    kh = k.shape[1]
    g = h // kh
    s_len = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    q5 = q.reshape(b, kh, g, lq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", q5.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(lq)[:, None]
    kpos = jnp.arange(s_len)[None, :]
    m = jnp.ones((lq, s_len), bool)
    if causal:
        m = m & (kpos <= qpos)
    if window is not None:
        m = m & (kpos > qpos - window)
    s = jnp.where(m, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, lq, d).astype(q.dtype)

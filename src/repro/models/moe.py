"""Mixture-of-Experts layer: top-k router + expert MLPs.

Two dispatch modes:
  * "dense": every expert computes every token, outputs weighted by the
    (sparse) gate matrix. Exact; used for tiny smoke configs and as the
    oracle in tests.
  * "scan": lax.scan over experts with per-expert token capacity
    C = ceil(L*k/E * capacity_factor). Each expert gathers its top-C tokens
    (by gate weight — overflow drops the lowest-gate tokens), runs the MLP,
    and scatter-adds back. Active-parameter FLOPs only; tiny live memory;
    HLO stays small for 128-expert configs. Gathers are batch-row local, so
    under data sharding they stay on-shard.

Router aux loss is the standard switch-style load-balance term.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from . import tuning


def init_moe(key, cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * std_in).astype(dt),
        "wi": (jax.random.normal(ks[1], (e, d, f)) * std_in).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, f, d)) * std_out).astype(dt),
    }
    if cfg.activation == "swiglu":
        p["wg"] = (jax.random.normal(ks[2], (e, d, f)) * std_in).astype(dt)
    return p


def _expert_mlp(cfg: ModelConfig, wi, wg, wo, x):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ wg) * (x @ wi)
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ wi))
    else:
        h = jax.nn.gelu(x @ wi)
    return h @ wo


def _route(p, cfg: ModelConfig, x):
    """x: (B, L, D) -> gates_full (B, L, E) sparse, aux loss scalar."""
    logits = (x @ p["router"]).astype(jnp.float32)          # (B,L,E)
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.num_experts_per_tok
    top_vals, top_idx = jax.lax.top_k(probs, k)             # (B,L,k)
    top_vals = top_vals / jnp.maximum(
        top_vals.sum(-1, keepdims=True), 1e-9)              # renormalize
    onehot = jax.nn.one_hot(top_idx, cfg.num_experts,
                            dtype=jnp.float32)              # (B,L,k,E)
    gates_full = (onehot * top_vals[..., None]).sum(axis=2)  # (B,L,E)
    # load-balance aux (Switch): E * sum_e mean(frac_e) * mean(prob_e)
    frac = (onehot.sum(axis=2)).mean(axis=(0, 1))           # (E,)
    mean_prob = probs.mean(axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(frac * mean_prob) / cfg.num_experts_per_tok
    return gates_full, aux


def moe_dense(p: dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Exact dense dispatch (oracle / tiny configs)."""
    gates, aux = _route(p, cfg, x)
    wg = p.get("wg")

    def one_expert(e):
        h = _expert_mlp(cfg, p["wi"][e], None if wg is None else wg[e],
                        p["wo"][e], x)
        return h * gates[..., e:e + 1].astype(x.dtype)

    y = sum(one_expert(e) for e in range(cfg.num_experts))
    return y, aux


def moe_scan(p: dict, cfg: ModelConfig, x: jax.Array,
             capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based scan-over-experts dispatch (scale path)."""
    b, l, d = x.shape
    if l == 1 and b > 1:
        # decode: route across the batch so experts see B tokens, not B calls
        y, aux = moe_scan(p, cfg, x.reshape(1, b, d), capacity_factor)
        return y.reshape(b, 1, d), aux
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = int(math.ceil(l * k / e * capacity_factor))
    cap = min(l, max(1, ((cap + 7) // 8) * 8))
    gates, aux = _route(p, cfg, x)                          # (B,L,E)
    gates_t = gates.transpose(2, 0, 1)                      # (E,B,L)
    wi, wg, wo = p["wi"], p.get("wg"), p["wo"]
    if tuning.enabled("moe_bank_gather"):
        # Gather the expert bank across the FSDP axis ONCE per layer, TP on
        # the expert-ff dim; without this the bank is re-gathered inside
        # every expert-scan step (§Perf hillclimb #1).
        def _in_spec(mesh):
            return P(None, None, "model") \
                if "model" in mesh.axis_names and \
                wi.shape[-1] % mesh.shape["model"] == 0 else None

        def _out_spec(mesh):
            return P(None, "model", None) \
                if "model" in mesh.axis_names and \
                wo.shape[1] % mesh.shape["model"] == 0 else None

        wi = tuning.constrain(wi, _in_spec)
        wo = tuning.constrain(wo, _out_spec)
        if wg is not None:
            wg = tuning.constrain(wg, _in_spec)
    has_g = wg is not None
    xs = (wi, wg, wo, gates_t) if has_g else (wi, wo, gates_t)

    def body(y, xs_e):
        if has_g:
            wi, wg, wo, g = xs_e
        else:
            wi, wo, g = xs_e
            wg = None
        vals, ids = jax.lax.top_k(g, cap)                   # (B,cap)
        xg = jnp.take_along_axis(x, ids[..., None], axis=1)  # (B,cap,D)
        h = _expert_mlp(cfg, wi, wg, wo, xg)
        h = h * vals[..., None].astype(x.dtype)
        y = y.at[jnp.arange(b)[:, None], ids].add(h)
        return y, None

    y0 = jnp.zeros_like(x)
    y, _ = jax.lax.scan(body, y0, xs)
    return y, aux


def moe_grouped(p: dict, cfg: ModelConfig, x: jax.Array,
                capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Grouped-einsum dispatch: all experts in ONE batched dot per matmul.

    Same capacity/drop policy as moe_scan, but the expert dimension is a
    dot_general batch dim, so TP partial-sum reduction happens once per
    layer instead of once per expert-scan step (§Perf hillclimb P1b).
    Costs (B, E, C, D)-shaped gathered activations transiently.
    """
    b, l, d = x.shape
    if l == 1 and b > 1:
        y, aux = moe_grouped(p, cfg, x.reshape(1, b, d), capacity_factor)
        return y.reshape(b, 1, d), aux
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = int(math.ceil(l * k / e * capacity_factor))
    cap = min(l, max(1, ((cap + 7) // 8) * 8))
    gates, aux = _route(p, cfg, x)                          # (B,L,E)
    gates_be = gates.transpose(0, 2, 1)                     # (B,E,L)
    vals, ids = jax.lax.top_k(gates_be, cap)                # (B,E,C)
    # keep the gating path in model dtype: f32 gate weights promote the
    # whole (B,E,C,D) backward to f32 (2x collective/HBM bytes; §Perf P1d)
    vals = vals.astype(x.dtype)
    bidx = jnp.arange(b)[:, None, None]
    xg = x[bidx, ids]                                       # (B,E,C,D)
    wi, wg, wo = p["wi"], p.get("wg"), p["wo"]
    if tuning.enabled("moe_expert_parallel"):
        # Expert parallelism: shard the E dim over "model"; the gathered
        # tokens move once via all-to-all (dispatch) instead of paying a TP
        # partial-sum all-reduce per matmul (§Perf P1e).
        def _w_spec(w):
            def f(mesh):
                if "model" in mesh.axis_names and \
                        w.shape[0] % mesh.shape["model"] == 0:
                    return P("model", None, None)
                return None
            return f

        def _xg_spec(mesh):
            if "model" in mesh.axis_names and \
                    e % mesh.shape["model"] == 0:
                return P(None, "model", None, None)
            return None

        wi = tuning.constrain(wi, _w_spec(wi))
        wo = tuning.constrain(wo, _w_spec(wo))
        if wg is not None:
            wg = tuning.constrain(wg, _w_spec(wg))
        xg = tuning.constrain(xg, _xg_spec)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xg, wg)) * \
            jnp.einsum("becd,edf->becf", xg, wi)
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("becd,edf->becf", xg, wi)))
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xg, wi))
    y_e = jnp.einsum("becf,efd->becd", h, wo)
    y_e = y_e * vals[..., None]
    y = jnp.zeros_like(x).at[bidx, ids].add(y_e)
    return y, aux


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              mode: str = "scan") -> Tuple[jax.Array, jax.Array]:
    if mode == "dense":
        return moe_dense(p, cfg, x)
    if mode == "grouped":
        return moe_grouped(p, cfg, x)
    return moe_scan(p, cfg, x)

"""Fused error-feedback residual sweep (the LowRankTransport hot path).

The PowerSGD factor math (matmuls + Gram-Schmidt) lives in
``opt.transport`` as plain jnp shared verbatim by both backends — those
ops already run on the MXU and fusing them would buy nothing while
risking bit-drift. What the pallas backend fuses is the elementwise tail:
given the reconstruction ``payload = P @ Q'^T``, ONE sweep per leaf
computes the masked error-feedback blend
``mk*(pending - payload) + (1-mk)*err`` (``residual_ef_batched``) — one
read of pending/payload/err instead of the reference path's subtract +
blend sweeps.

Numerics replicate ``opt.transport._ef_blend`` exactly (same expression,
same dtypes), so the pallas composed step stays bit-identical to the
reference backend at f32/f64.

``interpret=None`` resolves through ``common.interpret_default`` like
every kernel in this package.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (_LANES, _pad_to_3d, block_for, log_traffic,
                     resolve_interpret)

__all__ = ["residual_ef_batched", "residual_ef_row"]


def _residual_ef_kernel(s_ref, p_ref, q_ref, e_ref, ne_ref):
    mask = s_ref[0, 0]
    pending = p_ref[...]
    mk = mask.astype(pending.dtype)
    ne_ref[...] = mk * (pending - q_ref[...].astype(pending.dtype)) \
        + (1.0 - mk) * e_ref[...].astype(pending.dtype)


def residual_ef_batched(pending: jax.Array, payload: jax.Array,
                        err: jax.Array, mask: jax.Array, *,
                        block_rows: int = 256,
                        interpret: bool | None = None) -> jax.Array:
    """One-sweep masked EF residual of one (M, ...) leaf.

    Args:
      pending: (M, ...) deltas with the error residual already folded in.
      payload: (M, ...) encoded reconstruction the receiver sees.
      err: (M, ...) current error-feedback bank leaf.
      mask: (M,) f32 transmit mask from the censor stage.
    Returns:
      The next error-feedback leaf: transmitted workers keep the fresh
      residual ``pending - payload``, censored workers keep their old
      residual.
    """
    assert pending.shape == payload.shape == err.shape
    assert mask.shape == (pending.shape[0],)
    if pending.size == 0:
        return jnp.zeros(pending.shape, pending.dtype)
    shape, dtype = pending.shape, pending.dtype
    m = shape[0]
    p3 = _pad_to_3d(pending, block_rows)
    q3 = _pad_to_3d(payload, block_rows)
    e3 = _pad_to_3d(err, block_rows)
    sc = mask.astype(jnp.float32).reshape(m, 1)            # (M, 1)
    block = block_for(p3, block_rows)
    nr = p3.shape[1] // block
    new_err = pl.pallas_call(
        _residual_ef_kernel,
        grid=(m, nr),
        in_specs=[
            pl.BlockSpec((1, 1), lambda w, i: (w, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        out_shape=jax.ShapeDtypeStruct(p3.shape, dtype),
        interpret=resolve_interpret(interpret),
    )(sc, p3, q3, e3)
    new_err = log_traffic("residual_ef_batched", (sc, p3, q3, e3), new_err)
    n = math.prod(shape[1:])
    return new_err.reshape(m, -1)[:, :n].reshape(shape)


def residual_ef_row(pending: jax.Array, payload: jax.Array,
                    err: jax.Array, *, block_rows: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    """One worker's EF residual (the ``repro.fed`` entry point).

    Runs the batched kernel at M=1 with the transmit mask pinned to 1, so
    the result is bit-identical to the batched step's worker slice.
    """
    return residual_ef_batched(
        pending[None], payload[None], err[None],
        jnp.ones((1,), jnp.float32),
        block_rows=block_rows, interpret=interpret)[0]

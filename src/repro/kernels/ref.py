"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def censor_delta_sqnorm(g: jax.Array, ghat: jax.Array) -> jax.Array:
    """|| g - ghat ||^2 in f32 (per-tensor partial of the eq.-(8) test)."""
    d = g.astype(jnp.float32) - ghat.astype(jnp.float32)
    return jnp.sum(d * d)


def censor_select(g: jax.Array, ghat: jax.Array,
                  transmit: jax.Array) -> jax.Array:
    """ghat' = g where transmitted else ghat (worker-side bank advance)."""
    return jnp.where(transmit.astype(bool), g.astype(ghat.dtype), ghat)


def hb_update(theta: jax.Array, nabla: jax.Array, theta_prev: jax.Array,
              alpha: float, beta: float) -> jax.Array:
    """Eq. (4): theta - alpha*nabla + beta*(theta - theta_prev), f32 math."""
    t = theta.astype(jnp.float32)
    out = t - alpha * nabla.astype(jnp.float32) \
        + beta * (t - theta_prev.astype(jnp.float32))
    return out.astype(theta.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window=None, scale=None):
    """Naive attention oracle; q (B,H,L,d), k/v (B,K,S,d)."""
    from ..models.flash import reference_attention
    return reference_attention(q, k, v, causal=causal, window=window,
                               scale=scale)

"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import compute_dtype


def censor_delta_sqnorm(g: jax.Array, ghat: jax.Array) -> jax.Array:
    """|| g - ghat ||^2 in f32 (per-tensor partial of the eq.-(8) test)."""
    d = g.astype(jnp.float32) - ghat.astype(jnp.float32)
    return jnp.sum(d * d)


def censor_select(g: jax.Array, ghat: jax.Array,
                  transmit: jax.Array) -> jax.Array:
    """ghat' = g where transmitted else ghat (worker-side bank advance)."""
    return jnp.where(transmit.astype(bool), g.astype(ghat.dtype), ghat)


def hb_update(theta: jax.Array, nabla: jax.Array, theta_prev: jax.Array,
              alpha, beta) -> jax.Array:
    """Eq. (4): theta - alpha*nabla + beta*(theta - theta_prev).

    Math in ``common.compute_dtype`` (f32 for sub-f32 params, native
    precision for f32/f64), result cast back to the parameter dtype —
    the exact contract of the fused kernel. ``alpha``/``beta`` may be
    traced scalars.
    """
    acc = compute_dtype(theta.dtype)
    a = jnp.asarray(alpha).astype(acc)
    b = jnp.asarray(beta).astype(acc)
    t = theta.astype(acc)
    out = t - a * nabla.astype(acc) + b * (t - theta_prev.astype(acc))
    return out.astype(theta.dtype)


# ------------------------------------------------ leading-M batched oracles
def censor_delta_sqnorm_batched(g: jax.Array, ghat: jax.Array) -> jax.Array:
    """(M,) per-worker ||g_m - ghat_m||^2; subtraction in the bank dtype,
    f32 accumulation (the reference step's exact recipe)."""
    m = g.shape[0]
    d = (g.astype(ghat.dtype) - ghat).astype(jnp.float32)
    return jnp.sum(jnp.square(d).reshape(m, -1), axis=1)


def sqnorm_batched(x: jax.Array) -> jax.Array:
    """(M,) per-worker ||x_m||^2 with f32 accumulation."""
    m = x.shape[0]
    return jnp.sum(jnp.square(x.astype(jnp.float32)).reshape(m, -1), axis=1)


def _bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


def censor_bank_advance(g: jax.Array, ghat: jax.Array,
                        mask: jax.Array) -> jax.Array:
    """ghat + mask * (g - ghat), the arithmetic-mask bank advance."""
    return ghat + _bcast(mask, ghat) * (g.astype(ghat.dtype) - ghat)


def bank_advance(ghat: jax.Array, payload: jax.Array,
                 mask: jax.Array) -> jax.Array:
    """ghat + mask * payload (pre-encoded payload variant)."""
    return ghat + _bcast(mask, ghat) * payload.astype(ghat.dtype)


def absmax_batched(x: jax.Array) -> jax.Array:
    """(M,) per-worker max |x_m| in ``x.dtype``."""
    m = x.shape[0]
    return jnp.max(jnp.abs(x).reshape(m, -1), axis=1)


def quantize_ef_batched(pending: jax.Array, err: jax.Array,
                        mask: jax.Array, scale: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """(payload, new_err) of the fused int8 + error-feedback sweep."""
    s = _bcast(scale.astype(jnp.float32), pending).astype(jnp.float32)
    q32 = jnp.clip(jnp.round(pending.astype(jnp.float32) / s), -127, 127)
    payload = (q32 * s).astype(pending.dtype)
    mk = _bcast(mask, pending)
    new_err = mk * (pending - payload) \
        + (1.0 - mk) * err.astype(pending.dtype)
    return payload, new_err


def select_pack_ef_batched(pending: jax.Array, err: jax.Array,
                           keep: jax.Array, mask: jax.Array
                           ) -> tuple[jax.Array, jax.Array]:
    """(payload, new_err) of the fused top-k select/pack + EF sweep.

    The payload is a ``where`` select (not a multiply — ``x * 0`` flips
    negative zeros and would break bit-parity with the kernel)."""
    payload = jnp.where(keep != 0, pending, jnp.zeros_like(pending))
    mk = _bcast(mask, pending)
    new_err = mk * (pending - payload) \
        + (1.0 - mk) * err.astype(pending.dtype)
    return payload, new_err


def residual_ef_batched(pending: jax.Array, payload: jax.Array,
                        err: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked EF residual: ``mk*(pending - payload) + (1-mk)*err``."""
    mk = _bcast(mask, pending)
    return mk * (pending - payload.astype(pending.dtype)) \
        + (1.0 - mk) * err.astype(pending.dtype)


# -------------------------------------------------- fused-step oracles
def fused_dense_step(g: jax.Array, ghat: jax.Array, theta: jax.Array,
                     theta_prev: jax.Array, mask: jax.Array, alpha, beta
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(new_ghat, agg, new_theta) of the post-``decide`` dense megakernel:
    bank advance + eq.-(5) worker sum + eq.-(4) update, per leaf."""
    new_ghat = censor_bank_advance(g, ghat, mask)
    agg = jnp.sum(new_ghat, axis=0)
    return new_ghat, agg, hb_update(theta, agg, theta_prev, alpha, beta)


def int8_stats_batched(g: jax.Array, ghat: jax.Array, err: jax.Array
                       ) -> tuple[jax.Array, jax.Array]:
    """(sqnorms, amax) of the int8 pending delta, never materialized."""
    pending = (g.astype(ghat.dtype) - ghat) + err.astype(ghat.dtype)
    return sqnorm_batched(pending), absmax_batched(pending)


def fused_int8_step(g: jax.Array, ghat: jax.Array, err: jax.Array,
                    theta: jax.Array, theta_prev: jax.Array,
                    mask: jax.Array, scale: jax.Array, alpha, beta
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(new_ghat, new_err, agg, new_theta) of the post-``decide`` int8+EF
    megakernel: quantize round-trip + EF blend + bank advance + eq.-(5)
    worker sum + eq.-(4) update, per leaf."""
    pending = (g.astype(ghat.dtype) - ghat) + err.astype(ghat.dtype)
    payload, new_err = quantize_ef_batched(pending, err, mask, scale)
    new_ghat = bank_advance(ghat, payload, mask)
    agg = jnp.sum(new_ghat, axis=0)
    return (new_ghat, new_err, agg,
            hb_update(theta, agg, theta_prev, alpha, beta))


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window=None, scale=None):
    """Naive attention oracle; q (B,H,L,d), k/v (B,K,S,d)."""
    from ..models.flash import reference_attention
    return reference_attention(q, k, v, causal=causal, window=window,
                               scale=scale)

"""Pallas TPU kernel: single-query (decode) attention over a ring KV cache.

The decode hot path reads the whole cache once per step; fusing the
validity mask (ring-slot positions), softmax and weighted sum keeps it a
single HBM sweep. Grid (B, KV-heads, cache blocks): the cache-block index is
minor-most, so the online-softmax state for all G=H/K query heads of one kv
head lives in VMEM scratch.

Block shape (bc, d) over the cache: bc=512 rows x head_dim, (8,128)-tile
aligned. Validated in interpret mode against the pure-jnp oracle
(repro.models.layers.decode_attention's math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import resolve_interpret

_NEG = -1e30


def _kernel(scale, bc, nc, g,
            q_ref, k_ref, v_ref, pos_ref, cpos_ref, o_ref,
            acc_ref, m_ref, l_ref):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bc, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T) * scale                                # (G, bc)
    cpos = cpos_ref[0]                                   # (bc,) slot positions
    valid = (cpos >= 0) & (cpos <= pos_ref[0])
    s = jnp.where(valid[None, :], s, _NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(cj == nc - 1)
    def _done():
        l_safe = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, cache_pos, pos, *,
                            scale=None, block: int = 512,
                            interpret: bool | None = None) -> jax.Array:
    """q: (B, H, d); caches: (B, K, C, d); cache_pos: (C,) abs positions
    (-1 empty); pos: () current position. Returns (B, H, d)."""
    b, h, d = q.shape
    kh, c = k_cache.shape[1], k_cache.shape[2]
    assert h % kh == 0
    g = h // kh
    bc = min(block, c)
    assert c % bc == 0, (c, bc)
    nc = c // bc
    if scale is None:
        scale = d ** -0.5
    q4 = q.reshape(b, kh, g, d)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    cpos = cache_pos.astype(jnp.int32).reshape(1, c)

    kernel = functools.partial(_kernel, float(scale), bc, nc, g)
    out = pl.pallas_call(
        kernel,
        grid=(b, kh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, kh_, cj: (b_, kh_, 0, 0)),
            pl.BlockSpec((1, 1, bc, d), lambda b_, kh_, cj: (b_, kh_, cj, 0)),
            pl.BlockSpec((1, 1, bc, d), lambda b_, kh_, cj: (b_, kh_, cj, 0)),
            pl.BlockSpec((1,), lambda b_, kh_, cj: (0,)),
            pl.BlockSpec((1, bc), lambda b_, kh_, cj: (0, cj)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, kh_, cj: (b_, kh_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q4, k_cache, v_cache, pos_arr, cpos)
    return out.reshape(b, h, d)


def decode_attention_ref(q, k_cache, v_cache, cache_pos, pos, scale=None):
    """Pure-jnp oracle."""
    b, h, d = q.shape
    kh = k_cache.shape[1]
    g = h // kh
    if scale is None:
        scale = d ** -0.5
    q4 = q.reshape(b, kh, g, d).astype(jnp.float32)
    kt = k_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bkcd->bkgc", q4, kt) * scale
    valid = (cache_pos >= 0) & (cache_pos <= pos)
    s = jnp.where(valid[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bkcd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)

"""Jit-compiled wrappers around the Pallas kernels with jnp fallbacks.

On CPU (this container) kernels run in interpret mode for validation; on a
real TPU set interpret=False (the default flips on backend detection).
"""
from __future__ import annotations

import functools

import jax

from . import censor, flash_attention, hb_update, ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def censor_delta_sqnorm(g, ghat, use_pallas: bool = True):
    if use_pallas:
        return censor.censor_delta_sqnorm(g, ghat,
                                          interpret=_interpret_default())
    return ref.censor_delta_sqnorm(g, ghat)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def censor_select(g, ghat, transmit, use_pallas: bool = True):
    if use_pallas:
        return censor.censor_select(g, ghat, transmit,
                                    interpret=_interpret_default())
    return ref.censor_select(g, ghat, transmit)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "use_pallas"))
def hb_param_update(theta, nabla, theta_prev, alpha: float, beta: float,
                    use_pallas: bool = True):
    if use_pallas:
        return hb_update.hb_update(theta, nabla, theta_prev, alpha, beta,
                                   interpret=_interpret_default())
    return ref.hb_update(theta, nabla, theta_prev, alpha, beta)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_block",
                                    "kv_block", "use_pallas"))
def flash_attention_fwd(q, k, v, causal: bool = True, window=None,
                        q_block: int = 512, kv_block: int = 512,
                        use_pallas: bool = True):
    if use_pallas:
        return flash_attention.flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_block=q_block,
            kv_block=kv_block, interpret=_interpret_default())
    return ref.flash_attention_fwd(q, k, v, causal=causal, window=window)

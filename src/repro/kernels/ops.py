"""Pytree-aware dispatch onto the Pallas kernels + jit-compiled wrappers.

Two layers live here:

  * **Tree-level dispatch** (``tree_*``) — what the ``repro.opt`` pallas
    backend executes: leading-M-batched censor sqnorms, fused bank
    advances, the fused int8 + error-feedback sweep, and the eq.-(4)
    heavy-ball update, mapped over whole parameter pytrees. These are
    pure traceable functions (no ``jit`` of their own) so they inline
    into whatever program is being built — ``simulator.trajectory``'s
    scan, the sweep engine's ``lax.map`` partitions, ``core/distributed``
    strategies, or the ``repro.fed`` per-client closures.
  * **Jit-compiled single-tensor wrappers** (``censor_delta_sqnorm``,
    ``censor_select``, ``hb_param_update``, ``flash_attention_fwd``) —
    convenience entry points with a jnp fallback (``use_pallas=False``).

Hyperparameter contract: ``alpha``/``beta`` (and the censor's eps1, which
never reaches a kernel) are **traced scalar operands** everywhere — they
ride in SMEM blocks, not in the kernel closure, so sweeping a
hyperparameter grid reuses one compiled program. ``trace_counts`` records
how many times each dispatch function was traced (Python-side side effect:
it only ticks at trace time, never at execution time), which is how
``tests/test_kernels.py`` and ``benchmarks/kernel_roofline.py`` measure
retraces.

The interpret-vs-Mosaic decision lives in ``common.interpret_default`` and
is shared with direct kernel-module calls, so both entry points agree: on
CPU (this container) kernels run in interpret mode for validation; on a
real TPU both lower through Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import (censor, flash_attention, fused_step, hb_update, lowrank_ef,
               quantize_ef, ref, topk_pack)
from .common import interpret_default
from ..obs import compile_log

_interpret_default = interpret_default      # legacy alias (pre-backend name)


# ------------------------------------------------------- trace accounting
# The counters live in the process-wide ``repro.obs.compile_log`` under the
# "kernels" namespace; ``trace_counts`` is the *live* dict for that
# namespace (the same object the recorder updates), kept for the original
# API. ``obs.compile_log.snapshot()`` sees these ticks as "kernels/<name>"
# next to every other surface's counters.
trace_counts: dict[str, int] = compile_log.namespace("kernels")


def reset_trace_counts() -> None:
    """Zero the per-dispatch trace counters."""
    compile_log.reset("kernels")


def _traced(name: str) -> None:
    compile_log.record("kernels", name)


def _dispatch(fn):
    """Tree-dispatch wrapper: tick the compile log at trace time and wrap
    the kernel calls in a ``jax.named_scope`` so profiler traces (see
    ``repro.obs.profile``) attribute device time to the dispatch by name.
    The scope is HLO metadata only — numerics are untouched."""
    name = fn.__name__

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        _traced(name)
        with jax.named_scope(f"kernels/{name}"):
            return fn(*args, **kwargs)
    return wrapped


# ----------------------------------------------------- tree-level dispatch
@_dispatch
def tree_delta_sqnorms(grads, bank, *, block_rows: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """(M,) per-worker ||g_m - ghat_m||^2 over a whole pytree.

    The eq.-(8) left-hand side, fused: one sweep per leaf over the stacked
    bank, no materialized delta tree. The subtraction dtype and the
    leaf-by-leaf f32 accumulation match ``core.censoring.delta_sqnorms``;
    *within* a leaf the tiled partial sums regroup the float additions,
    so values agree with the reference reduction to ulps, not bits (a
    censor decision landing exactly on the eq.-(8) threshold could
    therefore differ — see ``docs/kernels.md``).
    """
    leaves_g = jax.tree_util.tree_leaves(grads)
    leaves_h = jax.tree_util.tree_leaves(bank)
    acc = jnp.zeros((leaves_h[0].shape[0],), jnp.float32)
    for g, h in zip(leaves_g, leaves_h):
        acc = acc + censor.censor_delta_sqnorm_batched(
            g, h, block_rows=block_rows, interpret=interpret)
    return acc


@_dispatch
def tree_sqnorms(pending, *, block_rows: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    """(M,) per-worker ||x_m||^2 of a materialized pending-delta pytree."""
    leaves = jax.tree_util.tree_leaves(pending)
    acc = jnp.zeros((leaves[0].shape[0],), jnp.float32)
    for x in leaves:
        acc = acc + censor.sqnorm_batched(x, block_rows=block_rows,
                                          interpret=interpret)
    return acc


@_dispatch
def tree_sqnorm_row(pending_row, *, block_rows: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    """One worker's ||x||^2 (the ``repro.fed`` per-client entry point).

    Runs the batched kernel at M=1, so tile partials — and therefore the
    censor decision — are bit-identical to the batched step's per-worker
    slice.
    """
    leaves = jax.tree_util.tree_leaves(pending_row)
    acc = jnp.zeros((1,), jnp.float32)
    for x in leaves:
        acc = acc + censor.sqnorm_batched(x[None], block_rows=block_rows,
                                          interpret=interpret)
    return acc[0]


@_dispatch
def tree_censor_bank_advance(grads, bank, mask, *, block_rows: int = 256,
                             interpret: bool | None = None):
    """Fused censor-select bank advance: ``ghat + mask * (g - ghat)``."""
    return jax.tree_util.tree_map(
        lambda g, h: censor.censor_bank_advance(
            g, h, mask, block_rows=block_rows, interpret=interpret),
        grads, bank)


@_dispatch
def tree_bank_advance(bank, payload, mask, *, block_rows: int = 256,
                      interpret: bool | None = None):
    """Fused bank advance from an encoded payload: ``ghat + mask * q``."""
    return jax.tree_util.tree_map(
        lambda h, q: censor.bank_advance(
            h, q, mask, block_rows=block_rows, interpret=interpret),
        bank, payload)


@_dispatch
def tree_int8_roundtrip_ef(pending, err, mask, *, block_rows: int = 256,
                           interpret: bool | None = None):
    """Fused per-worker int8 round-trip + error-feedback over a pytree.

    Per leaf: a one-sweep abs-max reduction derives the per-worker scales
    (``where(amax > 0, amax/127, 1)``, exactly ``core/quantize``'s), then
    one fused sweep emits the dequantized payload and the next
    error-feedback leaf together. Returns ``(payload_tree, new_err_tree)``.
    """

    def one_leaf(p, e):
        amax = quantize_ef.absmax_batched(p, block_rows=block_rows,
                                          interpret=interpret)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        return quantize_ef.quantize_ef_batched(
            p, e, mask, scale, block_rows=block_rows, interpret=interpret)

    leaves_p, treedef = jax.tree_util.tree_flatten(pending)
    leaves_e = treedef.flatten_up_to(err)
    outs = [one_leaf(p, e) for p, e in zip(leaves_p, leaves_e)]
    payload = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return payload, new_err


@_dispatch
def tree_topk_pack_ef(pending, err, keep, mask, *, block_rows: int = 256,
                      interpret: bool | None = None):
    """Fused per-worker top-k select/pack + error-feedback over a pytree.

    ``keep`` holds the transport's 0/1 keep masks (exact host-graph
    ``lax.top_k`` selections); per leaf ONE fused sweep emits the sparse
    payload and the next error-feedback leaf together. Returns
    ``(payload_tree, new_err_tree)``.
    """
    leaves_p, treedef = jax.tree_util.tree_flatten(pending)
    leaves_e = treedef.flatten_up_to(err)
    leaves_k = treedef.flatten_up_to(keep)
    outs = [topk_pack.select_pack_ef_batched(
        p, e, kp, mask, block_rows=block_rows, interpret=interpret)
        for p, e, kp in zip(leaves_p, leaves_e, leaves_k)]
    payload = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return payload, new_err


@_dispatch
def tree_residual_ef(pending, payload, err, mask, *, block_rows: int = 256,
                     interpret: bool | None = None):
    """Fused masked error-feedback residual over a pytree.

    Per leaf ONE sweep computes ``mk*(pending - payload) + (1-mk)*err``
    (the low-rank transport's EF tail; its factor matmuls stay host-graph
    jnp). Returns the new error-feedback tree.
    """
    return jax.tree_util.tree_map(
        lambda p, q, e: lowrank_ef.residual_ef_batched(
            p, q, e, mask, block_rows=block_rows, interpret=interpret),
        pending, payload, err)


@_dispatch
def tree_fused_dense_step(grads, bank, params, prev_params, mask, alpha,
                          beta, *, block_rows: int = 256,
                          interpret: bool | None = None):
    """The post-``decide`` dense megakernel over a whole pytree.

    Per leaf ONE fused sweep performs the censor-select bank advance, the
    eq.-(5) worker-sum aggregation, and the eq.-(4) heavy-ball epilogue
    (``alpha``/``beta`` as traced SMEM operands). Returns
    ``(new_ghat, agg, new_params)`` — bitwise the staged
    ``tree_censor_bank_advance`` → ``tree_sum_leading`` →
    ``tree_hb_update`` composition, in a third of the HBM sweeps.
    """
    leaves_t, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_h = treedef.flatten_up_to(bank)
    leaves_p = treedef.flatten_up_to(prev_params)
    outs = [fused_step.fused_dense_step(
        g, h, t, tp, mask, alpha, beta, block_rows=block_rows,
        interpret=interpret)
        for g, h, t, tp in zip(leaves_g, leaves_h, leaves_t, leaves_p)]
    unflat = jax.tree_util.tree_unflatten
    return (unflat(treedef, [o[0] for o in outs]),
            unflat(treedef, [o[1] for o in outs]),
            unflat(treedef, [o[2] for o in outs]))


@_dispatch
def tree_int8_stats(grads, bank, err, *, block_rows: int = 256,
                    interpret: bool | None = None):
    """Per-worker eq.-(8) sqnorms + int8 scales, pending never materialized.

    One fused reduction sweep per leaf recomputes
    ``pending = (g - ghat) + err`` in-register and emits the sqnorm and
    abs-max tile partials together. Returns ``(dsq, scales)``: the (M,)
    f32 eq.-(8) left-hand side (leaf accumulation order identical to
    ``tree_sqnorms``) and a pytree of (M,) f32 per-leaf quantization
    scales (the staged ``where(amax > 0, amax/127, 1)`` expression).
    """
    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_h = treedef.flatten_up_to(bank)
    leaves_e = treedef.flatten_up_to(err)
    acc = jnp.zeros((leaves_h[0].shape[0],), jnp.float32)
    scales = []
    for g, h, e in zip(leaves_g, leaves_h, leaves_e):
        sq, amax = fused_step.int8_stats_batched(
            g, h, e, block_rows=block_rows, interpret=interpret)
        acc = acc + sq
        scales.append(jnp.where(amax > 0, amax / 127.0,
                                1.0).astype(jnp.float32))
    return acc, jax.tree_util.tree_unflatten(treedef, scales)


@_dispatch
def tree_fused_int8_step(grads, bank, err, params, prev_params, mask,
                         scales, alpha, beta, *, block_rows: int = 256,
                         interpret: bool | None = None):
    """The post-``decide`` int8+EF megakernel over a whole pytree.

    Per leaf ONE fused sweep recomputes the pending delta in-register,
    quantize-roundtrips it (the dequantized payload never touches HBM),
    blends the error-feedback bank, advances the stale bank, aggregates
    the workers, and applies eq. (4). ``scales`` is ``tree_int8_stats``'s
    per-leaf (M,) scale pytree. Returns
    ``(new_ghat, new_err, agg, new_params)`` — bitwise the staged
    ``tree_int8_roundtrip_ef`` → ``tree_bank_advance`` →
    ``tree_sum_leading`` → ``tree_hb_update`` composition.
    """
    leaves_t, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_h = treedef.flatten_up_to(bank)
    leaves_e = treedef.flatten_up_to(err)
    leaves_p = treedef.flatten_up_to(prev_params)
    leaves_s = treedef.flatten_up_to(scales)
    outs = [fused_step.fused_int8_step(
        g, h, e, t, tp, mask, s, alpha, beta, block_rows=block_rows,
        interpret=interpret)
        for g, h, e, t, tp, s in zip(leaves_g, leaves_h, leaves_e,
                                     leaves_t, leaves_p, leaves_s)]
    unflat = jax.tree_util.tree_unflatten
    return (unflat(treedef, [o[0] for o in outs]),
            unflat(treedef, [o[1] for o in outs]),
            unflat(treedef, [o[2] for o in outs]),
            unflat(treedef, [o[3] for o in outs]))


@_dispatch
def tree_hb_update(params, prev_params, agg, alpha, beta, *,
                   block_rows: int = 256, interpret: bool | None = None):
    """Fused eq.-(4) server update over a whole parameter pytree.

    ``alpha``/``beta`` may be traced scalars (SMEM operands — no retrace
    across a hyperparameter grid). Plain GD is ``beta = 0``, bit-identical
    to the reference ``GradientDescent`` stage by construction.
    """
    return jax.tree_util.tree_map(
        lambda t, tp, g: hb_update.hb_update(
            t, g, tp, alpha, beta, block_rows=block_rows,
            interpret=interpret),
        params, prev_params, agg)


# ------------------------------------------- jitted single-tensor wrappers
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def censor_delta_sqnorm(g, ghat, use_pallas: bool = True):
    if use_pallas:
        return censor.censor_delta_sqnorm(g, ghat)
    return ref.censor_delta_sqnorm(g, ghat)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def censor_select(g, ghat, transmit, use_pallas: bool = True):
    if use_pallas:
        return censor.censor_select(g, ghat, transmit)
    return ref.censor_select(g, ghat, transmit)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def hb_param_update(theta, nabla, theta_prev, alpha, beta,
                    use_pallas: bool = True):
    """Eq.-(4) update; ``alpha``/``beta`` are traced operands, so calling
    this across a hyperparameter grid compiles exactly once per shape."""
    if use_pallas:
        return hb_update.hb_update(theta, nabla, theta_prev, alpha, beta)
    return ref.hb_update(theta, nabla, theta_prev, alpha, beta)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_block",
                                    "kv_block", "use_pallas"))
def flash_attention_fwd(q, k, v, causal: bool = True, window=None,
                        q_block: int = 512, kv_block: int = 512,
                        use_pallas: bool = True):
    if use_pallas:
        return flash_attention.flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_block=q_block,
            kv_block=kv_block, interpret=interpret_default())
    return ref.flash_attention_fwd(q, k, v, causal=causal, window=window)

"""Shared plumbing for the CHB Pallas kernels.

Every kernel in this package sees parameter tensors through the same lens:
the leaf is flattened and zero-padded into ``(rows, 128)`` lane-aligned
tiles (``_pad_to_2d``), or — for leading-M stacked bank leaves — into
``(M, rows, 128)`` with each worker slice padded independently
(``_pad_to_3d``), so a row entry point (``repro.fed``'s per-client path)
and the batched entry point (the composed step) produce bit-identical
per-worker tile partials.

``interpret_default`` is the single source of truth for the
interpret-vs-Mosaic decision: every kernel module resolves
``interpret=None`` through it, so direct kernel calls and the ``ops.py``
jit wrappers always agree (on TPU both lower through Mosaic; anywhere else
both run the Pallas interpreter).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_LANES = 128


def interpret_default() -> bool:
    """True everywhere except a real TPU backend (Mosaic lowering)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """Resolve a kernel's ``interpret=None`` default to the backend rule."""
    return interpret_default() if interpret is None else bool(interpret)


def tile_rows(n: int, block_rows: int) -> tuple[int, int]:
    """(padded row count R, grid length nr) for ``n`` flat elements.

    Small tensors shrink the block to the tensor's own row count instead of
    padding up to ``block_rows`` — a d=20 paper tensor is one (1, 128)
    tile, not a (256, 128) one. The result depends only on ``n`` and
    ``block_rows``, so the row and batched entry points tile identically.
    """
    r_needed = max(1, math.ceil(n / _LANES))
    block = min(block_rows, r_needed)
    nr = math.ceil(r_needed / block)
    return nr * block, nr


def _pad_to_2d(x: jax.Array, block_rows: int) -> jax.Array:
    """Flatten to zero-padded (R, 128); R a multiple of the block rows."""
    flat = x.reshape(-1)
    r, _ = tile_rows(flat.shape[0], block_rows)
    return jnp.pad(flat, (0, r * _LANES - flat.shape[0])).reshape(r, _LANES)


def _pad_to_3d(x: jax.Array, block_rows: int) -> jax.Array:
    """(M, ...) leaf to zero-padded (M, R, 128), each worker slice padded
    exactly as ``_pad_to_2d`` pads the slice alone."""
    m = x.shape[0]
    flat = x.reshape(m, -1)
    r, _ = tile_rows(flat.shape[1], block_rows)
    return jnp.pad(flat, ((0, 0), (0, r * _LANES - flat.shape[1]))
                   ).reshape(m, r, _LANES)


def block_for(x2d: jax.Array, block_rows: int) -> int:
    """The per-tile row count ``_pad_to_2d``/``_pad_to_3d`` used."""
    return min(block_rows, x2d.shape[-2])


def compute_dtype(dtype) -> jnp.dtype:
    """f32 accumulation for sub-f32 params, native precision otherwise.

    bf16/f16 params are upcast to f32 inside the kernels (the documented
    kernel contract, shared with the ``ref.py`` oracles); f32 and f64
    params compute in their own dtype — which is what makes the pallas
    backend bit-identical to the reference jnp step at those precisions.
    """
    return jnp.promote_types(dtype, jnp.float32)


# --------------------------------------------------- kernel traffic recorder
# XLA's ``cost_analysis()`` over-counts interpret-mode pallas calls: the
# interpreter emulates the grid at the HLO level (dynamic-slice copies of
# every block per grid step), so "bytes accessed" reflects the emulation
# machinery, not the kernel's HBM contract. The recorder below measures
# what Mosaic would move: the padded operand + result bytes of each
# ``pallas_call``, ticked at *trace* time by every kernel wrapper in this
# package. Trace the step exactly once inside the context for a
# per-execution figure (``benchmarks/kernel_roofline.py`` does).
_TRAFFIC_LOG: dict[str, float] | None = None


class track_kernel_bytes:
    """Context manager recording per-kernel HBM traffic at trace time.

    ``with track_kernel_bytes() as rec: jax.jit(step).lower(...)`` leaves
    ``rec.bytes`` holding ``{kernel_name: padded operand+result bytes}``
    summed over every pallas call traced inside the context, and
    ``rec.total()`` the grand total. Nestable; execution-time calls of an
    already-traced program tick nothing.
    """

    def __init__(self):
        self.bytes: dict[str, float] = {}

    def __enter__(self) -> "track_kernel_bytes":
        global _TRAFFIC_LOG
        self._prev = _TRAFFIC_LOG
        _TRAFFIC_LOG = self.bytes
        return self

    def __exit__(self, *exc):
        global _TRAFFIC_LOG
        _TRAFFIC_LOG = self._prev
        return False

    def total(self) -> float:
        return float(sum(self.bytes.values()))


def log_traffic(name: str, operands, results):
    """Tick the active traffic log with one pallas call's HBM bytes.

    Pass-through: returns ``results`` unchanged so kernel wrappers can
    wrap their ``pallas_call`` invocation in one line. Counts every
    operand and result leaf at its padded device size (SMEM scalar blocks
    included — they are negligible but really are transferred).
    """
    if _TRAFFIC_LOG is not None:
        leaves = jax.tree_util.tree_leaves((operands, results))
        nbytes = float(sum(x.size * x.dtype.itemsize for x in leaves))
        _TRAFFIC_LOG[name] = _TRAFFIC_LOG.get(name, 0.0) + nbytes
    return results

"""Pallas TPU flash-attention forward kernel (inference/prefill path).

Grid (B, H, nq, nk): the kv index is the minor-most grid dimension, so each
(b, h, qi) output block is revisited across kj steps and the online-softmax
state lives in VMEM scratch. Block shapes default to 512 q / 512 kv rows —
multiples of the (8,128) f32 / (16,128) bf16 TPU tile; the (bq, bk) f32
score block is 1 MiB, comfortably inside the ~16 MiB/core VMEM budget
together with the q/k/v tiles.

GQA is handled in the kv BlockSpec index map (kv head = h // group).
Causal and sliding-window masks are applied with absolute block offsets.
Training uses the custom-vjp pure-JAX flash in repro.models.flash; this
kernel is the TPU-native forward for serving, validated in interpret mode
against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import resolve_interpret

_NEG = -1e30


def _kernel(causal, window, scale, bq, bk, nk,
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T) * scale                        # (bq, bk)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l_safe = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           scale: float | None = None,
                           q_block: int = 512, kv_block: int = 512,
                           interpret: bool | None = None) -> jax.Array:
    """q: (B, H, Lq, d); k, v: (B, K, S, d); returns (B, H, Lq, d)."""
    b, h, lq, d = q.shape
    kh, s_len = k.shape[1], k.shape[2]
    assert h % kh == 0
    g = h // kh
    bq = min(q_block, lq)
    bk = min(kv_block, s_len)
    assert lq % bq == 0 and s_len % bk == 0
    nq, nk = lq // bq, s_len // bk
    if scale is None:
        scale = d ** -0.5

    kernel = functools.partial(_kernel, causal, window, float(scale),
                               bq, bk, nk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, kj: (b_, h_ // g, kj, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, kj: (b_, h_ // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)

"""One-sweep fused CHB step: the post-``decide`` megakernel.

The staged pallas path materializes every inter-stage intermediate of the
composed step (pending delta, quantized payload, advanced bank, worker
sum) as its own HBM round-trip. The kernels here collapse everything that
happens *after* the censor decision into ONE pass over the ``(M, n)``
bank, so a composed step becomes two sweeps total:

  sweep 1 (reduction): per-worker eq.-(8) sqnorms feeding
      ``censor.decide`` — ``censor.censor_delta_sqnorm_batched`` for the
      dense transport, or :func:`int8_stats_batched` (sqnorm + abs-max
      partials from an in-register pending recompute) for int8+EF;
  sweep 2 (elementwise): :func:`fused_dense_step` /
      :func:`fused_int8_step` — transport encode + error-feedback blend,
      bank advance, eq.-(5) worker-sum aggregation, and the eq.-(4)
      heavy-ball epilogue, per leaf, in one ``pallas_call``.

Bit-exactness contract (same as every kernel in this package): each fused
stage evaluates the staged path's exact expressions in the staged path's
dtypes. Two structural choices make that hold to the bit:

  * the whole worker axis rides in ONE ``(M, block, 128)`` VMEM block and
    the kernel aggregates with ``jnp.sum(·, axis=0)`` — the same reduce
    HLO the staged path's host-side ``tree_sum_leading`` lowers to (a
    sequential zero-init accumulator fold is NOT bitwise equal to XLA's
    axis-0 reduce grouping);
  * int8 never materializes the pending tree: both sweeps recompute
    ``pending = (g - ghat) + err`` in-register with the identical
    (deterministic, elementwise) expression, so the recomputed values are
    bitwise the staged path's materialized ones — and the dequantized
    payload never touches HBM at all.

``alpha``/``beta`` are traced SMEM operands (the ``baked-traced-hparam``
contract — one compile per shape across a whole hyperparameter grid);
per-worker mask (+ int8 scale) ride in an ``(M, 1)``/``(M, 2)`` SMEM
block. ``eps1`` is consumed by ``censor.decide`` between the sweeps and
never reaches a kernel. ``interpret=None`` resolves through
``common.interpret_default`` like every kernel in this package.

The module-level :func:`force_staged` context manager routes
``ComposedOptimizer`` back through the staged per-stage kernels at trace
time — the conformance suite and the roofline benchmark use it to compare
the two programs on identical inputs.
"""
from __future__ import annotations

import contextlib
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (_LANES, _pad_to_2d, _pad_to_3d, block_for,
                     compute_dtype, log_traffic, resolve_interpret)

__all__ = ["fused_dense_step", "fused_int8_step", "int8_stats_batched",
           "fusion_enabled", "force_staged"]


# ------------------------------------------------------- fused/staged toggle
_FUSION_ENABLED = True


def fusion_enabled() -> bool:
    """Whether ``ComposedOptimizer``'s pallas backend traces the megakernel.

    Consulted at *trace* time: flipping it affects programs traced after
    the flip, never already-compiled ones.
    """
    return _FUSION_ENABLED


@contextlib.contextmanager
def force_staged():
    """Trace the staged per-stage kernels instead of the fused megakernel.

    For A/B comparison only (conformance tests, the roofline benchmark's
    staged-vs-fused columns): both programs are bit-identical at f32/f64,
    the staged one just moves more bytes.
    """
    global _FUSION_ENABLED
    prev = _FUSION_ENABLED
    _FUSION_ENABLED = False
    try:
        yield
    finally:
        _FUSION_ENABLED = prev


def _hb_scalars(alpha, beta, dtype) -> jax.Array:
    """(1, 2) SMEM block of traced eq.-(4) scalars in the compute dtype."""
    acc = compute_dtype(dtype)
    return jnp.stack([jnp.asarray(alpha).astype(acc),
                      jnp.asarray(beta).astype(acc)]).reshape(1, 2)


# ------------------------------------------------------ dense megakernel
def _fused_dense_kernel(s_ref, mk_ref, g_ref, h_ref, t_ref, p_ref,
                        ng_ref, agg_ref, out_ref):
    # bank advance: the arithmetic mask form, matching
    # censor._censor_bank_advance_kernel per element
    h = h_ref[...]                                   # (M, block, 128)
    g = g_ref[...].astype(h.dtype)
    mask = mk_ref[...].astype(h.dtype)               # (M, 1)
    ng = h + mask[:, :, None] * (g - h)
    ng_ref[...] = ng
    # eq. (5): whole worker axis in-block, so this is the same axis-0
    # reduce HLO as the staged path's host-side tree_sum_leading
    agg_ref[...] = jnp.sum(ng, axis=0)
    # eq. (4) epilogue, matching hb_update._hb_kernel. agg is re-read
    # through the ref, not kept in-register: XLA's FMA-contraction
    # heuristic treats a reduce result differently from a loaded operand,
    # and the contraction of ``t - alpha*agg`` must round exactly like
    # the staged kernel's (whose nabla is a load) in every jit context.
    acc = s_ref.dtype
    alpha = s_ref[0, 0]
    beta = s_ref[0, 1]
    t = t_ref[...].astype(acc)
    p = p_ref[...].astype(acc)
    out_ref[...] = (t - alpha * agg_ref[...].astype(acc)
                    + beta * (t - p)).astype(out_ref.dtype)


def fused_dense_step(g: jax.Array, ghat: jax.Array, theta: jax.Array,
                     theta_prev: jax.Array, mask: jax.Array, alpha, beta, *,
                     block_rows: int = 256, interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Everything after ``decide`` for one dense leaf, in ONE sweep.

    Fuses ``censor.censor_bank_advance`` + the eq.-(5) worker sum + the
    eq.-(4) ``hb_update.hb_update`` epilogue: one read of ``(g, ghat,
    theta, theta_prev)``, one write of ``(new_ghat, agg, new_theta)`` —
    the staged path's intermediate reads of the advanced bank and the
    aggregate never happen.

    Args:
      g: (M, ...) fresh worker gradients.
      ghat: (M, ...) stale bank leaf (its dtype is the bank dtype).
      theta / theta_prev: the parameter leaf and its predecessor.
      mask: (M,) f32 transmit mask from the censor stage.
      alpha / beta: traced eq.-(4) scalars (SMEM operands).
    Returns:
      ``(new_ghat, agg, new_theta)`` with ``agg = sum_m new_ghat_m`` in
      the bank dtype (unpadded, so downstream ``tree_sqnorm`` sees the
      staged path's exact array).
    """
    assert g.shape == ghat.shape and mask.shape == (g.shape[0],)
    if ghat.size == 0:
        return ghat, jnp.sum(ghat, axis=0), theta
    m = g.shape[0]
    shape, n = theta.shape, math.prod(theta.shape)
    s = _hb_scalars(alpha, beta, theta.dtype)
    mk = mask.astype(jnp.float32).reshape(m, 1)
    g3 = _pad_to_3d(g, block_rows)
    h3 = _pad_to_3d(ghat, block_rows)
    t2 = _pad_to_2d(theta, block_rows)
    p2 = _pad_to_2d(theta_prev, block_rows)
    block = block_for(g3, block_rows)
    nr = g3.shape[1] // block
    b3 = pl.BlockSpec((m, block, _LANES), lambda i: (0, i, 0))
    b2 = pl.BlockSpec((block, _LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        _fused_dense_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((m, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            b3, b3, b2, b2,
        ],
        out_specs=[b3, b2, b2],
        out_shape=[jax.ShapeDtypeStruct(h3.shape, ghat.dtype),
                   jax.ShapeDtypeStruct(t2.shape, ghat.dtype),
                   jax.ShapeDtypeStruct(t2.shape, theta.dtype)],
        interpret=resolve_interpret(interpret),
    )(s, mk, g3, h3, t2, p2)
    ng3, agg2, out2 = log_traffic("fused_dense_step",
                                  (s, mk, g3, h3, t2, p2), outs)
    return (ng3.reshape(m, -1)[:, :n].reshape((m,) + shape),
            agg2.reshape(-1)[:n].reshape(shape),
            out2.reshape(-1)[:n].reshape(shape))


# ----------------------------------------------- int8 sweep 1: stats kernel
def _int8_stats_kernel(g_ref, h_ref, e_ref, sq_ref, am_ref):
    # pending recomputed in-register with the staged path's exact
    # expression: delta in the bank dtype, err cast onto it
    h = h_ref[...]
    pending = (g_ref[...].astype(h.dtype) - h) + e_ref[...].astype(h.dtype)
    x = pending.astype(jnp.float32)
    sq_ref[0, 0] = jnp.sum(x * x)              # == censor._sqnorm_batched
    am_ref[0, 0] = jnp.max(jnp.abs(pending))   # == quantize_ef._absmax


def int8_stats_batched(g: jax.Array, ghat: jax.Array, err: jax.Array, *,
                       block_rows: int = 256,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Per-worker eq.-(8) sqnorms AND abs-max of one int8+EF leaf, fused.

    The staged path materializes ``pending = (g - ghat) + err`` to HBM
    and then sweeps it twice more (``sqnorm_batched`` + ``absmax_batched``
    = 5 row-reads total); here ONE read of ``(g, ghat, err)`` emits both
    per-tile partial sets together, and pending is never written.

    Returns ``(sqnorms, amax)``: (M,) f32 sqnorms (tile partials bitwise
    equal to the staged/row kernels') and (M,) abs-max in the bank dtype
    (max is exactly associative, so padding and tiling cannot perturb it).
    """
    assert g.shape == ghat.shape == err.shape
    m = g.shape[0]
    if g.size == 0:
        return jnp.zeros((m,), jnp.float32), jnp.zeros((m,), ghat.dtype)
    g3 = _pad_to_3d(g, block_rows)
    h3 = _pad_to_3d(ghat, block_rows)
    e3 = _pad_to_3d(err, block_rows)
    block = block_for(g3, block_rows)
    nr = g3.shape[1] // block
    outs = pl.pallas_call(
        _int8_stats_kernel,
        grid=(m, nr),
        in_specs=[pl.BlockSpec((1, block, _LANES),
                               lambda w, i: (w, i, 0))] * 3,
        out_specs=[pl.BlockSpec((1, 1), lambda w, i: (w, i))] * 2,
        out_shape=[jax.ShapeDtypeStruct((m, nr), jnp.float32),
                   jax.ShapeDtypeStruct((m, nr), ghat.dtype)],
        interpret=resolve_interpret(interpret),
    )(g3, h3, e3)
    sq, am = log_traffic("int8_stats_batched", (g3, h3, e3), outs)
    return jnp.sum(sq, axis=1), jnp.max(am, axis=1)


# ------------------------------------------------------- int8 megakernel
def _fused_int8_kernel(s_ref, sc_ref, g_ref, h_ref, e_ref, t_ref, p_ref,
                       ng_ref, ne_ref, agg_ref, out_ref):
    # pending recomputed in-register — bitwise the sweep-1 values (same
    # deterministic elementwise expression), never materialized to HBM
    h = h_ref[...]                                   # (M, block, 128)
    e = e_ref[...]
    pending = (g_ref[...].astype(h.dtype) - h) + e.astype(h.dtype)
    sc = sc_ref[...]                                 # (M, 2) f32
    scale = sc[:, 1][:, None, None]
    # int8 round-trip in f32, matching quantize_ef._quantize_ef_kernel;
    # the dequantized payload lives only in VMEM
    q32 = jnp.clip(jnp.round(pending.astype(jnp.float32) / scale),
                   -127, 127)
    payload = (q32 * scale).astype(pending.dtype)
    mk = sc[:, 0].astype(pending.dtype)[:, None, None]
    ne_ref[...] = mk * (pending - payload) \
        + (1.0 - mk) * e.astype(pending.dtype)
    # bank advance from the payload, matching censor._bank_advance_kernel
    ng = h + sc[:, 0].astype(h.dtype)[:, None, None] * payload.astype(h.dtype)
    ng_ref[...] = ng
    agg_ref[...] = jnp.sum(ng, axis=0)
    # eq. (4) epilogue; agg re-read through the ref so the contraction of
    # ``t - alpha*agg`` matches the staged kernel's loaded-operand form
    # in every jit context (see _fused_dense_kernel)
    acc = s_ref.dtype
    alpha = s_ref[0, 0]
    beta = s_ref[0, 1]
    t = t_ref[...].astype(acc)
    p = p_ref[...].astype(acc)
    out_ref[...] = (t - alpha * agg_ref[...].astype(acc)
                    + beta * (t - p)).astype(out_ref.dtype)


def fused_int8_step(g: jax.Array, ghat: jax.Array, err: jax.Array,
                    theta: jax.Array, theta_prev: jax.Array,
                    mask: jax.Array, scale: jax.Array, alpha, beta, *,
                    block_rows: int = 256, interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Everything after ``decide`` for one int8+EF leaf, in ONE sweep.

    Fuses ``quantize_ef.quantize_ef_batched`` + ``censor.bank_advance`` +
    the eq.-(5) worker sum + the eq.-(4) epilogue. The pending delta and
    the dequantized payload exist only in registers/VMEM: one read of
    ``(g, ghat, err, theta, theta_prev)``, one write of ``(new_ghat,
    new_err, agg, new_theta)``.

    Args:
      g / ghat / err: (M, ...) gradients, stale bank, error-feedback bank.
      theta / theta_prev: the parameter leaf and its predecessor.
      mask: (M,) f32 transmit mask from the censor stage.
      scale: (M,) f32 per-worker quantization scales, derived from
        :func:`int8_stats_batched`'s abs-max via the staged
        ``where(amax > 0, amax/127, 1)`` expression (``ops.py`` does this).
      alpha / beta: traced eq.-(4) scalars (SMEM operands).
    Returns:
      ``(new_ghat, new_err, agg, new_theta)``, all unpadded.
    """
    assert g.shape == ghat.shape == err.shape
    assert mask.shape == (g.shape[0],) and scale.shape == (g.shape[0],)
    if ghat.size == 0:
        return (ghat, jnp.zeros(ghat.shape, ghat.dtype),
                jnp.sum(ghat, axis=0), theta)
    m = g.shape[0]
    shape, n = theta.shape, math.prod(theta.shape)
    s = _hb_scalars(alpha, beta, theta.dtype)
    sc = jnp.stack([mask.astype(jnp.float32),
                    scale.astype(jnp.float32)], axis=1)       # (M, 2)
    g3 = _pad_to_3d(g, block_rows)
    h3 = _pad_to_3d(ghat, block_rows)
    e3 = _pad_to_3d(err, block_rows)
    t2 = _pad_to_2d(theta, block_rows)
    p2 = _pad_to_2d(theta_prev, block_rows)
    block = block_for(g3, block_rows)
    nr = g3.shape[1] // block
    b3 = pl.BlockSpec((m, block, _LANES), lambda i: (0, i, 0))
    b2 = pl.BlockSpec((block, _LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        _fused_int8_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((m, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            b3, b3, b3, b2, b2,
        ],
        out_specs=[b3, b3, b2, b2],
        out_shape=[jax.ShapeDtypeStruct(h3.shape, ghat.dtype),
                   jax.ShapeDtypeStruct(h3.shape, ghat.dtype),
                   jax.ShapeDtypeStruct(t2.shape, ghat.dtype),
                   jax.ShapeDtypeStruct(t2.shape, theta.dtype)],
        interpret=resolve_interpret(interpret),
    )(s, sc, g3, h3, e3, t2, p2)
    ng3, ne3, agg2, out2 = log_traffic("fused_int8_step",
                                       (s, sc, g3, h3, e3, t2, p2), outs)
    up3 = lambda x3: x3.reshape(m, -1)[:, :n].reshape((m,) + shape)  # noqa: E731
    return (up3(ng3), up3(ne3),
            agg2.reshape(-1)[:n].reshape(shape),
            out2.reshape(-1)[:n].reshape(shape))

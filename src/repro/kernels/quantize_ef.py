"""Fused int8 quantize + error-feedback sweep (the Int8Transport hot path).

The reference transport costs four parameter sweeps per step on top of the
censor test: abs-max reduction, quantize round-trip, error-feedback
update, bank advance. Here the round-trip and the error-feedback update
fuse into ONE sweep per leaf (``quantize_ef_batched``: two outputs, one
read of pending/err), fed by a one-sweep per-worker abs-max reduction
(``absmax_batched``). The bank advance reuses
``censor.bank_advance``.

Numerics replicate ``core/quantize.quantize_roundtrip`` exactly: the
abs-max runs in the payload dtype (max is exactly associative, so tile
partials cannot perturb it), the scale is derived host-graph-side with the
same ``where(amax > 0, amax/127, 1)`` expression, and the round-trip
``clip(round(x/scale)) * scale`` runs in f32 — so the pallas backend's
int8 trajectories are bit-identical to the reference backend's.

``interpret=None`` resolves through ``common.interpret_default`` like
every kernel in this package.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (_LANES, _pad_to_3d, block_for, log_traffic,
                     resolve_interpret)

__all__ = ["absmax_batched", "quantize_ef_batched"]


def _absmax_kernel(x_ref, out_ref):
    out_ref[0, 0] = jnp.max(jnp.abs(x_ref[...]))


def absmax_batched(x: jax.Array, *, block_rows: int = 256,
                   interpret: bool | None = None) -> jax.Array:
    """Per-worker ``max |x_m|`` of one (M, ...) leaf, in ``x.dtype``.

    Zero padding cannot raise a max of absolute values, and max is exactly
    associative, so the tiled partials equal the reference
    ``jnp.max(jnp.abs(x_m))`` bit-for-bit.
    """
    m = x.shape[0]
    if x.size == 0:
        return jnp.zeros((m,), x.dtype)
    x3 = _pad_to_3d(x, block_rows)
    block = block_for(x3, block_rows)
    nr = x3.shape[1] // block
    partials = pl.pallas_call(
        _absmax_kernel,
        grid=(m, nr),
        in_specs=[pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda w, i: (w, i)),
        out_shape=jax.ShapeDtypeStruct((m, nr), x.dtype),
        interpret=resolve_interpret(interpret),
    )(x3)
    partials = log_traffic("absmax_batched", (x3,), partials)
    return jnp.max(partials, axis=1)


def _quantize_ef_kernel(s_ref, p_ref, e_ref, q_ref, ne_ref):
    mask = s_ref[0, 0]
    scale = s_ref[0, 1]
    pending = p_ref[...]
    q32 = jnp.clip(jnp.round(pending.astype(jnp.float32) / scale),
                   -127, 127)
    payload = (q32 * scale).astype(q_ref.dtype)
    q_ref[...] = payload
    mk = mask.astype(pending.dtype)
    ne_ref[...] = mk * (pending - payload) \
        + (1.0 - mk) * e_ref[...].astype(pending.dtype)


def quantize_ef_batched(pending: jax.Array, err: jax.Array,
                        mask: jax.Array, scale: jax.Array, *,
                        block_rows: int = 256,
                        interpret: bool | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """One-sweep int8 round-trip + error-feedback update of one (M, ...)
    leaf.

    Args:
      pending: (M, ...) deltas with the error residual already folded in.
      err: (M, ...) current error-feedback bank leaf (any float dtype).
      mask: (M,) f32 transmit mask from the censor stage.
      scale: (M,) f32 per-worker quantization scales (from
        :func:`absmax_batched` via ``where(amax > 0, amax/127, 1)``).
    Returns:
      ``(payload, new_err)`` — the dequantized payload the receiver
      reconstructs (``pending.dtype``) and the next error-feedback leaf
      (transmitted workers keep the fresh residual ``pending - payload``,
      censored workers keep their old residual), both computed from one
      read of each input.
    """
    assert pending.shape == err.shape and mask.shape == (pending.shape[0],)
    if pending.size == 0:
        return pending, jnp.zeros(pending.shape, pending.dtype)
    shape, dtype = pending.shape, pending.dtype
    m = shape[0]
    p3 = _pad_to_3d(pending, block_rows)
    e3 = _pad_to_3d(err, block_rows)
    sc = jnp.stack([mask.astype(jnp.float32),
                    scale.astype(jnp.float32)], axis=1)   # (M, 2)
    block = block_for(p3, block_rows)
    nr = p3.shape[1] // block
    payload, new_err = pl.pallas_call(
        _quantize_ef_kernel,
        grid=(m, nr),
        in_specs=[
            pl.BlockSpec((1, 2), lambda w, i: (w, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(p3.shape, dtype),
                   jax.ShapeDtypeStruct(p3.shape, dtype)],
        interpret=resolve_interpret(interpret),
    )(sc, p3, e3)
    payload, new_err = log_traffic("quantize_ef_batched", (sc, p3, e3),
                                   (payload, new_err))
    n = math.prod(shape[1:])
    return (payload.reshape(m, -1)[:, :n].reshape(shape),
            new_err.reshape(m, -1)[:, :n].reshape(shape))

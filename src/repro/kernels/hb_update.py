"""Fused heavy-ball parameter update (eq. 4).

theta' = theta - alpha*nabla + beta*(theta - theta_prev)

Unfused this is two elementwise ops (5 reads + 2 writes of parameter-sized
arrays); the kernel does it in one sweep (3 reads + 1 write), f32 math with
the output cast back to the parameter dtype. Tiles are (rows, 128) VMEM
blocks.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .censor import _LANES, _pad_to_2d


def _hb_kernel(alpha, beta, t_ref, n_ref, p_ref, out_ref):
    t = t_ref[...].astype(jnp.float32)
    n = n_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    out_ref[...] = (t - alpha * n + beta * (t - p)).astype(out_ref.dtype)


def hb_update(theta: jax.Array, nabla: jax.Array, theta_prev: jax.Array,
              alpha: float, beta: float, *, block_rows: int = 256,
              interpret: bool = True) -> jax.Array:
    assert theta.shape == nabla.shape == theta_prev.shape
    shape, dtype = theta.shape, theta.dtype
    t2 = _pad_to_2d(theta, block_rows)
    n2 = _pad_to_2d(nabla, block_rows)
    p2 = _pad_to_2d(theta_prev, block_rows)
    nr = t2.shape[0] // block_rows
    import functools
    out = pl.pallas_call(
        functools.partial(_hb_kernel, float(alpha), float(beta)),
        grid=(nr,),
        in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(t2.shape, dtype),
        interpret=interpret,
    )(t2, n2, p2)
    n = math.prod(shape)
    return out.reshape(-1)[:n].reshape(shape)

"""Fused heavy-ball parameter update (eq. 4).

theta' = theta - alpha*nabla + beta*(theta - theta_prev)

Unfused this is two elementwise ops (5 reads + 2 writes of parameter-sized
arrays); the kernel does it in one sweep (3 reads + 1 write). Math runs in
``common.compute_dtype``: f32 for sub-f32 params (cast back on write, the
shared oracle contract), native precision for f32/f64 — which keeps the
pallas backend bit-identical to the reference jnp step at those dtypes.
Tiles are (block_rows, 128) VMEM blocks.

``alpha``/``beta`` are **traced scalar operands**, shipped to the kernel as
a (1, 2) SMEM block — never baked into the kernel body. Every point of an
(alpha, beta) hyperparameter grid therefore reuses one compiled program
(the ``repro.sweep`` engine's contract; regression-tested by
``tests/test_kernels.py::test_hb_update_no_retrace_across_alpha_grid``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (_LANES, _pad_to_2d, block_for, compute_dtype,
                     log_traffic, resolve_interpret)


def _hb_kernel(s_ref, t_ref, n_ref, p_ref, out_ref):
    alpha = s_ref[0, 0]
    beta = s_ref[0, 1]
    acc = s_ref.dtype
    t = t_ref[...].astype(acc)
    n = n_ref[...].astype(acc)
    p = p_ref[...].astype(acc)
    out_ref[...] = (t - alpha * n + beta * (t - p)).astype(out_ref.dtype)


def hb_update(theta: jax.Array, nabla: jax.Array, theta_prev: jax.Array,
              alpha, beta, *, block_rows: int = 256,
              interpret: bool | None = None) -> jax.Array:
    """One-sweep eq.-(4) update; ``alpha``/``beta`` may be traced scalars."""
    assert theta.shape == nabla.shape == theta_prev.shape
    shape, dtype = theta.shape, theta.dtype
    if theta.size == 0:
        return theta
    acc = compute_dtype(dtype)
    scalars = jnp.stack([jnp.asarray(alpha).astype(acc),
                         jnp.asarray(beta).astype(acc)]).reshape(1, 2)
    t2 = _pad_to_2d(theta, block_rows)
    n2 = _pad_to_2d(nabla, block_rows)
    p2 = _pad_to_2d(theta_prev, block_rows)
    block = block_for(t2, block_rows)
    nr = t2.shape[0] // block
    out = pl.pallas_call(
        _hb_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(t2.shape, dtype),
        interpret=resolve_interpret(interpret),
    )(scalars, t2, n2, p2)
    out = log_traffic("hb_update", (scalars, t2, n2, p2), out)
    n = math.prod(shape)
    return out.reshape(-1)[:n].reshape(shape)

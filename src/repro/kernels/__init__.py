from . import (censor, common, decode_attention, flash_attention, hb_update,
               ops, quantize_ef, ref)

from . import censor, decode_attention, flash_attention, hb_update, ops, ref

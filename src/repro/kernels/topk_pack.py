"""Fused top-k select/pack + error-feedback sweep (TopKTransport hot path).

The transport's keep masks come from ``jax.lax.top_k`` on the host graph
(selection and the ones-scatter are exact integer/compare ops — batching
cannot perturb them), so the kernel's job is the remaining elementwise
work: select the kept entries into the payload and fold the dropped mass
into the error-feedback bank, in ONE sweep per leaf with two outputs
(``select_pack_ef_batched`` — one read of pending/err/keep).

Numerics replicate the reference ``TopKTransport.encode`` +
``_ef_blend`` exactly: the payload is a ``where`` select (NOT a multiply
— ``x * 0`` would turn negative zeros positive and break bit-parity with
the reference), and the EF blend is the shared
``mk*(pending - payload) + (1-mk)*err`` form. Because every payload entry
is either ``pending`` or ``0.0`` bit-for-bit, ``payload + new_err ==
pending`` holds *bitwise* after a transmit (the ``exact_residual``
contract the conformance suite pins).

``interpret=None`` resolves through ``common.interpret_default`` like
every kernel in this package.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (_LANES, _pad_to_3d, block_for, log_traffic,
                     resolve_interpret)

__all__ = ["select_pack_ef_batched", "select_pack_ef_row"]


def _select_pack_ef_kernel(s_ref, p_ref, e_ref, k_ref, q_ref, ne_ref):
    mask = s_ref[0, 0]
    pending = p_ref[...]
    payload = jnp.where(k_ref[...] != 0, pending, jnp.zeros_like(pending))
    q_ref[...] = payload
    mk = mask.astype(pending.dtype)
    ne_ref[...] = mk * (pending - payload) \
        + (1.0 - mk) * e_ref[...].astype(pending.dtype)


def select_pack_ef_batched(pending: jax.Array, err: jax.Array,
                           keep: jax.Array, mask: jax.Array, *,
                           block_rows: int = 256,
                           interpret: bool | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """One-sweep top-k select + error-feedback update of one (M, ...) leaf.

    Args:
      pending: (M, ...) deltas with the error residual already folded in.
      err: (M, ...) current error-feedback bank leaf (any float dtype).
      keep: (M, ...) 0/1 keep masks in ``pending.dtype`` (from
        ``opt.transport.tree_topk_keep`` — exact, so host-side).
      mask: (M,) f32 transmit mask from the censor stage.
    Returns:
      ``(payload, new_err)`` — the sparse payload the receiver
      reconstructs (kept entries verbatim, zeros elsewhere) and the next
      error-feedback leaf (transmitted workers keep the dropped entries,
      censored workers keep their old residual), from one read of each
      input.
    """
    assert pending.shape == err.shape == keep.shape
    assert mask.shape == (pending.shape[0],)
    if pending.size == 0:
        return pending, jnp.zeros(pending.shape, pending.dtype)
    shape, dtype = pending.shape, pending.dtype
    m = shape[0]
    p3 = _pad_to_3d(pending, block_rows)
    e3 = _pad_to_3d(err, block_rows)
    k3 = _pad_to_3d(keep, block_rows)
    sc = mask.astype(jnp.float32).reshape(m, 1)            # (M, 1)
    block = block_for(p3, block_rows)
    nr = p3.shape[1] // block
    payload, new_err = pl.pallas_call(
        _select_pack_ef_kernel,
        grid=(m, nr),
        in_specs=[
            pl.BlockSpec((1, 1), lambda w, i: (w, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(p3.shape, dtype),
                   jax.ShapeDtypeStruct(p3.shape, dtype)],
        interpret=resolve_interpret(interpret),
    )(sc, p3, e3, k3)
    payload, new_err = log_traffic("select_pack_ef_batched",
                                   (sc, p3, e3, k3), (payload, new_err))
    n = math.prod(shape[1:])
    return (payload.reshape(m, -1)[:, :n].reshape(shape),
            new_err.reshape(m, -1)[:, :n].reshape(shape))


def select_pack_ef_row(pending: jax.Array, err: jax.Array,
                       keep: jax.Array, *, block_rows: int = 256,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """One worker's select/pack + EF sweep (the ``repro.fed`` entry point).

    Runs the batched kernel at M=1 with the transmit mask pinned to 1
    (the event runtime only applies feedback on delivered uploads), so the
    tile partials are bit-identical to the batched step's worker slice.
    """
    payload, new_err = select_pack_ef_batched(
        pending[None], err[None], keep[None], jnp.ones((1,), jnp.float32),
        block_rows=block_rows, interpret=interpret)
    return payload[0], new_err[0]

"""Fused censoring kernels (the CHB hot spot added on top of a train step).

Naively, the eq.-(8) test + bank advance costs three HBM sweeps per
parameter tensor per worker: (1) delta = g - ghat, (2) ||delta||^2
reduction, (3) select ghat' = g or ghat. We fuse into single-sweep
kernels:

  censor_delta_sqnorm : one pass, emits per-tile partial sums of
                        ||g - ghat||^2 (f32 accumulation in VMEM)
  censor_select       : one pass, ghat' = transmit ? g : ghat

plus the leading-M batched variants the ``repro.opt`` pallas backend
dispatches through (see ``ops.py``): ``censor_delta_sqnorm_batched`` /
``sqnorm_batched`` (per-worker eq.-(8) partials over the stacked bank,
without ever materializing the delta tree) and ``censor_bank_advance`` /
``bank_advance`` (the fused bank advance ``ghat + mask * delta``, written
in the arithmetic mask form so it is bit-identical to the reference jnp
step).

Tiles are (block_rows, 128) VMEM blocks — ``block_rows=256`` by default,
shrunk to the tensor's own row count for small tensors (``common.tile_rows``).
Per-worker masks and the transmit flag ride in SMEM scalar blocks.

Kernels default to ``interpret=None``, resolved by
``common.interpret_default()``: the Pallas interpreter everywhere except a
real TPU backend, where they lower through Mosaic for the fused
single-sweep performance. Direct calls and the ``ops.py`` wrappers share
that rule, so neither entry point silently ships interpreter performance
on TPU. Numerics are identical either way.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (_LANES, _pad_to_2d, _pad_to_3d, block_for,
                     log_traffic, resolve_interpret)

__all__ = [
    "censor_delta_sqnorm", "censor_select",
    "censor_delta_sqnorm_batched", "sqnorm_batched",
    "censor_bank_advance", "bank_advance",
]


def _smem_scalar(index_map):
    return pl.BlockSpec((1, 1), index_map, memory_space=pltpu.SMEM)


# --------------------------------------------------- single-tensor kernels
def _delta_sqnorm_kernel(g_ref, h_ref, out_ref):
    d = g_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(d * d)


def censor_delta_sqnorm(g: jax.Array, ghat: jax.Array, *,
                        block_rows: int = 256,
                        interpret: bool | None = None) -> jax.Array:
    """|| g - ghat ||^2 via a tiled one-sweep Pallas reduction."""
    assert g.shape == ghat.shape
    if g.size == 0:
        return jnp.zeros((), jnp.float32)
    g2 = _pad_to_2d(g, block_rows)
    h2 = _pad_to_2d(ghat, block_rows)
    block = block_for(g2, block_rows)
    nr = g2.shape[0] // block
    partials = pl.pallas_call(
        _delta_sqnorm_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, 1), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(g2, h2)
    partials = log_traffic("censor_delta_sqnorm", (g2, h2), partials)
    return jnp.sum(partials)


def _select_kernel(t_ref, g_ref, h_ref, out_ref):
    transmit = t_ref[0, 0] != 0
    g = g_ref[...].astype(out_ref.dtype)
    h = h_ref[...]
    out_ref[...] = jnp.where(transmit, g, h)


def censor_select(g: jax.Array, ghat: jax.Array, transmit: jax.Array, *,
                  block_rows: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """ghat' = transmit ? g : ghat — single fused sweep."""
    assert g.shape == ghat.shape
    orig_shape, orig_dtype = ghat.shape, ghat.dtype
    if ghat.size == 0:
        return ghat
    g2 = _pad_to_2d(g, block_rows)
    h2 = _pad_to_2d(ghat, block_rows)
    t = jnp.asarray(transmit, jnp.int32).reshape(1, 1)
    block = block_for(g2, block_rows)
    nr = g2.shape[0] // block
    out = pl.pallas_call(
        _select_kernel,
        grid=(nr,),
        in_specs=[
            _smem_scalar(lambda i: (0, 0)),
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(h2.shape, orig_dtype),
        interpret=resolve_interpret(interpret),
    )(t, g2, h2)
    out = log_traffic("censor_select", (t, g2, h2), out)
    n = math.prod(orig_shape)
    return out.reshape(-1)[:n].reshape(orig_shape)


# ------------------------------------------------ leading-M batched kernels
def _delta_sqnorm_batched_kernel(g_ref, h_ref, out_ref):
    # subtraction runs in the bank dtype (matching the reference step's
    # ``g.astype(h.dtype) - h``), the square-sum accumulates in f32
    d = (g_ref[...].astype(h_ref.dtype) - h_ref[...]).astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(d * d)


def censor_delta_sqnorm_batched(g: jax.Array, ghat: jax.Array, *,
                                block_rows: int = 256,
                                interpret: bool | None = None) -> jax.Array:
    """Per-worker ||g_m - ghat_m||^2 partials of one (M, ...) leaf.

    One fused sweep over the stacked bank: the delta tree is never
    materialized. Returns (M,) f32 — the leaf's contribution to the
    eq.-(8) left-hand side.
    """
    assert g.shape == ghat.shape
    m = g.shape[0]
    if g.size == 0:
        return jnp.zeros((m,), jnp.float32)
    g3 = _pad_to_3d(g, block_rows)
    h3 = _pad_to_3d(ghat, block_rows)
    block = block_for(g3, block_rows)
    nr = g3.shape[1] // block
    partials = pl.pallas_call(
        _delta_sqnorm_batched_kernel,
        grid=(m, nr),
        in_specs=[
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda w, i: (w, i)),
        out_shape=jax.ShapeDtypeStruct((m, nr), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(g3, h3)
    partials = log_traffic("censor_delta_sqnorm_batched", (g3, h3), partials)
    return jnp.sum(partials, axis=1)


def _sqnorm_batched_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(x * x)


def sqnorm_batched(x: jax.Array, *, block_rows: int = 256,
                   interpret: bool | None = None) -> jax.Array:
    """Per-worker ||x_m||^2 of one (M, ...) leaf (f32 accumulation).

    The pending-delta variant of :func:`censor_delta_sqnorm_batched`, for
    transports that materialize the pending tree anyway (error feedback).
    Tile partials are identical to the fused variant's, so the fed
    runtime's row entry point (``M=1``) reproduces the batched step's
    per-worker values bit-for-bit.
    """
    m = x.shape[0]
    if x.size == 0:
        return jnp.zeros((m,), jnp.float32)
    x3 = _pad_to_3d(x, block_rows)
    block = block_for(x3, block_rows)
    nr = x3.shape[1] // block
    partials = pl.pallas_call(
        _sqnorm_batched_kernel,
        grid=(m, nr),
        in_specs=[pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda w, i: (w, i)),
        out_shape=jax.ShapeDtypeStruct((m, nr), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(x3)
    partials = log_traffic("sqnorm_batched", (x3,), partials)
    return jnp.sum(partials, axis=1)


def _censor_bank_advance_kernel(m_ref, g_ref, h_ref, out_ref):
    h = h_ref[...]
    g = g_ref[...].astype(h.dtype)
    mask = m_ref[0, 0].astype(h.dtype)
    out_ref[...] = h + mask * (g - h)


def censor_bank_advance(g: jax.Array, ghat: jax.Array, mask: jax.Array, *,
                        block_rows: int = 256,
                        interpret: bool | None = None) -> jax.Array:
    """Fused censor-select bank advance of one (M, ...) leaf.

    ``ghat'_m = ghat_m + mask_m * (g_m - ghat_m)`` in one sweep — the
    arithmetic form of "transmitted workers replace their bank row",
    matching the reference step's ``h + bcast(mask) * delta`` expression
    bit-for-bit (a ``where``-select would NOT: ``h + (g - h) != g`` in
    floating point). ``mask`` is the censor's (M,) f32 transmit mask,
    delivered to the kernel as a per-worker SMEM scalar.
    """
    assert g.shape == ghat.shape and mask.shape == (g.shape[0],)
    if ghat.size == 0:
        return ghat
    shape, dtype = ghat.shape, ghat.dtype
    m = g.shape[0]
    g3 = _pad_to_3d(g, block_rows)
    h3 = _pad_to_3d(ghat, block_rows)
    mk = mask.astype(jnp.float32).reshape(m, 1)
    block = block_for(g3, block_rows)
    nr = g3.shape[1] // block
    out = pl.pallas_call(
        _censor_bank_advance_kernel,
        grid=(m, nr),
        in_specs=[
            _smem_scalar(lambda w, i: (w, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        out_shape=jax.ShapeDtypeStruct(h3.shape, dtype),
        interpret=resolve_interpret(interpret),
    )(mk, g3, h3)
    out = log_traffic("censor_bank_advance", (mk, g3, h3), out)
    n = math.prod(shape[1:])
    return out.reshape(m, -1)[:, :n].reshape(shape)


def _bank_advance_kernel(m_ref, q_ref, h_ref, out_ref):
    h = h_ref[...]
    mask = m_ref[0, 0].astype(h.dtype)
    out_ref[...] = h + mask * q_ref[...].astype(h.dtype)


def bank_advance(ghat: jax.Array, payload: jax.Array, mask: jax.Array, *,
                 block_rows: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    """``ghat'_m = ghat_m + mask_m * payload_m`` in one fused sweep.

    The pre-encoded-payload variant of :func:`censor_bank_advance`, used
    when the transport materializes the payload anyway (quantization).
    """
    assert payload.shape == ghat.shape and mask.shape == (ghat.shape[0],)
    if ghat.size == 0:
        return ghat
    shape, dtype = ghat.shape, ghat.dtype
    m = ghat.shape[0]
    q3 = _pad_to_3d(payload, block_rows)
    h3 = _pad_to_3d(ghat, block_rows)
    mk = mask.astype(jnp.float32).reshape(m, 1)
    block = block_for(q3, block_rows)
    nr = q3.shape[1] // block
    out = pl.pallas_call(
        _bank_advance_kernel,
        grid=(m, nr),
        in_specs=[
            _smem_scalar(lambda w, i: (w, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
            pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, _LANES), lambda w, i: (w, i, 0)),
        out_shape=jax.ShapeDtypeStruct(h3.shape, dtype),
        interpret=resolve_interpret(interpret),
    )(mk, q3, h3)
    out = log_traffic("bank_advance", (mk, q3, h3), out)
    n = math.prod(shape[1:])
    return out.reshape(m, -1)[:, :n].reshape(shape)

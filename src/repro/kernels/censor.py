"""Fused censoring kernels (the CHB hot spot added on top of a train step).

Naively, the eq.-(8) test + bank advance costs three HBM sweeps per
parameter tensor per worker: (1) delta = g - ghat, (2) ||delta||^2
reduction, (3) select ghat' = g or ghat. We fuse into two single-sweep
kernels:

  censor_delta_sqnorm : one pass, emits per-tile partial sums of
                        ||g - ghat||^2 (f32 accumulation in VMEM)
  censor_select       : one pass, ghat' = transmit ? g : ghat

Block shapes are (8k, 128)-aligned for f32 / (16k, 128) for bf16 VMEM tiles.

Both kernels default to ``interpret=True`` — the Pallas interpreter, which
runs on any backend (including the CPU-only CI container) and is what the
tier-1 suite validates against the ``kernels/ref.py`` oracles. On real TPU
hardware pass ``interpret=False`` to lower through Mosaic and get the fused
single-sweep performance; numerics are identical either way.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


_LANES = 128


def _pad_to_2d(x: jax.Array, rows: int) -> jax.Array:
    """Flatten to (R, 128) padding with zeros; R a multiple of `rows`."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = _LANES
    r = math.ceil(n / cols)
    r = math.ceil(r / rows) * rows
    pad = r * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(r, cols)


def _delta_sqnorm_kernel(g_ref, h_ref, out_ref):
    d = g_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(d * d)


def censor_delta_sqnorm(g: jax.Array, ghat: jax.Array, *,
                        block_rows: int = 256,
                        interpret: bool = True) -> jax.Array:
    """|| g - ghat ||^2 via a tiled one-sweep Pallas reduction."""
    assert g.shape == ghat.shape
    g2 = _pad_to_2d(g, block_rows)
    h2 = _pad_to_2d(ghat, block_rows)
    nr = g2.shape[0] // block_rows
    partials = pl.pallas_call(
        _delta_sqnorm_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, 1), jnp.float32),
        interpret=interpret,
    )(g2, h2)
    return jnp.sum(partials)


def _select_kernel(g_ref, h_ref, t_ref, out_ref):
    transmit = t_ref[0, 0] != 0
    g = g_ref[...].astype(out_ref.dtype)
    h = h_ref[...]
    out_ref[...] = jnp.where(transmit, g, h)


def censor_select(g: jax.Array, ghat: jax.Array, transmit: jax.Array, *,
                  block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """ghat' = transmit ? g : ghat — single fused sweep."""
    assert g.shape == ghat.shape
    orig_shape, orig_dtype = ghat.shape, ghat.dtype
    g2 = _pad_to_2d(g, block_rows)
    h2 = _pad_to_2d(ghat, block_rows)
    t = jnp.asarray(transmit, jnp.int32).reshape(1, 1)
    nr = g2.shape[0] // block_rows
    out = pl.pallas_call(
        _select_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(h2.shape, orig_dtype),
        interpret=interpret,
    )(g2, h2, t)
    n = math.prod(orig_shape)
    return out.reshape(-1)[:n].reshape(orig_shape)

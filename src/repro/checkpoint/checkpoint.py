"""Pytree checkpointing: flattened-path npz, sharding-aware restore.

save() gathers device arrays to host (fine for the single-process CPU
container; on a real cluster this is the process-0 path of a distributed
checkpointer). restore() re-places leaves with the provided shardings.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # bfloat16 has no numpy equivalent:
            arr = np.asarray(jax.numpy.asarray(leaf,
                                               jax.numpy.float32))  # lossless
        flat[jax.tree_util.keystr(path)] = arr
    return flat


def save(path: str, tree: Any, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz",
             **{k.replace("/", _SEP): v for k, v in flat.items()})
    if metadata is not None:
        with open(path.rstrip(".npz") + ".meta.json", "w") as f:
            json.dump(metadata, f)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or SDS)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    flat_sh = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(paths))
    for (p, leaf), sh in zip(paths, flat_sh):
        key = jax.tree_util.keystr(p).replace("/", _SEP)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        arr = jax.numpy.asarray(arr).astype(leaf.dtype)  # bf16 round-trip
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(path.rstrip(".npz") + ".meta.json") as f:
        return json.load(f)

from . import checkpoint

"""MusicGen-medium [arXiv:2306.05284].

48L d_model=1536 24H (MHA kv=24, head_dim 64) d_ff=6144, vocab 2048
(EnCodec codebook). Decoder-only over EnCodec tokens; the conditioning
frontend (text/melody -> frame embeddings) is STUBBED: input_specs provides
precomputed (B, 256, 768) frame embeddings consumed as a prefix via a
learned projector (DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern="A",
    activation="gelu",
    frontend="audio",
    num_frontend_tokens=256,
    d_frontend=768,
    scan_period=1,
    long_context_window=4096,    # long_500k via sliding-window VARIANT
    source="arXiv:2306.05284",
).validate()

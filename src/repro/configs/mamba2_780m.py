"""Mamba-2 780m [arXiv:2405.21060].

48L d_model=1536, attention-free SSD (state-space duality), ssm_state=128,
head_dim 64, expand 2, vocab 50280. No MLP blocks (d_ff=0): the mamba mixer
IS the layer, as in the paper. long_500k native (constant-size state).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,          # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    layer_pattern="M",
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    scan_period=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
).validate()

"""Gemma-3 12B [hf:google/gemma-3-1b-pt family, scaled per assignment].

48L d_model=3840 16H (GQA kv=8, head_dim 256) d_ff=15360 vocab=262144.
5:1 local:global attention (sliding window 1024), 128k context class.
long_500k is supported natively: 40/48 layers are sliding-window.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    layer_pattern="SSSSSA",      # 5 local : 1 global
    sliding_window=1024,
    qk_norm=True,
    activation="gelu",
    rope_theta=1e6,
    scan_period=6,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt (scaled)",
).validate()

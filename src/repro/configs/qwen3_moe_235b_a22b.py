"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family, scaled per assignment].

94L d_model=4096 64H (GQA kv=4, head_dim 128, qk-norm) 128 experts top-8,
expert d_ff=1536, vocab 151936. MoE on every layer (no shared dense MLP).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    layer_pattern="A",
    qk_norm=True,
    activation="swiglu",
    num_experts=128,
    num_experts_per_tok=8,
    d_ff_expert=1536,
    rope_theta=1e6,
    scan_period=1,
    long_context_window=4096,   # explicit long-context VARIANT for long_500k
    source="hf:Qwen/Qwen3-30B-A3B (scaled)",
).validate()

"""Qwen3 4B [hf:Qwen/Qwen3-8B family, scaled per assignment].

36L d_model=2560 32H (GQA kv=8, head_dim 128, qk-norm) d_ff=9728
vocab=151936, SwiGLU.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    layer_pattern="A",
    qk_norm=True,
    activation="swiglu",
    rope_theta=1e6,
    scan_period=1,
    tie_embeddings=True,
    long_context_window=4096,    # long_500k via sliding-window VARIANT
    source="hf:Qwen/Qwen3-8B (scaled)",
).validate()

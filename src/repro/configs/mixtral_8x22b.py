"""Mixtral 8x22B [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8, head_dim 128) expert d_ff=16384,
8 experts top-2, vocab 32768, sliding-window attention (4096) per the
assignment. long_500k supported natively via SWA.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    layer_pattern="S",
    sliding_window=4096,
    activation="swiglu",
    num_experts=8,
    num_experts_per_tok=2,
    d_ff_expert=16384,
    rope_theta=1e6,
    scan_period=1,
    source="arXiv:2401.04088",
).validate()

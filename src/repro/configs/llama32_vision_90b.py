"""Llama-3.2-Vision 90B [hf:meta-llama/Llama-3.2-11B-Vision, scaled].

100L d_model=8192 64H (GQA kv=8, head_dim 128) d_ff=28672 vocab=128256.
Every 5th layer is a cross-attention image layer. The ViT vision encoder is
STUBBED: input_specs provides (B, 1600, 7680) patch embeddings consumed via
a learned projector + cross-attention (DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    layer_pattern="AAAAX",
    activation="swiglu",
    rope_theta=5e5,
    frontend="vision",
    num_frontend_tokens=1600,
    d_frontend=7680,
    scan_period=5,
    long_context_window=4096,    # long_500k via sliding-window VARIANT
    source="hf:meta-llama/Llama-3.2-11B-Vision (scaled)",
).validate()

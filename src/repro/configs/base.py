"""Model configuration schema shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


# Mixer kinds (per-layer): A full attention, S sliding-window attention,
# M mamba2 (SSD), X cross-attention (VLM image layers).
MIXERS = ("A", "S", "M", "X")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention / layer pattern ---
    # repeated cyclically to num_layers; one char per layer from MIXERS
    layer_pattern: str = "A"
    sliding_window: int = 4096
    qk_norm: bool = False
    rope_theta: float = 1e4

    # --- feedforward ---
    activation: str = "swiglu"       # swiglu | squared_relu | gelu
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    d_ff_expert: int = 0
    moe_layer_period: int = 1        # layer l uses MoE iff num_experts>0 and
    moe_layer_offset: int = 0        # (l % period) == offset
    router_aux_coef: float = 0.01

    # --- SSM (mamba2 / SSD) ---
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- modality frontend stubs (vlm / audio) ---
    frontend: Optional[str] = None   # "vision" | "audio"
    num_frontend_tokens: int = 0
    d_frontend: int = 0

    # --- misc ---
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # period used for scan-over-layers; must divide num_layers and be a
    # multiple of the layer_pattern / MoE interleave periods
    scan_period: int = 1
    # if set, 'A' layers are lowered as sliding-window with this window for
    # the long_500k shape (the explicit long-context VARIANT; DESIGN.md §4)
    long_context_window: Optional[int] = None
    # source citation
    source: str = ""

    # ------------------------------------------------------------ derived
    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def mixer_at(self, layer: int) -> str:
        return self.layer_pattern[layer % len(self.layer_pattern)]

    def is_moe_layer(self, layer: int) -> bool:
        return (self.num_experts > 0
                and layer % self.moe_layer_period == self.moe_layer_offset)

    def layer_plan(self) -> Tuple[Tuple[str, bool], ...]:
        """(mixer, is_moe) per layer."""
        return tuple((self.mixer_at(l), self.is_moe_layer(l))
                     for l in range(self.num_layers))

    def block_plan(self) -> Tuple[Tuple[str, bool], ...]:
        """The repeating super-block pattern (length scan_period)."""
        plan = self.layer_plan()
        period = self.scan_period
        assert self.num_layers % period == 0, (self.name, period)
        proto = plan[:period]
        for s in range(self.num_layers // period):
            assert plan[s * period:(s + 1) * period] == proto, \
                f"{self.name}: layer plan not periodic with scan_period={period}"
        return proto

    @property
    def num_superblocks(self) -> int:
        return self.num_layers // self.scan_period

    def validate(self) -> "ModelConfig":
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if "M" in self.layer_pattern:
            assert self.ssm_state_dim > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.num_experts:
            assert 0 < self.num_experts_per_tok <= self.num_experts
            assert self.d_ff_expert > 0
        if self.frontend:
            assert self.num_frontend_tokens > 0 and self.d_frontend > 0
        self.block_plan()
        return self

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                max_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        # keep the pattern FLAVOUR: ordered-unique mixers, fit to num_layers
        seen = []
        for l in range(self.num_layers):
            mx = self.mixer_at(l)
            if mx not in seen:
                seen.append(mx)
        pattern = "".join((seen * num_layers)[:num_layers])
        heads = 4
        kv = min(self.num_kv_heads, heads)
        kv = next(k for k in range(kv, 0, -1) if heads % k == 0)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=d_model * 4 if self.d_ff > 0 else 0,
            vocab_size=vocab,
            layer_pattern=pattern or "A",
            sliding_window=64,
            num_experts=min(self.num_experts, max_experts),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            d_ff_expert=d_model * 2 if self.num_experts else 0,
            ssm_state_dim=32 if self.ssm_state_dim else 0,
            ssm_head_dim=32 if self.ssm_state_dim else 64,
            ssm_chunk=16,
            num_frontend_tokens=8 if self.frontend else 0,
            d_frontend=64 if self.frontend else 0,
            scan_period=num_layers,
            dtype="float32",
            long_context_window=None,
        ).validate()

"""Jamba-1.5-Large 398B [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8, head_dim 128) d_ff=24576, MoE 16 experts
top-2 on every other layer, Mamba:attention 7:1 interleave (1 attention
layer per 8-layer block). long_500k native (SSM + 9 attention layers).

Adaptation note (DESIGN.md §3): Jamba uses Mamba-1 selective scan; we use
the Mamba-2 SSD mixer (state 64) — the TPU-native chunked dual form.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern="MMMAMMMM",    # attention at index 3 of each 8-block
    activation="swiglu",
    num_experts=16,
    num_experts_per_tok=2,
    d_ff_expert=24576,
    moe_layer_period=2,
    moe_layer_offset=1,
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    scan_period=8,
    source="arXiv:2403.19887",
).validate()

"""A ~124M decoder LM used by the end-to-end CHB training example
(examples/train_llm_chb.py). Not part of the assigned pool; sized so a few
hundred CHB steps run on CPU/laptop scale as the paper's "train a neural
network" experiment scaled up to the LLM era.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chb-paper-lm-124m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=32768,
    layer_pattern="A",
    activation="swiglu",
    scan_period=1,
    dtype="float32",
    source="paper Sec. IV NN experiment, scaled to an LM",
).validate()

"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

from . import (chb_paper_lm, gemma3_12b, jamba15_large_398b,
               llama32_vision_90b, mamba2_780m, mixtral_8x22b,
               musicgen_medium, nemotron4_15b, phi3_medium_14b, qwen3_4b,
               qwen3_moe_235b_a22b)
from .base import ModelConfig

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        qwen3_moe_235b_a22b.CONFIG,
        gemma3_12b.CONFIG,
        musicgen_medium.CONFIG,
        mixtral_8x22b.CONFIG,
        mamba2_780m.CONFIG,
        llama32_vision_90b.CONFIG,
        jamba15_large_398b.CONFIG,
        qwen3_4b.CONFIG,
        phi3_medium_14b.CONFIG,
        nemotron4_15b.CONFIG,
        chb_paper_lm.CONFIG,
    ]
}

ASSIGNED = [n for n in ARCHS if n != "chb-paper-lm-124m"]


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]

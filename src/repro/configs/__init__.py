from .base import ModelConfig
from .registry import ARCHS, ASSIGNED, get

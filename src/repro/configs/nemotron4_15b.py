"""Nemotron-4 15B [arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8, head_dim 128) d_ff=24576 vocab=256000,
squared-ReLU MLP (no gating), RoPE.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    layer_pattern="A",
    activation="squared_relu",
    rope_theta=1e4,
    scan_period=1,
    long_context_window=4096,    # long_500k via sliding-window VARIANT
    source="arXiv:2402.16819",
).validate()

"""Schema-versioned BENCH_*.json artifacts: build, validate, diff-ready.

``benchmarks/run.py --json`` emits one artifact per invocation; this
module owns its layout so every producer (the benchmark driver, CI's
smoke job) and consumer (``tools/bench_diff.py``, the CI validator)
agrees on one contract:

    {"schema_version": 1,
     "kind": "repro-bench",
     "name": "<artifact name>",
     "env": {"jax_version": "...", "backend": "cpu|tpu|gpu",
             "x64": true|false},
     "registry": ["chb", "gd", ...],
     "failed": ["<benchmark name>", ...],
     "benchmarks": {"<name>": {"row": "name,us_per_call,derived",
                               "seconds": <float>, ...payload}}}

Per-benchmark payloads are free-form beyond the required ``row``; the
conventional keys (``specs`` — per-point ``repro.opt`` registry specs,
``backend`` — "reference"/"pallas" axes, ``measured_bytes`` /
``analytic_bytes`` — roofline accounting, ``trace_counts`` — retrace
audit) are documented in docs/observability.md. ``validate_artifact``
enforces the envelope plus those conventions where present, and the CLI
(``python -m repro.obs.bench --validate PATH``) is what CI runs against
the artifact it just produced.
"""
from __future__ import annotations

import json
from typing import Any, Optional

#: Version of the artifact envelope (bump on breaking layout changes).
SCHEMA_VERSION = 1

#: The ``kind`` tag distinguishing these artifacts from other JSON files.
KIND = "repro-bench"


def environment() -> dict:
    """The execution environment stamped into every artifact."""
    import jax
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "x64": bool(jax.config.read("jax_enable_x64")),
    }


def make_artifact(name: str, benchmarks: dict, *,
                  failed: Optional[list] = None,
                  registry: Optional[list] = None,
                  extra: Optional[dict] = None) -> dict:
    """Assemble a schema-conforming artifact document.

    Args:
      name: artifact name (conventionally the ``BENCH_<name>.json`` stem).
      benchmarks: ``{bench_name: payload}``; every payload must carry a
        ``row`` CSV string (the driver adds it).
      failed: benchmark names that raised (empty = clean run).
      registry: the ``repro.opt`` algorithm names available when the
        artifact was produced (provenance for spec round-trips).
      extra: additional top-level keys (must not collide with the schema).
    Returns:
      The artifact dict (validated — raises ``ValueError`` on a
      malformed document, so producers fail at build time, not in CI).
    """
    doc: dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "kind": KIND,
        "name": name,
        "env": environment(),
        "registry": list(registry or []),
        "failed": list(failed or []),
        "benchmarks": dict(benchmarks),
    }
    for k, v in (extra or {}).items():
        if k in doc:
            raise ValueError(f"extra key {k!r} collides with the schema")
        doc[k] = v
    errors = validate_artifact(doc)
    if errors:
        raise ValueError("malformed artifact: " + "; ".join(errors))
    return doc


def validate_artifact(doc: Any) -> list[str]:
    """All schema violations in ``doc`` (empty list = valid).

    Checks the envelope (version, kind, env, benchmarks) and the
    documented per-benchmark conventions where the keys are present
    (``specs`` must be a list of dicts/None, ``backend`` a string, byte
    counts numeric). Unknown extra keys are allowed — the schema is
    open for extension, closed for modification.
    """
    errs: list[str] = []
    if not isinstance(doc, dict):
        return [f"artifact must be a JSON object, got {type(doc).__name__}"]
    ver = doc.get("schema_version")
    if not isinstance(ver, int):
        errs.append("schema_version missing or not an int")
    elif ver > SCHEMA_VERSION:
        errs.append(f"schema_version {ver} is newer than supported "
                    f"{SCHEMA_VERSION}")
    if doc.get("kind") != KIND:
        errs.append(f"kind must be {KIND!r}, got {doc.get('kind')!r}")
    if not isinstance(doc.get("name"), str) or not doc.get("name"):
        errs.append("name missing or empty")
    env = doc.get("env")
    if not isinstance(env, dict):
        errs.append("env missing or not an object")
    else:
        for k in ("jax_version", "backend", "x64"):
            if k not in env:
                errs.append(f"env.{k} missing")
    if not isinstance(doc.get("failed"), list):
        errs.append("failed missing or not a list")
    benches = doc.get("benchmarks")
    if not isinstance(benches, dict):
        errs.append("benchmarks missing or not an object")
        return errs
    for bname, payload in benches.items():
        where = f"benchmarks[{bname!r}]"
        if not isinstance(payload, dict):
            errs.append(f"{where} is not an object")
            continue
        if not isinstance(payload.get("row"), str):
            errs.append(f"{where}.row missing or not a string")
        if "seconds" in payload and \
                not isinstance(payload["seconds"], (int, float)):
            errs.append(f"{where}.seconds is not a number")
        if "specs" in payload:
            specs = payload["specs"]
            vals = list(specs.values()) if isinstance(specs, dict) \
                else specs if isinstance(specs, list) else None
            if vals is None or any(
                    s is not None and not isinstance(s, dict)
                    for s in vals):
                errs.append(f"{where}.specs must be a list (per point) or "
                            "name-keyed object of spec objects/nulls")
        if "backend" in payload and not isinstance(payload["backend"],
                                                   (str, list)):
            errs.append(f"{where}.backend must be a string or list")
        for k in ("measured_bytes", "analytic_bytes"):
            if k in payload and not isinstance(payload[k], dict):
                errs.append(f"{where}.{k} must be an object "
                            "(per-backend/per-kernel byte counts)")
    return errs


def write_artifact(doc: dict, path: str) -> str:
    """Validate and write an artifact; returns ``path``."""
    errors = validate_artifact(doc)
    if errors:
        raise ValueError("refusing to write malformed artifact: "
                         + "; ".join(errors))
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_artifact(path: str, *, validate: bool = True) -> dict:
    """Load (and by default validate) a BENCH_*.json artifact."""
    with open(path) as f:
        doc = json.load(f)
    if validate:
        errors = validate_artifact(doc)
        if errors:
            raise ValueError(f"{path}: " + "; ".join(errors))
    return doc


def _main(argv: Optional[list] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.bench",
        description="Validate BENCH_*.json artifacts against the schema.")
    ap.add_argument("--validate", metavar="PATH", action="append",
                    default=[], help="artifact file to validate "
                    "(repeatable); exits 1 on any violation")
    args = ap.parse_args(argv)
    if not args.validate:
        ap.error("nothing to do; pass --validate PATH")
    bad = 0
    for path in args.validate:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}")
            bad += 1
            continue
        errors = validate_artifact(doc)
        if errors:
            bad += 1
            for e in errors:
                print(f"{path}: {e}")
        else:
            n = len(doc.get("benchmarks", {}))
            print(f"{path}: ok (schema_version="
                  f"{doc.get('schema_version')}, {n} benchmark(s))")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(_main())

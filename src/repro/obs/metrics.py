"""In-graph metric collection: the ``MetricBag`` and its collectors.

A **MetricBag** is a flat ``dict[str, jax.Array]`` of named scalar
observables for one round — a pytree, so it rides through ``lax.scan`` /
``lax.map`` / ``vmap`` unchanged and stacks into ``(K,)`` (or ``(B, K)``)
series on the way out. The paper's primary observables (censor rate,
uplink bytes, bank/gradient norms — Figs. 1, 10-12) are all per-round
scalars, which is what makes one flat bag the right shape for every
execution surface.

Collection is strictly **read-only**: every entry is computed *from* the
optimizer state and step stats the run already produced, never fed back
into them, so a metrics-on trajectory is bit-identical to a metrics-off
one (pinned by tests/test_obs.py against the golden fingerprints) and the
bag can be dropped without touching the compiled step's math.

Two layers of observables:

  * **Base metrics** (:func:`step_metrics`) — what every composition
    reports: ``censor_rate``, exact cumulative ``uplink_bytes`` (derived
    from the split-int32 counters in ``core/accounting``), uplink/downlink
    counts, ``agg_grad_sqnorm``/``step_sqnorm``/``delta_sqnorm_mean``
    (free — already in ``StepStats``), and ``bank_sqnorm`` (one extra
    read-sweep over the stale bank, the only metric that costs HBM
    traffic).
  * **Stage metrics** — each censor/transport/server stage opts in via a
    ``metrics(...) -> dict`` hook; keys are namespaced by the stage's
    registry kind (``censor/stochastic/tau``, ``transport/int8/
    ef_residual_sqnorm``), so a bag is self-describing for any registered
    composition — including user-registered stages.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..core.util import tree_sqnorm

#: One round's named scalar observables (a flat pytree of () arrays).
MetricBag = Dict[str, jax.Array]


def _stage_kind(stage, table: dict[str, type]) -> str:
    """Registry kind of a stage, falling back to its lowercased class."""
    for kind, cls in table.items():
        if type(stage) is cls:
            return kind
    return type(stage).__name__.lower()


def stage_metrics(opt, state) -> MetricBag:
    """The stage-hook half of the bag, keys namespaced by registry kind.

    Calls each stage's ``metrics`` hook with its own slice of the
    optimizer state (censor state / error-feedback bank / nothing) and
    prefixes the returned keys. Stages without the hook — e.g. a custom
    class predating it — contribute nothing.
    """
    from ..opt.registry import CENSOR_KINDS, SERVER_KINDS, TRANSPORT_KINDS
    bag: MetricBag = {}
    for stage, table, ns, arg in (
            (opt.censor, CENSOR_KINDS, "censor", (state.censor,)),
            (opt.transport, TRANSPORT_KINDS, "transport", (state.err,)),
            (opt.server, SERVER_KINDS, "server", ())):
        hook = getattr(stage, "metrics", None)
        if hook is None:
            continue
        kind = _stage_kind(stage, table)
        for k, v in hook(*arg).items():
            bag[f"{ns}/{kind}/{k}"] = jnp.asarray(v)
    return bag


def step_metrics(opt, state, stats) -> MetricBag:
    """The full per-round bag for one composed step.

    Args:
      opt: the ``ComposedOptimizer`` (or anything with the three stage
        attributes) that produced the step.
      state: the post-step ``OptState``.
      stats: the step's ``StepStats``.
    Returns:
      A flat MetricBag of f32/() scalars — base metrics plus every stage
      hook's namespaced observables.
    """
    bag: MetricBag = {
        "censor_rate": 1.0 - jnp.mean(stats.mask.astype(jnp.float32)),
        "transmit_rate": jnp.mean(stats.mask.astype(jnp.float32)),
        "agg_grad_sqnorm": stats.agg_grad_sqnorm,
        "step_sqnorm": stats.step_sq,
        "delta_sqnorm_mean": jnp.mean(stats.delta_sq),
        "bank_sqnorm": tree_sqnorm(state.ghat),
    }
    bag.update(state.comm.metrics())
    bag.update(stage_metrics(opt, state))
    return bag


def merge_shard_bags(bags, weights=None) -> MetricBag:
    """Fold K per-shard MetricBags into one cohort-level bag.

    The sharded fed runtime (``fed.mesh``) collects one bag per mesh
    shard; this merges them at fold time so consumers see the same single
    bag every other surface produces. Merge rule per key, by suffix
    convention:

      * ``*rate`` / ``*mean`` — weighted mean (weights default to
        uniform; pass per-shard worker counts for exactness under uneven
        shards);
      * ``*max`` — max; ``*min`` — min;
      * everything else (counts, cumulative bytes, sqnorms of per-shard
        disjoint state) — sum.

    Cross-shard non-additive observables (``agg_grad_sqnorm`` is
    ``||sum of partials||^2``, not a sum of shard norms) must be
    overwritten by the caller with the post-fold value — the mesh runtime
    does exactly that.
    """
    bags = list(bags)
    if not bags:
        return {}
    if weights is None:
        weights = [1.0] * len(bags)
    total_w = sum(weights)
    out: MetricBag = {}
    for key in bags[0]:
        vals = [b[key] for b in bags]
        if key.endswith("rate") or key.endswith("mean"):
            out[key] = sum(w * v for w, v in zip(weights, vals)) / total_w
        elif key.endswith("max"):
            out[key] = functools.reduce(jnp.maximum, vals)
        elif key.endswith("min"):
            out[key] = functools.reduce(jnp.minimum, vals)
        else:
            out[key] = sum(vals)
    return out


def metric_names(opt, params) -> tuple[str, ...]:
    """The bag's key set for a composition, without running a step.

    Evaluates :func:`step_metrics` under ``jax.eval_shape`` on the
    iteration-0 state (zero cost, nothing compiled) — useful for schema
    checks and for writers that want a stable header before round 1.
    """
    def keys_of(p):
        state = opt.init(p)
        m = jax.tree_util.tree_leaves(state.ghat)[0].shape[0]
        from ..opt.api import StepStats
        stats = StepStats(mask=jnp.ones((m,), jnp.float32),
                          delta_sq=jnp.zeros((m,), jnp.float32),
                          step_sq=jnp.zeros((), jnp.float32),
                          agg_grad_sqnorm=jnp.zeros((), jnp.float32))
        return step_metrics(opt, state, stats)
    shapes = jax.eval_shape(keys_of, params)
    return tuple(sorted(shapes))


def summarize(series: Any, reducer=None) -> dict[str, float]:
    """Collapse a stacked ``{name: (K,) array}`` bag to final host floats.

    Args:
      series: the stacked metrics pytree a trajectory returns.
      reducer: optional ``(array) -> scalar``; default takes the last
        round's value (cumulative metrics) — pass ``np.mean`` &co for
        rate-like series.
    Returns:
      ``{name: float}`` — JSON-ready.
    """
    import numpy as np
    out = {}
    for k, v in series.items():
        arr = np.asarray(v)
        out[k] = float(reducer(arr) if reducer is not None else arr[-1])
    return out

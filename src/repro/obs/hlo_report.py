"""Compiled-HLO hotspot reports: trip-count-weighted collectives and HBM.

The profiler half of ``repro.obs`` answers "where did the time go" on a
timeline; this module answers "where will the bytes go" *statically*,
from the compiled (post-SPMD, scheduled) HLO text. It builds on
``repro.launch.hlo_analysis`` — which reconstructs the call graph and
resolves canonical ``lax.scan`` trip counts — and ranks individual ops
by bytes x trips, per device.

Two entry points:

  * :func:`report` — structured rows (JSON-ready dicts), what the tests
    and artifact writers consume.
  * :func:`format_report` — the human-readable table the
    ``tools/top_collectives.py`` CLI prints.

Plus :func:`compiled_text` to get scheduled HLO from any jittable
function, and :func:`cost_summary` for XLA's own per-module
``cost_analysis`` numbers (the "measured bytes" side of the roofline
benchmarks — unlike this module's loop-aware totals, XLA counts a while
body once; both views are reported so the ratio itself is informative).
"""
from __future__ import annotations

from typing import Optional

from ..launch import hlo_analysis as ha


def compiled_text(fn, *args, static_argnums=(), donate_argnums=()) -> str:
    """Scheduled HLO text of ``fn`` compiled for ``args``."""
    import jax
    jitted = jax.jit(fn, static_argnums=static_argnums,
                     donate_argnums=donate_argnums)
    return jitted.lower(*args).compile().as_text()


def cost_summary(fn, *args, static_argnums=()) -> dict:
    """XLA's ``cost_analysis`` for ``fn(*args)``: flops + bytes accessed.

    Returns ``{"flops": float, "bytes_accessed": float}`` (zeros when the
    backend reports nothing). This is the *measured* side of the roofline
    artifacts: what the compiler itself accounts for the module, as
    opposed to the analytic model's hand-counted bytes.
    """
    import jax
    compiled = jax.jit(fn, static_argnums=static_argnums) \
        .lower(*args).compile()
    costs = compiled.cost_analysis()
    if isinstance(costs, (list, tuple)):
        costs = costs[0] if costs else {}
    costs = costs or {}
    return {"flops": float(costs.get("flops", 0.0)),
            "bytes_accessed": float(costs.get("bytes accessed", 0.0))}


def _call_multipliers(comps: dict) -> tuple[dict, set]:
    """Per-computation execution multipliers + the control-flow set.

    A computation reached through a ``while`` body runs ``trip_count``
    times per caller execution; multipliers are additive over call sites
    and multiplicative down the graph. ``control`` holds computations on
    the entry control path (whose top-level ops touch HBM, as opposed to
    fused subcomputations).
    """
    entry = next(c for c in comps.values() if c.is_entry)
    edges: dict[str, list] = {c: [] for c in comps}
    for comp in comps.values():
        for i in comp.instrs:
            if i.opcode == "while":
                bm = ha._BODY_RE.search(i.rest)
                cm = ha._COND_RE.search(i.rest)
                trips = ha._trip_count(comps[cm.group(1)]) if cm and \
                    cm.group(1) in comps else 1
                if bm and bm.group(1) in comps:
                    edges[comp.name].append((bm.group(1), trips, True))
                if cm and cm.group(1) in comps:
                    edges[comp.name].append((cm.group(1), trips, False))
            else:
                keeps = i.opcode in ("call", "conditional")
                for callee in ha._CALLS_RE.findall(i.rest):
                    if callee in comps:
                        edges[comp.name].append((callee, 1, keeps))

    order: list[str] = []
    seen: set = set()

    def topo(name: str) -> None:
        if name in seen:
            return
        seen.add(name)
        for callee, _, _ in edges[name]:
            topo(callee)
        order.append(name)

    topo(entry.name)
    mult: dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    control: set = {entry.name}
    for name in reversed(order):
        for callee, trips, keeps in edges[name]:
            mult[callee] += mult[name] * trips
            if name in control and keeps:
                control.add(callee)
    return mult, control


def report(hlo_text: str, top: Optional[int] = None) -> dict:
    """Trip-weighted per-op hotspot rows for a compiled module.

    Args:
      hlo_text: scheduled HLO (``compiled_text`` output).
      top: keep only the heaviest N rows per section (None = all).
    Returns:
      ``{"collectives": [...], "hbm_ops": [...], "totals": {...}}`` —
      rows sorted by descending weighted bytes; ``totals`` is
      ``hlo_analysis.analyze``'s module-wide summary (flops, loop-aware
      hbm_bytes, per-kind collective traffic).
    """
    comps = ha.parse_module(hlo_text)
    if not any(c.is_entry for c in comps.values()):
        return {"collectives": [], "hbm_ops": [],
                "totals": {"flops": 0.0, "hbm_bytes": 0.0,
                           "collectives": {}}}
    mult, control = _call_multipliers(comps)

    colls: list[dict] = []
    hbms: list[dict] = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        sym = comp.symbol_table()
        for i in comp.instrs:
            for k in ha.COLLECTIVE_OPS:
                if i.opcode in (k, k + "-start"):
                    w = 2 if k == "all-reduce" else 1
                    colls.append({
                        "bytes": m * w * ha.shape_bytes(i.result_type),
                        "mult": m, "kind": k,
                        "type": i.result_type[:70],
                    })
            if cname in control and i.opcode not in ha._SKIP_BYTES_OPS \
                    and i.opcode != "while" \
                    and not i.opcode.endswith("-done"):
                hbms.append({
                    "bytes": m * ha._instr_hbm_bytes(i, sym, comps),
                    "mult": m, "opcode": i.opcode,
                    "name": i.name[:40], "type": i.result_type[:60],
                })
    colls.sort(key=lambda r: r["bytes"], reverse=True)
    hbms.sort(key=lambda r: r["bytes"], reverse=True)
    if top is not None:
        colls, hbms = colls[:top], hbms[:top]
    return {"collectives": colls, "hbm_ops": hbms,
            "totals": ha.analyze(hlo_text)}


def top_collectives(fn, *args, top: int = 14, static_argnums=(),
                    donate_argnums=()) -> dict:
    """Compile ``fn(*args)`` and report its heaviest ops (see ``report``)."""
    return report(compiled_text(fn, *args, static_argnums=static_argnums,
                                donate_argnums=donate_argnums), top=top)


def format_report(rep: dict) -> str:
    """The classic two-table text rendering of a ``report`` result."""
    lines = ["== top collectives (bytes x trips) =="]
    for r in rep["collectives"]:
        lines.append(f"{r['bytes']/1e9:9.1f}GB m={r['mult']:7.0f} "
                     f"{r['kind']:18s} {r['type']}")
    lines.append("== top HBM ops ==")
    for r in rep["hbm_ops"]:
        lines.append(f"{r['bytes']/1e9:9.1f}GB m={r['mult']:7.0f} "
                     f"{r['opcode']:18s} {r['name']:40s} {r['type']}")
    return "\n".join(lines)

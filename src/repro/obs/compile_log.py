"""Compile/retrace accounting for every execution surface.

``kernels/ops.trace_counts`` proved the pattern: a host-side counter that
ticks when a dispatch function's Python body runs — i.e. at *trace* time,
never at execution time — measures exactly how many programs XLA was asked
to build. This module generalizes it into one process-wide log that any
surface can record into under a namespace:

  * ``kernels``   — one tick per Pallas tree-dispatch trace (``ops.py``);
  * ``simulator`` — one tick per ``trajectory`` trace (scan body build);
  * ``sweep``     — one tick per compiled partition program;
  * ``fed``       — one tick per event-runtime closure trace.

``namespace(name)`` returns the *live* counter dict for a namespace — the
same object the recorder updates — so legacy views (``ops.trace_counts``)
stay plain dicts. ``snapshot()`` flattens everything to ``"ns/key"`` for
artifacts, and ``track()`` captures the delta across a block:

    with compile_log.track() as log:
        sweep.run_sweep(grid, task, num_iters=300, base_cfg=base)
    assert log.counts.get("kernels/tree_hb_update", 0) == 1

which is how the regression tests pin "enabling metrics adds zero extra
compiles per sweep partition".
"""
from __future__ import annotations

import contextlib
import dataclasses

_namespaces: dict[str, dict[str, int]] = {}


def namespace(name: str) -> dict[str, int]:
    """The live counter dict for ``name`` (created on first use)."""
    return _namespaces.setdefault(name, {})


def record(ns: str, key: str, n: int = 1) -> None:
    """Tick ``ns/key`` by ``n`` (call from trace-time Python only)."""
    d = namespace(ns)
    d[key] = d.get(key, 0) + n


def snapshot() -> dict[str, int]:
    """All counters flattened to ``"ns/key"`` (a copy, artifact-ready)."""
    return {f"{ns}/{k}": v for ns, d in sorted(_namespaces.items())
            for k, v in sorted(d.items())}


def counts(ns: str) -> dict[str, int]:
    """A copy of one namespace's counters."""
    return dict(namespace(ns))


def reset(ns: str | None = None) -> None:
    """Zero one namespace (or every namespace) in place.

    Clearing in place keeps live views (``ops.trace_counts``) attached.
    """
    if ns is not None:
        namespace(ns).clear()
        return
    for d in _namespaces.values():
        d.clear()


@dataclasses.dataclass
class TrackedCounts:
    """The delta captured by :func:`track` (filled at block exit)."""

    counts: dict[str, int] = dataclasses.field(default_factory=dict)

    def total(self, ns: str | None = None) -> int:
        """Sum of all ticks, optionally restricted to one namespace."""
        return sum(v for k, v in self.counts.items()
                   if ns is None or k.startswith(ns + "/"))


@contextlib.contextmanager
def track():
    """Capture the counter *delta* across a block, without resetting.

    Yields a :class:`TrackedCounts` whose ``counts`` maps flattened
    ``"ns/key"`` names to how many ticks happened inside the block. Nested
    tracking works; concurrent recording from other threads is attributed
    to every open tracker (counters are process-global by design).
    """
    before = snapshot()
    out = TrackedCounts()
    try:
        yield out
    finally:
        after = snapshot()
        out.counts = {k: v - before.get(k, 0) for k, v in after.items()
                      if v != before.get(k, 0)}

"""Profiler hooks: named annotations + trace capture around hot paths.

Thin, dependency-free wrappers over ``jax.profiler`` so call sites never
touch it directly:

  * :func:`annotate` — a host-side ``TraceAnnotation`` context: the
    wrapped block shows up as a named span on the profiler timeline.
    Use it around *dispatch* (a composed step, a sweep partition launch);
    for *in-graph* attribution the dispatch layer already wraps every
    Pallas tree kernel in ``jax.named_scope`` (``kernels/<name>`` — see
    ``kernels/ops._dispatch``) and the composed step in
    ``chb_step[<backend>]``, which is HLO metadata only and therefore
    free and bit-exact.
  * :func:`trace` — capture a profiler trace directory for a block
    (viewable in TensorBoard / Perfetto). No-ops gracefully when the
    runtime lacks profiler support, so library code can call it
    unconditionally.
  * :func:`annotate_fn` — decorator form of :func:`annotate`.

None of these affect numerics: annotations are metadata, and trace
capture only observes.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Iterator, Optional

import jax

#: Re-export: the in-graph (HLO metadata) scope used by the dispatch layer.
named_scope = jax.named_scope


@contextlib.contextmanager
def annotate(name: str) -> Iterator[None]:
    """Named host-side span on the profiler timeline (no-op if absent)."""
    ann = getattr(jax.profiler, "TraceAnnotation", None)
    if ann is None:                       # pragma: no cover - old jax
        yield
        return
    with ann(name):
        yield


def annotate_fn(name: Optional[str] = None):
    """Decorator: run the function under :func:`annotate`."""
    def deco(fn):
        label = name or fn.__name__

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with annotate(label):
                return fn(*args, **kwargs)
        return wrapped
    return deco


@contextlib.contextmanager
def trace(log_dir: str, *, create_perfetto_link: bool = False,
          create_perfetto_trace: bool = False) -> Iterator[None]:
    """Capture a profiler trace for the block into ``log_dir``.

    Wraps ``jax.profiler.trace``; degrades to a no-op (still executing the
    block) if the runtime's profiler is unavailable, so benchmarks can
    offer ``--profile DIR`` without a hard dependency.
    """
    tracer = getattr(jax.profiler, "trace", None)
    if tracer is None:                    # pragma: no cover - old jax
        yield
        return
    try:
        ctx = tracer(log_dir, create_perfetto_link=create_perfetto_link,
                     create_perfetto_trace=create_perfetto_trace)
        ctx.__enter__()
    except Exception:                     # pragma: no cover - backend quirk
        # profiling must never take the run down with it
        yield
        return
    try:
        yield
    finally:
        ctx.__exit__(None, None, None)

"""JSONL run logging: one event per round / sweep point, host-side.

``RunLog`` is the sink half of ``repro.obs``: the in-graph ``MetricBag``
(see ``obs.metrics``) produces named scalar series on device, and the
RunLog writes them — together with the run's identity (the ``repro.opt``
registry spec, the backend, free-form tags) — as newline-delimited JSON,
one self-contained object per line. JSONL because runs append
incrementally (an event-driven ``repro.fed`` run logs as rounds complete,
not at exit) and because downstream tooling (``tools/bench_diff.py``,
pandas, ``jq``) can stream it without loading the whole file.

Event schema (documented in docs/observability.md, versioned by
``EVENT_SCHEMA_VERSION``):

    {"schema_version": 1, "event": "<kind>", "step": <int|null>,
     "run": "<name>", "backend": "<reference|pallas|null>",
     "spec": {...} | null, "metrics": {"<name>": <float>, ...}, ...tags}

``metrics`` values are plain floats (device scalars are pulled to host at
write time); ``spec`` is the full optimizer spec when the caller provides
one, so every line is reproducible in isolation.
"""
from __future__ import annotations

import json
import os
from typing import Any, IO, Optional

import numpy as np

#: Version of the per-line event schema (bump on breaking layout changes).
EVENT_SCHEMA_VERSION = 1


def _jsonable(v: Any) -> Any:
    """Pull device/numpy scalars and arrays to JSON-native values."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    arr = np.asarray(v)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


class RunLog:
    """Append-only JSONL writer for run events.

    Args:
      path: file to append to (created if missing), or ``None`` to write
        to an in-memory buffer (``.lines`` — useful for tests and for
        callers that embed the events in a larger artifact).
      run: run name stamped on every event.
      backend: execution backend stamped on every event ("reference" /
        "pallas" / None).
      spec: the run's ``repro.opt`` registry spec; stamped on every event
        unless the event carries its own (per-point sweeps).

    Usable as a context manager; ``close`` flushes and releases the file.
    """

    def __init__(self, path: Optional[str] = None, *, run: str = "run",
                 backend: Optional[str] = None,
                 spec: Optional[dict] = None):
        self.path = path
        self.run = run
        self.backend = backend
        self.spec = spec
        self.lines: list[str] = []
        self._fh: Optional[IO[str]] = None
        if path is not None:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(path, "a")

    # ------------------------------------------------------------- events
    def write(self, event: str, *, step: Optional[int] = None,
              metrics: Optional[dict] = None,
              spec: Optional[dict] = None, **tags: Any) -> dict:
        """Append one event line; returns the event dict that was written."""
        doc: dict[str, Any] = {
            "schema_version": EVENT_SCHEMA_VERSION,
            "event": event,
            "step": step,
            "run": self.run,
            "backend": self.backend,
            "spec": _jsonable(spec if spec is not None else self.spec),
            "metrics": {k: _jsonable(v)
                        for k, v in (metrics or {}).items()},
        }
        for k, v in tags.items():
            doc.setdefault(k, _jsonable(v))
        line = json.dumps(doc, sort_keys=True)
        self.lines.append(line)
        if self._fh is not None:
            self._fh.write(line + "\n")
            self._fh.flush()
        return doc

    def write_round(self, step: int, metrics: dict, **tags: Any) -> dict:
        """One optimization round's MetricBag (event kind ``"round"``)."""
        return self.write("round", step=step, metrics=metrics, **tags)

    def write_point(self, index: int, metrics: dict,
                    spec: Optional[dict] = None, **tags: Any) -> dict:
        """One sweep point's summary (event kind ``"point"``)."""
        return self.write("point", step=index, metrics=metrics, spec=spec,
                          **tags)

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "RunLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str) -> list[dict]:
    """Load every event of a JSONL run log (skipping blank lines)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out

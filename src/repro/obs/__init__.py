"""repro.obs — in-graph telemetry + host-side observability sinks.

Two halves, one package:

  * **In-graph** (``obs.metrics``): the ``MetricBag`` — a flat pytree of
    named scalar observables that rides alongside the optimizer state
    through every execution surface (``simulator.trajectory``'s scan, the
    sweep engine's ``lax.map`` partitions, the ``repro.fed`` event loop)
    without perturbing it: metrics-on runs are bit-identical to
    metrics-off runs, and collection is opt-in per run.
  * **Host-side sinks**: ``obs.runlog`` (JSONL event writer),
    ``obs.compile_log`` (process-wide trace/retrace counters, the
    generalization of ``kernels/ops.trace_counts``), ``obs.profile``
    (profiler annotations + trace capture), ``obs.bench``
    (schema-versioned BENCH_*.json artifacts), and ``obs.hlo_report``
    (trip-count-weighted collective/HBM hotspot reports from compiled
    HLO).

See docs/observability.md for the contracts.
"""
from . import bench, compile_log, metrics, profile, runlog
from .compile_log import TrackedCounts
from .metrics import MetricBag, metric_names, stage_metrics, step_metrics, \
    summarize
from .profile import annotate, annotate_fn, named_scope, trace
from .runlog import EVENT_SCHEMA_VERSION, RunLog, read_jsonl


def __getattr__(name: str):
    # hlo_report pulls in repro.launch's HLO parser; keep it lazy so the
    # kernels -> obs import (compile_log) stays featherweight and acyclic
    if name == "hlo_report":
        import importlib
        return importlib.import_module(".hlo_report", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "bench", "compile_log", "hlo_report", "metrics", "profile", "runlog",
    "TrackedCounts", "MetricBag", "metric_names", "stage_metrics",
    "step_metrics", "summarize", "annotate", "annotate_fn", "named_scope",
    "trace", "RunLog", "read_jsonl", "EVENT_SCHEMA_VERSION",
]

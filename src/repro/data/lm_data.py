"""Synthetic-but-learnable token pipeline for LLM-scale CHB training.

Sequences are drawn from a fixed random first-order Markov chain over the
vocabulary (deterministic given seed), so cross-entropy has real structure
to learn: loss should fall from ~ln(V_branch) toward the chain's entropy.
The iterator shards batches worker-first for the scan strategy or flat for
the pod strategy, and can place them on a mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class MarkovLM:
    vocab_size: int
    branch: int = 16          # out-degree per state -> entropy ~ ln(branch)
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.next_tokens = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, self.branch),
            dtype=np.int32)

    def sample(self, rng: np.random.Generator, batch: int,
               seq_len: int) -> np.ndarray:
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=batch)
        choices = rng.integers(0, self.branch, size=(batch, seq_len))
        for t in range(seq_len):
            toks[:, t + 1] = self.next_tokens[toks[:, t], choices[:, t]]
        return toks

    def entropy_floor(self) -> float:
        return float(np.log(self.branch))


def batch_iterator(cfg, *, global_batch: int, seq_len: int,
                   num_workers: Optional[int] = None, seed: int = 1,
                   heterogeneous: bool = False,
                   mesh=None, batch_sharding=None) -> Iterator[dict]:
    """Yields {"tokens", "labels"(, "enc_embeddings")} batches.

    num_workers given -> worker-chunked layout (M, B/M, L) (scan strategy);
    otherwise flat (B, L) (pod strategy / plain training).
    heterogeneous -> each worker samples its OWN Markov chain with a
    different branching factor (non-IID federated data; worker 0 has the
    lowest-entropy source). Requires num_workers.
    """
    if heterogeneous:
        assert num_workers, "heterogeneous data needs worker chunking"
        lms = [MarkovLM(cfg.vocab_size, branch=2 ** (1 + i % 5),
                        seed=seed + 100 + i) for i in range(num_workers)]
    else:
        lm = MarkovLM(cfg.vocab_size, seed=seed)
    rng = np.random.default_rng(seed + 1)
    enc_rng = np.random.default_rng(seed + 2)
    while True:
        if heterogeneous:
            m = num_workers
            per = global_batch // m
            raw = np.stack([lms[i].sample(rng, per, seq_len)
                            for i in range(m)])        # (M, per, L+1)
            tokens, labels = raw[..., :-1], raw[..., 1:]
        else:
            raw = lm.sample(rng, global_batch, seq_len)
            tokens, labels = raw[:, :-1], raw[:, 1:]
            if num_workers:
                m = num_workers
                tokens = tokens.reshape(m, global_batch // m, seq_len)
                labels = labels.reshape(m, global_batch // m, seq_len)
        batch = {"tokens": jnp.asarray(tokens),
                 "labels": jnp.asarray(labels)}
        if cfg.frontend:
            shape = tokens.shape[:-1] + (cfg.num_frontend_tokens,
                                         cfg.d_frontend)
            batch["enc_embeddings"] = jnp.asarray(
                0.3 * enc_rng.standard_normal(shape), cfg.jnp_dtype)
        if mesh is not None and batch_sharding is not None:
            batch = jax.tree_util.tree_map(
                lambda x, s=batch_sharding: jax.device_put(x, s), batch)
        yield batch

"""Vectorized task builders that scale to 10^5–10^6 clients.

``paper_tasks.make_linear_regression`` builds its workers in a Python loop
(an eigendecomposition-backed rescale per worker) — faithful to the
paper's 9-worker figures, quadratic-cost hopeless at six figures. The
builders here construct the whole population with single vectorized numpy
draws, keeping memory linear in M:

  * :func:`make_edge_quadratics` — per-client scaled quadratics
    ``f_m = 0.5 * a_m * ||theta - c_m||^2``: O(M*d) memory, a closed-form
    optimum, and tunable gradient heterogeneity. The 10^6-client scaling
    ladder in ``benchmarks/fed_mesh.py`` runs on this.
  * :func:`make_edge_linreg` — per-client least squares with shared
    feature statistics: O(M*n*d) memory, the realistic mid-scale (10^5)
    workload.

Both return plain ``core.simulator.FedTask`` bundles, so they run on every
execution surface; the mesh runtime additionally requires M divisible by
the shard count (``launch.sharding.client_shard_sizes``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.simulator import FedTask


def make_edge_quadratics(m: int, d: int = 16, seed: int = 0,
                         hetero: float = 3.0) -> FedTask:
    """f_m(theta) = 0.5 * a_m * ||theta - c_m||^2 for M clients.

    Args:
      m: client count (any size; memory is ``(m, d)`` + ``(m,)``).
      d: parameter dimension.
      seed: numpy seed for centers and curvatures.
      hetero: curvature spread — ``a_m`` is log-uniform over
        ``[1, hetero]``, so clients disagree on scale (the censor has
        something to censor); ``hetero=1`` makes all clients identical.
    Returns:
      A ``FedTask``; the global optimum is the a-weighted mean of the
      centers, so ``f*`` is cheap to evaluate in closed form.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(m, d)).astype(np.float64)
    curv = np.exp(rng.uniform(0.0, np.log(max(hetero, 1.0)), size=(m,)))

    def loss_fn(theta, data):
        a, c = data
        r = theta - c
        return 0.5 * a * jnp.sum(r * r)

    def grad_fn(theta, data):
        a, c = data
        return a * (theta - c)

    return FedTask(init_params=jnp.zeros((d,)),
                   grad_fn=grad_fn, loss_fn=loss_fn,
                   worker_data=(jnp.asarray(curv), jnp.asarray(centers)),
                   name=f"edge_quadratics_m{m}")


def edge_quadratics_fstar(task: FedTask) -> float:
    """Closed-form optimum of :func:`make_edge_quadratics`.

    ``f(theta) = 0.5 * sum_m a_m ||theta - c_m||^2`` is minimized at the
    a-weighted center mean; plugging it back gives f*.
    """
    a, c = (np.asarray(x) for x in task.worker_data)
    theta_star = (a[:, None] * c).sum(axis=0) / a.sum()
    r = theta_star[None, :] - c
    return float(0.5 * (a * np.square(r).sum(axis=1)).sum())


def make_edge_linreg(m: int, n_per: int = 2, d: int = 16,
                     seed: int = 0, label_noise: float = 0.1) -> FedTask:
    """Vectorized per-client least squares: f_m = 0.5||X_m theta - y_m||^2.

    One ``(m, n_per, d)`` normal draw and one shared ground-truth theta
    with per-client label noise — no per-worker Python loop, no per-worker
    eigendecompositions. Feature scale is normalized by ``sqrt(d)`` so the
    global smoothness constant grows ~linearly in ``m * n_per`` (pick the
    step size as ``1 / (m * n_per)`` to stay stable at any M).
    """
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, n_per, d)).astype(np.float64) / np.sqrt(d)
    theta_true = rng.normal(size=(d,))
    y = x @ theta_true + label_noise * rng.normal(size=(m, n_per))

    def loss_fn(theta, data):
        xm, ym = data
        r = xm @ theta - ym
        return 0.5 * jnp.sum(r * r)

    def grad_fn(theta, data):
        xm, ym = data
        return xm.T @ (xm @ theta - ym)

    return FedTask(init_params=jnp.zeros((d,)),
                   grad_fn=grad_fn, loss_fn=loss_fn,
                   worker_data=(jnp.asarray(x), jnp.asarray(y)),
                   name=f"edge_linreg_m{m}")

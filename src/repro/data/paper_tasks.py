"""The paper's experiment tasks: linear / logistic / lasso regression and a
1-hidden-layer neural network, distributed over M workers.

Dataset notes (offline container): the real datasets used in the paper
(ijcnn1, MNIST, Housing, Body fat, Abalone, Ionosphere, Adult, Derm) are not
downloadable here, so each benchmark uses a synthetic stand-in with matched
(n_samples, n_features, n_workers) and controlled smoothness constants. The
paper's *relative* claims (communication ratios, iteration parity with HB)
are what we validate; see DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.simulator import FedTask


# ---------------------------------------------------------------- helpers
def _split_workers(x: np.ndarray, y: np.ndarray, m: int):
    n = (x.shape[0] // m) * m
    xs = x[:n].reshape(m, n // m, x.shape[1])
    ys = y[:n].reshape(m, n // m)
    return xs, ys


def _rescale_to_smoothness(x: np.ndarray, target_hess_lmax: float) -> np.ndarray:
    """Scale X so that lambda_max(X^T X) == target_hess_lmax."""
    lmax = float(np.linalg.eigvalsh(x.T @ x)[-1])
    return x * np.sqrt(target_hess_lmax / lmax)


def _features(rng, n: int, d: int, condition: float) -> np.ndarray:
    """Gaussian features with a geometric per-column scale.

    condition > 1 makes the Hessian ill-conditioned (kappa ~ condition^2),
    which matches the iteration counts of the paper's real datasets
    (hundreds to thousands) — the regime where censoring actually fires.
    Well-conditioned random Gaussians converge in ~20 iterations and no
    algorithm ever censors (see EXPERIMENTS.md §Repro notes).
    """
    x = rng.standard_normal((n, d))
    if condition > 1.0:
        scale = condition ** (-np.arange(d) / max(d - 1, 1))
        x = x * scale[None, :]
    return x


@dataclasses.dataclass
class TaskBundle:
    task: FedTask
    L: float                 # global smoothness constant of f = sum_m f_m
    L_m: np.ndarray          # (M,) per-worker smoothness constants
    alpha_paper: float       # the step size the paper uses for this setup


# ------------------------------------------------------- linear regression
def make_linear_regression(m: int = 9, n_per: int = 50, d: int = 50,
                           worker_L: Sequence[float] | None = None,
                           seed: int = 0,
                           condition: float = 1.0) -> TaskBundle:
    """f_m(theta) = 0.5 ||X_m theta - y_m||^2.

    Default worker smoothness follows the paper's Fig. 1/2 setting
    L_m = (1.3^(m-1))^2, m = 1..9.
    """
    rng = np.random.default_rng(seed)
    if worker_L is None:
        worker_L = [(1.3 ** i) ** 2 for i in range(m)]
    xs, ys = [], []
    for i in range(m):
        y = rng.choice([-1.0, 1.0], size=n_per)
        x = _features(rng, n_per, d, condition)
        x = _rescale_to_smoothness(x, worker_L[i])
        xs.append(x)
        ys.append(y)
    X = np.stack(xs)    # (M, n, d)
    Y = np.stack(ys)    # (M, n)
    H = sum(x.T @ x for x in xs)
    L = float(np.linalg.eigvalsh(H)[-1])

    def loss_fn(theta, data):
        x, y = data
        r = x @ theta - y
        return 0.5 * jnp.sum(r * r)

    def grad_fn(theta, data):
        x, y = data
        return x.T @ (x @ theta - y)

    task = FedTask(init_params=jnp.zeros((d,)),
                   grad_fn=grad_fn, loss_fn=loss_fn,
                   worker_data=(jnp.asarray(X), jnp.asarray(Y)),
                   name="linear_regression")
    return TaskBundle(task=task, L=L, L_m=np.asarray(worker_L),
                      alpha_paper=1.0 / L)


# ----------------------------------------------------- logistic regression
def make_logistic_regression(m: int = 9, n_per: int = 50, d: int = 50,
                             worker_L: Sequence[float] | None = None,
                             reg: float = 0.001, seed: int = 1,
                             condition: float = 25.0) -> TaskBundle:
    """f_m = sum_n log(1+exp(-y x.theta)) + (reg/(2M))||theta||^2.

    Default: the paper's Fig. 3 setting with common L_1=..=L_9=4.
    Worker smoothness of the logistic term is lmax(X^T X)/4.
    """
    rng = np.random.default_rng(seed)
    if worker_L is None:
        worker_L = [4.0] * m
    xs, ys = [], []
    for i in range(m):
        y = rng.choice([-1.0, 1.0], size=n_per)
        x = _features(rng, n_per, d, condition)
        # logistic Hessian bound: X^T X / 4 (+ reg/M); rescale the data term
        x = _rescale_to_smoothness(x, 4.0 * (worker_L[i] - reg / m))
        xs.append(x)
        ys.append(y)
    X, Y = np.stack(xs), np.stack(ys)
    H = sum(x.T @ x for x in xs) / 4.0
    L = float(np.linalg.eigvalsh(H)[-1]) + reg

    def loss_fn(theta, data):
        x, y = data
        z = -y * (x @ theta)
        return jnp.sum(jnp.logaddexp(0.0, z)) + \
            reg / (2.0 * m) * jnp.sum(theta * theta)

    grad_fn = jax.grad(loss_fn)
    task = FedTask(init_params=jnp.zeros((d,)),
                   grad_fn=grad_fn, loss_fn=loss_fn,
                   worker_data=(jnp.asarray(X), jnp.asarray(Y)),
                   name="logistic_regression")
    return TaskBundle(task=task, L=L, L_m=np.asarray(worker_L),
                      alpha_paper=1.0 / L)


# ----------------------------------------------------------- lasso (subgrad)
def make_lasso(m: int = 9, n_per: int = 50, d: int = 50,
               reg: float = 0.5, seed: int = 2,
               worker_L: Sequence[float] | None = None,
               condition: float = 6.0) -> TaskBundle:
    """f_m = 0.5||X_m theta - y||^2 + (reg/M)||theta||_1, subgradient used."""
    rng = np.random.default_rng(seed)
    if worker_L is None:
        worker_L = [(1.2 ** i) ** 2 for i in range(m)]
    xs, ys = [], []
    for i in range(m):
        y = rng.choice([-1.0, 1.0], size=n_per)
        x = _rescale_to_smoothness(_features(rng, n_per, d, condition),
                                   worker_L[i])
        xs.append(x)
        ys.append(y)
    X, Y = np.stack(xs), np.stack(ys)
    H = sum(x.T @ x for x in xs)
    L = float(np.linalg.eigvalsh(H)[-1])

    def loss_fn(theta, data):
        x, y = data
        r = x @ theta - y
        return 0.5 * jnp.sum(r * r) + reg / m * jnp.sum(jnp.abs(theta))

    def grad_fn(theta, data):  # subgradient
        x, y = data
        return x.T @ (x @ theta - y) + reg / m * jnp.sign(theta)

    task = FedTask(init_params=jnp.zeros((d,)),
                   grad_fn=grad_fn, loss_fn=loss_fn,
                   worker_data=(jnp.asarray(X), jnp.asarray(Y)),
                   name="lasso")
    return TaskBundle(task=task, L=L, L_m=np.asarray(worker_L),
                      alpha_paper=1.0 / L)


# ------------------------------------------------- 1-hidden-layer NN (paper)
def make_neural_network(m: int = 9, n_per: int = 200, d: int = 22,
                        hidden: int = 30, reg: float | None = None,
                        seed: int = 3) -> TaskBundle:
    """The paper's nonconvex task: one hidden layer, 30 nodes, sigmoid.

    Binary labels; sigmoid output with squared loss + L2 regularization.
    Progress metric is ||grad_k||^2 (StepInfo.agg_grad_sqnorm).
    """
    rng = np.random.default_rng(seed)
    n_total = m * n_per
    if reg is None:
        reg = 1.0 / n_total
    w_true = rng.standard_normal((d,))
    X = rng.standard_normal((n_total, d))
    Y = (np.tanh(X @ w_true) + 0.1 * rng.standard_normal(n_total) > 0)
    Y = Y.astype(np.float64)
    Xs, Ys = _split_workers(X, Y, m)

    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    params = {
        "w1": jax.random.normal(k1, (d, hidden)) * (1.0 / np.sqrt(d)),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, 1)) * (1.0 / np.sqrt(hidden)),
        "b2": jnp.zeros((1,)),
    }

    def loss_fn(p, data):
        x, y = data
        h = jax.nn.sigmoid(x @ p["w1"] + p["b1"])
        out = jax.nn.sigmoid(h @ p["w2"] + p["b2"])[:, 0]
        l2 = sum(jnp.sum(v * v) for v in jax.tree_util.tree_leaves(p))
        return jnp.sum((out - y) ** 2) + reg / (2.0 * m) * l2

    grad_fn = jax.grad(loss_fn)
    task = FedTask(init_params=params, grad_fn=grad_fn, loss_fn=loss_fn,
                   worker_data=(jnp.asarray(Xs), jnp.asarray(Ys)),
                   name="neural_network")
    # nonconvex: no meaningful global L; report a proxy via data scale
    return TaskBundle(task=task, L=float("nan"),
                      L_m=np.full((m,), np.nan), alpha_paper=0.02)


# ------------------------------------------- dataset-shaped synthetic stand-ins
STAND_INS = {
    # name: (n_samples, n_features, paper_workers)
    "ijcnn1": (49990, 22, 9),
    "mnist": (60000, 196, 9),     # 196 = 14x14 downsample scale; keeps eigh cheap
    "housing": (506, 13, 3),
    "bodyfat": (252, 14, 3),
    "abalone": (4177, 8, 3),
    "ionosphere": (351, 33, 3),
    "adult": (1605, 14, 3),
    "derm": (366, 34, 3),
}


def make_standin(name: str, kind: str, seed: int = 7, **kw) -> TaskBundle:
    """Synthetic stand-in with a real dataset's (n, d, M) signature."""
    n, d, m = STAND_INS[name]
    n_per = n // m
    mk = {"linear": make_linear_regression,
          "logistic": make_logistic_regression,
          "lasso": make_lasso,
          "nn": make_neural_network}[kind]
    if kind == "nn":
        return mk(m=m, n_per=min(n_per, 400), d=d, seed=seed, **kw)
    # ill-conditioning matched to the paper's iteration counts (real tabular
    # data): linear ~2e2 iters, logistic ~5e3 iters; worker smoothness spread
    # like the paper's evenly-split real datasets
    condition = {"linear": 8.0, "logistic": 30.0, "lasso": 8.0}[kind]
    bundle = mk(m=m, n_per=min(n_per, 800), d=d, seed=seed,
                condition=condition,
                worker_L=[4.0 * (1.25 ** i) for i in range(m)],
                **kw)
    return dataclasses.replace(bundle, task=bundle.task._replace(
        name=f"{name}_{kind}"))

from . import edge_tasks, lm_data, paper_tasks

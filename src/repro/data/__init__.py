from . import lm_data, paper_tasks

"""repro: Censored Heavy Ball (CHB) federated training framework in JAX."""
__version__ = "1.0.0"

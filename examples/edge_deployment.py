"""Edge-deployment walkthrough: CHB on battery-driven wireless clients.

The paper's premise (Sec. I) is wireless, battery-driven workers — this
example builds exactly that deployment around the CHB core and reads out
the costs the uplink *count* only hints at: joules and seconds.

  PYTHONPATH=src python examples/edge_deployment.py

Steps:
  1. a 9-client population where two clients are 12x slower (stragglers)
     and every client is only 80% likely to answer a dispatch,
  2. a 1 Mbps uplink that drops 15% of packets,
  3. a radio/compute energy model,
  4. an 8-of-9 quorum so one straggler never stalls a round,
then compares CHB against plain heavy ball on the paper's linear-regression
task.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro import fed
from repro import opt
from repro.core import simulator
from repro.data import paper_tasks


def main():
    m = 9
    bundle = paper_tasks.make_linear_regression()   # paper Fig. 2 setting
    fstar = float(simulator.estimate_fstar(bundle.task, bundle.alpha_paper))

    # 1. who computes: heterogeneous, intermittently available clients
    population = fed.straggler_population(
        m, compute_mean_s=1.0, straggler_frac=0.22, straggler_slowdown=12.0,
        jitter="exp", availability="bernoulli", avail_p=0.8, seed=0)

    # 2. over what air: 1 Mbps uplink, 15% packet loss
    channel = fed.ChannelConfig.lossy(0.15, uplink_rate_bps=1e6)

    # 3. at what cost: ~5 uJ/byte radio, 2 W while computing
    energy = fed.EnergyModel(uplink_j_per_byte=5e-6, uplink_j_per_tx=1e-3)

    # 4. server policy: advance on 8 of 9 reports, fold stragglers stale
    edge = fed.EdgeConfig(population=population, channel=channel,
                          energy=energy, quorum=8.0 / 9.0, seed=0)

    print(f"{m} clients, 2 stragglers (12x), 80% availability, "
          f"1 Mbps uplink @ 15% loss, quorum 8/9")
    print(f"target: f - f* < 1e-6 (f* = {fstar:.4f})\n")
    print(f"{'algo':5s} {'rounds':>7s} {'uplinks':>8s} {'dropped':>8s} "
          f"{'stale':>6s} {'energy J':>9s} {'wall s':>8s}")
    for algo in ("chb", "hb"):
        cfg = opt.make(algo, bundle.alpha_paper, m)
        hist = fed.run_edge(cfg, bundle.task, edge, num_rounds=400)
        met = fed.edge_metrics_to_accuracy(hist, fstar, 1e-6)
        d = hist.stats.as_dict()
        print(f"{algo:5s} {met['rounds']:7d} {met['uplinks']:8d} "
              f"{d['dropped']:8d} {d['stale_folds']:6d} "
              f"{met['energy_j']:9.2f} {met['wall_clock_s']:8.2f}")

    print("\nCHB self-censoring saves radio bytes/uplinks at HB's "
          "convergence speed; dropped and stale uplinks are folded with "
          "the same eq. (5) bank semantics the paper proves convergent.")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~124M-parameter decoder LM with CHB for a few
hundred steps on a synthetic Markov-chain corpus, comparing uplink traffic
against classical HB at matched iteration count.

  PYTHONPATH=src python examples/train_llm_chb.py --steps 300
  PYTHONPATH=src python examples/train_llm_chb.py --steps 30 --smoke
"""
import argparse

from repro.configs import get
from repro.train.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model (CI-speed)")
    ap.add_argument("--eps1-scale", type=float, default=4.0,
                    help="censoring threshold scale; stochastic minibatch "
                         "gradients need a larger eps1 than the paper's "
                         "full-batch 0.1 (see EXPERIMENTS.md)")
    ap.add_argument("--quantize", default=None, choices=["int8"])
    args = ap.parse_args()

    cfg = get("chb-paper-lm-124m")
    if args.smoke:
        cfg = cfg.reduced()
    results = {}
    for algo in ("chb", "hb"):
        tc = TrainConfig(algorithm=algo, num_workers=4, alpha=0.05,
                         beta=0.4, eps1_scale=args.eps1_scale,
                         quantize=args.quantize if algo == "chb" else None,
                         global_batch=16 if args.smoke else 32,
                         seq_len=128 if args.smoke else 256,
                         steps=args.steps, log_every=max(args.steps // 10, 1))
        print(f"\n=== {algo.upper()} ===")
        params, state, hist = train(cfg, tc)
        results[algo] = (hist[-1], int(state.comm.total_uplinks),
                         float(state.comm.uplink_bytes))
    print("\n=== summary ===")
    for algo, (last, comms, byts) in results.items():
        print(f"{algo:4s} final_loss={last['loss']:.4f} uplinks={comms} "
              f"uplink_GB={byts/1e9:.2f}")
    saved = 1 - results["chb"][1] / max(results["hb"][1], 1)
    print(f"CHB censored {saved*100:.1f}% of uplinks at matched steps.")


if __name__ == "__main__":
    main()

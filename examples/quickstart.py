"""Quickstart: CHB vs HB/GD/LAG on a 9-worker linear-regression problem.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro import opt
from repro.core import simulator
from repro.data import paper_tasks


def main():
    bundle = paper_tasks.make_linear_regression()  # paper Fig. 2 setting
    fstar = simulator.estimate_fstar(bundle.task, bundle.alpha_paper)
    print(f"9 workers, L={bundle.L:.1f}, alpha=1/L, f*={float(fstar):.4f}\n")
    print(f"{'algo':6s} {'comms@1e-7':>12s} {'iters@1e-7':>12s}")
    for name in ("chb", "hb", "lag", "gd"):
        cfg = opt.make(name, bundle.alpha_paper, 9)
        hist = simulator.run(cfg, bundle.task, 3000)
        c = simulator.comms_to_accuracy(hist, fstar, 1e-7)
        k = simulator.iterations_to_accuracy(hist, fstar, 1e-7)
        print(f"{name:6s} {c:12d} {k:12d}")
    print("\nCHB: heavy-ball convergence speed at a fraction of the uplinks.")


if __name__ == "__main__":
    main()

"""Reproduce the paper's four learning tasks (Sec. IV) in one script:
linear / logistic / lasso regression + the 1-hidden-layer neural network.

  PYTHONPATH=src python examples/federated_paper_experiments.py
"""
import jax

jax.config.update("jax_enable_x64", True)


from repro import opt
from repro.core import simulator
from repro.data import paper_tasks


def run_task(name, bundle, iters, tol, alpha=None):
    alpha = alpha or bundle.alpha_paper
    print(f"\n--- {name} (alpha={alpha:.3e}) ---")
    fstar = simulator.estimate_fstar(bundle.task, alpha) if tol else 0.0
    for algo in ("chb", "hb", "lag", "gd"):
        cfg = opt.make(algo, alpha, bundle.L_m.shape[0])
        hist = simulator.run(cfg, bundle.task, iters)
        if tol:
            c = simulator.comms_to_accuracy(hist, fstar, tol)
            k = simulator.iterations_to_accuracy(hist, fstar, tol)
            print(f"{algo:4s} comms={c:6d} iters={k:6d}")
        else:
            print(f"{algo:4s} comms={int(hist.comm_cum[-1]):6d} "
                  f"||grad||^2={float(hist.agg_grad_sqnorm[-1]):.3e}")


def main():
    run_task("linear regression", paper_tasks.make_linear_regression(),
             3000, 1e-7)
    run_task("logistic regression", paper_tasks.make_logistic_regression(),
             4000, 1e-5)
    run_task("lasso (subgradient)", paper_tasks.make_lasso(), 3000, 1e-5)
    run_task("neural network (500 fixed iters)",
             paper_tasks.make_neural_network(), 500, None, alpha=0.02)


if __name__ == "__main__":
    main()

"""Non-IID federated LLM training — the paper's Fig. 1 at LLM scale.

Each of M=4 workers holds a DIFFERENT Markov-chain corpus (branching factor
2,4,8,16: worker 0 has the lowest-entropy, smoothest objective — the LLM
analogue of a small smoothness constant L_m). CHB should censor the
low-entropy workers more, reproducing the paper's per-worker ordering in a
stochastic, non-convex, non-IID setting.

  PYTHONPATH=src python examples/heterogeneous_federated_llm.py --steps 80
"""
import argparse

import jax
import numpy as np

from repro.configs import get
from repro.core import distributed
from repro.core.chb import FedOptConfig
from repro.data import lm_data
from repro.models import model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--eps1-scale", type=float, default=2.0)
    args = ap.parse_args()

    cfg = get("chb-paper-lm-124m").reduced()
    m, gb, sl, alpha = 4, 16, 128, 0.05
    fcfg = FedOptConfig(alpha=alpha, beta=0.4,
                        eps1=args.eps1_scale / (alpha ** 2 * m ** 2),
                        num_workers=m)

    def loss_fn(p, b):
        return model.train_loss(p, cfg, b, remat="none")[0]

    params = model.init_params(jax.random.PRNGKey(0), cfg)
    state = distributed.init_scan_state(fcfg, params)
    step = jax.jit(distributed.make_scan_step(fcfg, loss_fn),
                   donate_argnums=(0, 1))
    data = lm_data.batch_iterator(cfg, global_batch=gb, seq_len=sl,
                                  num_workers=m, heterogeneous=True)
    for s in range(args.steps):
        params, state, metr = step(params, state, next(data))
        if s % 10 == 0:
            print(f"step {s:4d} loss={float(metr['loss']):.4f} "
                  f"tx={float(metr['transmitted']):.0f}/{m}")
    counts = np.asarray(state.comm.uplink_count)
    print("\nper-worker uplinks (branch 2,4,8,16 = rising entropy):", counts)
    print("entropy floors:", [round(np.log(2 ** (1 + i)), 2)
                              for i in range(m)])
    if counts[0] < counts[-1]:
        print("=> lowest-entropy worker censored most — the paper's Fig.-1 "
              "ordering reproduces in the non-IID LLM regime.")
    else:
        print("=> ordering did NOT reproduce: minibatch-noise magnitudes "
              "are nearly worker-independent, so the global eq.-(8) test "
              "flips all workers together (EXPERIMENTS.md P4e).")


if __name__ == "__main__":
    main()

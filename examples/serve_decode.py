"""Batched serving example: prefill + decode with KV caches on the reduced
qwen3-4b config (runs on CPU).

  PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys

if __name__ == "__main__":
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-4b",
         "--reduced", "--batch", "4", "--prompt-len", "64", "--gen", "16"]))

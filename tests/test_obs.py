"""repro.obs: in-graph telemetry + host-side sinks.

Pins the observability contracts:

  * **bit-exactness**: metrics-on runs are bit-identical to metrics-off
    runs on both backends — same golden hex fingerprints as
    tests/test_backend.py;
  * **zero extra compiles**: collecting metrics through ``run_sweep``
    neither changes the partition keys nor adds compiled programs or
    kernel retraces (pinned via ``obs.compile_log``);
  * **exact byte accounting**: the split-int32 ``CommStats`` counters —
    and the MetricBag entries derived from them — stay exact past
    float32's 2^24 integer limit;
  * the JSONL ``RunLog`` event schema, the ``obs.bench`` artifact schema
    (+ CLI validator + ``tools/bench_diff.py``), the stage ``metrics``
    hooks, the fed runtime's staleness histogram, and the
    ``obs.hlo_report`` trip-count-weighted analysis.
"""
import json
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import fed, obs, opt, sweep
from repro.core import simulator
from repro.core.accounting import MIB, CommStats
from repro.data import paper_tasks
from repro.kernels import ops as kernel_ops
from repro.obs import bench, compile_log

M = 5
ITERS = 60

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# same setting + golden as tests/test_backend.py: chb, f32, 60 iters
GOLDEN_CHB_F32 = ("0x1.107a260000000p+6", "0x1.0024fc0000000p+12",
                  262, 262, "0x1.dc40000000000p-42",
                  "0x1.a94328858133cp+1")


@pytest.fixture(scope="module")
def linreg():
    return paper_tasks.make_linear_regression(m=M, n_per=30, d=20, seed=0)


def _as_f32(task):
    cast = lambda t: jax.tree_util.tree_map(  # noqa: E731
        lambda x: x.astype(jnp.float32)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, t)
    return task._replace(init_params=cast(task.init_params),
                         worker_data=cast(task.worker_data))


@pytest.fixture(scope="module")
def task32(linreg):
    return _as_f32(linreg.task)


def _fingerprint(h):
    obj = np.asarray(h.objective)
    fsq = float(sum(np.sum(np.square(np.asarray(x, np.float64)))
                    for x in jax.tree_util.tree_leaves(h.final_params)))
    return (float(obj[-1]).hex(), float(obj.sum()).hex(),
            int(np.asarray(h.comm_cum)[-1]),
            int(np.asarray(h.mask).sum()),
            float(np.asarray(h.agg_grad_sqnorm)[-1]).hex(), fsq.hex())


# ===================================================== bit-exactness anchor
@pytest.mark.parametrize("backend", opt.BACKENDS)
def test_metrics_on_matches_golden_fingerprint(linreg, task32, backend):
    """Metrics ride alongside the state: the golden hex trajectory is
    unchanged with collection on, on both backends."""
    o = opt.make("chb", linreg.alpha_paper, M, backend=backend)
    h = simulator.run(o, task32, ITERS, collect_metrics=True)
    assert _fingerprint(h) == GOLDEN_CHB_F32
    # and the bag itself came back as stacked (K,) series
    assert h.metrics and all(np.asarray(v).shape == (ITERS,)
                             for v in h.metrics.values())


def test_metrics_off_by_default(linreg, task32):
    h = simulator.run(opt.make("chb", linreg.alpha_paper, M), task32, 10)
    assert h.metrics == ()


def test_metrics_bit_identity_all_fields(linreg):
    """Every History field (params and bank included) is bit-identical
    between metrics-on and metrics-off f64 runs."""
    o = opt.make("chb", linreg.alpha_paper, M, quantize="int8")
    h0 = simulator.run(o, linreg.task, ITERS)
    h1 = simulator.run(o, linreg.task, ITERS, collect_metrics=True)
    for f in ("objective", "mask", "comm_cum", "agg_grad_sqnorm"):
        np.testing.assert_array_equal(np.asarray(getattr(h0, f)),
                                      np.asarray(getattr(h1, f)), err_msg=f)
    for a, b in zip(jax.tree_util.tree_leaves(h0.final_params),
                    jax.tree_util.tree_leaves(h1.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(h0.final_state.ghat),
                    jax.tree_util.tree_leaves(h1.final_state.ghat)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# =============================================== MetricBag content + hooks
def test_base_bag_contents(linreg, task32):
    o = opt.make("chb", linreg.alpha_paper, M)
    h = simulator.run(o, task32, ITERS, collect_metrics=True)
    bag = h.metrics
    # censor rate is 1 - mean(mask) per round
    np.testing.assert_allclose(
        np.asarray(bag["censor_rate"]),
        1.0 - np.asarray(h.mask).mean(axis=1), atol=1e-6)
    # cumulative uplink count matches the comm trajectory
    np.testing.assert_array_equal(
        np.asarray(bag["comm/uplink_total"]).astype(np.int64),
        np.asarray(h.comm_cum))
    # final-round bag bytes == the exact split counters
    assert float(np.asarray(bag["comm/uplink_bytes"])[-1]) == float(
        h.final_state.comm.uplink_bytes_exact())
    # eq-8 censor reports its (traced) threshold by registry kind
    assert "censor/eq8/eps1" in bag
    assert "server/hb/alpha" in bag and "server/hb/beta" in bag


def test_stage_hooks_namespaced_by_kind(linreg, task32):
    # int8 transport adds the EF-residual norm under transport/int8/
    o = opt.make("chb", linreg.alpha_paper, M, quantize="int8")
    h = simulator.run(o, task32, 30, collect_metrics=True)
    assert "transport/int8/ef_residual_sqnorm" in h.metrics
    # stochastic censor reports its decaying threshold
    o2 = opt.make("csgd", linreg.alpha_paper, M, tau0=5.0)
    h2 = simulator.run(o2, task32, 30, collect_metrics=True)
    tau = np.asarray(h2.metrics["censor/stochastic/tau"])
    assert tau.shape == (30,) and tau[0] > tau[-1] > 0
    # adaptive censor reports its EMA state
    o3 = opt.ComposedOptimizer(
        censor=opt.AdaptiveCensor(adaptive=1.0),
        transport=opt.DenseTransport(),
        server=opt.HeavyBall(linreg.alpha_paper, 0.4), num_workers=M)
    h3 = simulator.run(o3, task32, 30, collect_metrics=True)
    assert "censor/adaptive/ema_mean" in h3.metrics


def test_metric_names_without_running(linreg, task32):
    o = opt.make("chb", linreg.alpha_paper, M)
    names = obs.metric_names(o, task32.init_params)
    assert "censor_rate" in names and "censor/eq8/eps1" in names
    # eval_shape must not have compiled or executed anything kernel-side
    h = simulator.run(o, task32, 5, collect_metrics=True)
    assert names == tuple(sorted(h.metrics))


def test_summarize_reducers():
    series = {"a": np.arange(5.0), "b": np.ones(5)}
    assert obs.summarize(series) == {"a": 4.0, "b": 1.0}
    assert obs.summarize(series, reducer=np.mean)["a"] == 2.0


# ============================================ exact byte accounting > 2^24
def test_commstats_exact_past_2pow24():
    """The split-int32 counters register every byte far past float32's
    2^24 integer limit, and the MetricBag view agrees exactly (f64)."""
    stats = CommStats.init(4)
    payload = 3 * MIB + 17          # odd size: exercises the carry
    mask = jnp.ones((4,), jnp.float32)
    update = jax.jit(lambda s: s.update(mask, payload))
    rounds = 2000                   # 4 workers * 2000 * ~3MiB ≈ 25 GiB
    for _ in range(rounds):
        stats = update(stats)
    exact = stats.uplink_bytes_exact()
    assert exact == 4 * rounds * payload
    assert exact > (1 << 24)        # past the f32 integer floor
    assert 0 <= int(stats.uplink_rem) < MIB
    # a single f32 accumulator would have lost the +17 increments
    f32_acc = np.float32(0)
    for _ in range(rounds):
        f32_acc = np.float32(f32_acc + np.float32(4 * payload))
    assert int(f32_acc) != exact
    # the metrics() view (f64 under x64) reproduces the exact count
    assert float(stats.metrics()["comm/uplink_bytes"]) == float(exact)


def test_commstats_metrics_keys():
    stats = CommStats.init(3)
    bag = stats.metrics()
    assert set(bag) == {"comm/uplink_total", "comm/uplink_bytes",
                        "comm/downlink_count", "comm/iterations"}


# =============================== sweep round-trip: no retraces, same keys
def test_sweep_metrics_roundtrip_zero_extra_compiles(linreg, task32):
    """collect_metrics must not change partition keys, add compiled
    programs, or retrace any kernel dispatch."""
    grid = sweep.ConfigGrid(
        alpha=[0.5 * linreg.alpha_paper, linreg.alpha_paper],
        beta=[0.0, 0.4], eps1=[0.5, 2.0])
    base = opt.make("chb", linreg.alpha_paper, M, backend="pallas")

    with compile_log.track() as off:
        res0 = sweep.run_sweep(grid, task32, num_iters=40, base_cfg=base)
    with compile_log.track() as on:
        res1 = sweep.run_sweep(grid, task32, num_iters=40, base_cfg=base,
                               collect_metrics=True)
    # identical partitioning and identical compile/trace activity
    assert res1.num_programs == res0.num_programs == 1
    assert on.counts == off.counts
    assert on.counts.get("sweep/partition") == 1
    assert on.counts.get("kernels/tree_delta_sqnorms") == 1
    # trajectories bit-identical, metrics only on the collecting run
    for i in range(len(res0)):
        np.testing.assert_array_equal(res0.history(i).objective,
                                      res1.history(i).objective)
        assert res0.metrics(i) == {}
        bag = res1.metrics(i)
        assert np.asarray(bag["censor_rate"]).shape == (40,)
        # the traced hyperparameters round-trip through the bag
        assert float(np.asarray(bag["censor/eq8/eps1"])[-1]) == \
            pytest.approx(res1.points[i].eps1)
    # summary rows are JSON-ready floats
    summary = res1.metrics_summary()
    assert len(summary) == len(res1)
    json.dumps(summary)
    # and to_json embeds them only when collected
    assert "metrics" in json.loads(res1.to_json(include_trajectories=False))
    assert "metrics" not in json.loads(
        res0.to_json(include_trajectories=False))


# ====================================================== compile_log itself
def test_compile_log_namespaces_and_track():
    compile_log.reset("t-ns")
    ns = compile_log.namespace("t-ns")
    compile_log.record("t-ns", "x")
    compile_log.record("t-ns", "x")
    assert ns == {"x": 2}               # live dict view
    with compile_log.track() as tc:
        compile_log.record("t-ns", "y")
    assert tc.counts == {"t-ns/y": 1}   # delta only
    assert tc.total("t-ns") == 1
    assert compile_log.snapshot()["t-ns/x"] == 2
    compile_log.reset("t-ns")
    assert ns == {}


def test_kernel_trace_counts_is_compile_log_view():
    kernel_ops.reset_trace_counts()
    assert kernel_ops.trace_counts == {}
    assert kernel_ops.trace_counts is compile_log.namespace("kernels")


# ================================================================= RunLog
def test_runlog_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    spec = {"algo": "chb"}
    with obs.RunLog(path, run="t", backend="reference", spec=spec) as log:
        log.write_round(0, {"censor_rate": jnp.float32(0.25)})
        log.write_point(3, {"final_err": 1e-6}, spec={"algo": "gd"},
                        note="tagged")
    events = obs.read_jsonl(path)
    assert [e["event"] for e in events] == ["round", "point"]
    for e in events:
        assert e["schema_version"] == obs.EVENT_SCHEMA_VERSION
        assert e["run"] == "t" and e["backend"] == "reference"
    assert events[0]["metrics"]["censor_rate"] == pytest.approx(0.25)
    assert events[0]["spec"] == spec          # default spec stamped
    assert events[1]["spec"] == {"algo": "gd"}  # per-event override
    assert events[1]["note"] == "tagged"
    # appending reopens cleanly
    with obs.RunLog(path, run="t2") as log:
        log.write("done")
    assert len(obs.read_jsonl(path)) == 3


def test_runlog_in_memory():
    log = obs.RunLog(run="mem")
    log.write_round(0, {"x": np.float64(1.5)})
    assert json.loads(log.lines[0])["metrics"]["x"] == 1.5


# ========================================================== fed runtime
def test_fed_metrics_and_staleness(linreg):
    edge = fed.sync_config(M, seed=0)
    o = opt.make("chb", linreg.alpha_paper, M)
    log = obs.RunLog(run="edge", backend="reference")
    h0 = fed.run_edge(o, linreg.task, edge, 25)
    h1 = fed.run_edge(o, linreg.task, edge, 25, collect_metrics=True,
                      runlog=log)
    assert h0.metrics == ()
    # metrics are observation only: trajectories unchanged
    np.testing.assert_array_equal(h0.objective, h1.objective)
    np.testing.assert_array_equal(h0.mask, h1.mask)
    bag = h1.metrics
    assert np.asarray(bag["censor_rate"]).shape == (25,)
    # sync anchor: nothing is ever late or dropped
    assert np.asarray(bag["staleness/h1"]).sum() == 0
    assert np.asarray(bag["staleness/h4p"]).sum() == 0
    assert np.asarray(bag["drops"]).sum() == 0
    # every fold this round arrived fresh
    np.testing.assert_array_equal(np.asarray(bag["staleness/h0"]),
                                  np.asarray(h1.mask).sum(axis=1))
    np.testing.assert_array_equal(np.asarray(bag["comm/uplink_total"]),
                                  np.asarray(h1.comm_cum).astype(np.float64))
    # one JSONL round event per server round
    assert len(log.lines) == 25
    ev = json.loads(log.lines[0])
    assert ev["event"] == "round" and ev["cohort_size"] == M
    # the fed closures trace a bounded number of times (client_eval sees
    # two ssq signatures: the round-0 literal and the traced update), and
    # the count must NOT grow with the number of rounds
    with compile_log.track() as t5:
        fed.run_edge(o, linreg.task, edge, 5)
    with compile_log.track() as t12:
        fed.run_edge(o, linreg.task, edge, 12)
    assert t5.counts == t12.counts
    assert t5.counts.get("fed/server_update") == 1
    assert t5.counts.get("fed/client_eval", 0) <= 2


def test_fed_staleness_buckets_with_stragglers(linreg):
    """A straggler cohort with partial quorum produces late folds that
    land in the >=1-round staleness buckets."""
    edge = fed.EdgeConfig(
        population=fed.straggler_population(
            M, compute_mean_s=1.0, straggler_frac=0.4,
            straggler_slowdown=25.0, jitter="exp", seed=3),
        channel=fed.ChannelConfig(uplink_rate_bps=1e6),
        quorum=3.0 / 5.0, seed=3)
    o = opt.make("hb", linreg.alpha_paper * 0.5, M)
    h = fed.run_edge(o, linreg.task, edge, 40, collect_metrics=True)
    late = (np.asarray(h.metrics["staleness/h1"]).sum()
            + np.asarray(h.metrics["staleness/h2_3"]).sum()
            + np.asarray(h.metrics["staleness/h4p"]).sum())
    assert late > 0
    assert late == int(h.stats.stale_count.sum())
    # every folded delta landed in exactly one bucket
    assert np.asarray(h.metrics["staleness/h0"]).sum() + late == \
        np.asarray(h.mask).sum()


# ======================================================== bench artifacts
def _tiny_artifact(name="t", us=10.0, mbytes=100.0, traces=None):
    return bench.make_artifact(name, {
        "k": {"row": f"k,{us:.1f},d=1", "seconds": 0.1,
              "backend": ["reference", "pallas"],
              "specs": {"reference": {"algo": "chb"}},
              "measured_bytes": {"reference": mbytes},
              "analytic_bytes": {"reference": 90.0},
              "measured": {"pallas": {"kernel_traces": traces or
                                      {"tree_hb_update": 1}}}}},
        registry=["chb"])


def test_bench_artifact_schema_roundtrip(tmp_path):
    doc = _tiny_artifact()
    assert doc["schema_version"] == bench.SCHEMA_VERSION
    assert doc["kind"] == bench.KIND
    assert set(doc["env"]) == {"jax_version", "backend", "x64"}
    p = str(tmp_path / "BENCH_t.json")
    bench.write_artifact(doc, p)
    assert bench.load_artifact(p) == doc


@pytest.mark.parametrize("mutate,msg", [
    (lambda d: d.pop("schema_version"), "schema_version"),
    (lambda d: d.update(kind="other"), "kind"),
    (lambda d: d.update(env=None), "env"),
    (lambda d: d["benchmarks"]["k"].pop("row"), "row"),
    (lambda d: d["benchmarks"]["k"].update(specs=3), "specs"),
    (lambda d: d["benchmarks"]["k"].update(measured_bytes=[1]),
     "measured_bytes"),
])
def test_bench_validation_catches(mutate, msg):
    doc = _tiny_artifact()
    mutate(doc)
    errs = bench.validate_artifact(doc)
    assert errs and any(msg in e for e in errs), errs


def test_bench_validate_cli(tmp_path):
    good = str(tmp_path / "good.json")
    bench.write_artifact(_tiny_artifact(), good)
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump({"schema_version": 1}, f)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    ok = subprocess.run(
        [sys.executable, "-m", "repro.obs.bench", "--validate", good],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    fail = subprocess.run(
        [sys.executable, "-m", "repro.obs.bench", "--validate", bad],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert fail.returncode == 1
    assert "kind" in fail.stdout


def test_bench_diff_cli(tmp_path):
    old = str(tmp_path / "old.json")
    new_ok = str(tmp_path / "new_ok.json")
    new_bad = str(tmp_path / "new_bad.json")
    bench.write_artifact(_tiny_artifact(us=10.0), old)
    bench.write_artifact(_tiny_artifact(us=11.0), new_ok)
    bench.write_artifact(
        _tiny_artifact(us=50.0, mbytes=500.0,
                       traces={"tree_hb_update": 4}), new_bad)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    script = os.path.join(REPO, "tools", "bench_diff.py")
    ok = subprocess.run([sys.executable, script, old, new_ok],
                        capture_output=True, text=True, env=env, cwd=REPO)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "no regressions" in ok.stdout
    bad = subprocess.run([sys.executable, script, old, new_bad],
                         capture_output=True, text=True, env=env, cwd=REPO)
    assert bad.returncode == 1
    assert "us_per_call" in bad.stdout
    assert "measured_bytes" in bad.stdout
    assert "retrace" in bad.stdout


def test_checked_in_artifacts_validate():
    """The committed BENCH_*.json files at the repo root stay schema-valid."""
    import glob
    paths = glob.glob(os.path.join(REPO, "BENCH_*.json"))
    assert paths, "no BENCH_*.json artifacts checked in at the repo root"
    for p in paths:
        doc = bench.load_artifact(p)       # raises on violation
        assert doc["benchmarks"], p


# ========================================================== profiler hooks
def test_annotate_and_named_scope_run(linreg, task32):
    with obs.annotate("test/span"):
        x = jnp.ones(3) + 1
    assert float(x.sum()) == 6.0

    @obs.annotate_fn()
    def f(v):
        return v * 2
    assert float(f(jnp.float32(2.0))) == 4.0
    # named_scope shows up in the composed step's HLO metadata
    o = opt.make("chb", linreg.alpha_paper, M)
    state = o.init(task32.init_params)
    grads = jax.vmap(task32.grad_fn, in_axes=(None, 0))(
        task32.init_params, task32.worker_data)
    hlo = jax.jit(lambda s, p, g: o.step(s, p, g)).lower(
        state, task32.init_params, grads).compile().as_text()
    assert "chb_step[reference]" in hlo


def test_profiler_trace_capture(tmp_path):
    with obs.trace(str(tmp_path / "prof")):
        jnp.arange(8).sum().block_until_ready()
    # capture must not have failed the block; directory may or may not
    # contain events depending on backend support


# ============================================================= hlo_report
def test_hlo_report_scan_trip_counts(task32, linreg):
    """The report weights scan-body ops by trip count; XLA's own
    cost_analysis is also exposed for the measured-bytes artifacts."""
    from repro.obs import hlo_report
    o = opt.make("chb", linreg.alpha_paper, M)
    fn = lambda p: simulator.trajectory(  # noqa: E731
        o, task32._replace(init_params=p), 50).objective
    text = hlo_report.compiled_text(fn, task32.init_params)
    rep = hlo_report.report(text, top=5)
    assert len(rep["hbm_ops"]) == 5
    # something in the module runs 50x (the scan body's ops)
    assert max(r["mult"] for r in rep["hbm_ops"]) >= 50
    assert rep["totals"]["hbm_bytes"] > 0
    out = hlo_report.format_report(rep)
    assert "top HBM ops" in out
    cost = hlo_report.cost_summary(fn, task32.init_params)
    assert cost["bytes_accessed"] > 0

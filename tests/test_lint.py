"""repro.lint: fixture firing/silence, suppressions, CLI, repo self-check.

The self-check tests at the bottom are the tier-1 enforcement point: they
lint the real repo and fail on any unsuppressed finding, so re-introducing
a fixed bug class (re-baked hparams, mask-multiply selects, float byte
counters, ...) fails the suite even before CI's dedicated lint job runs.
"""
import json
import os

import pytest

from repro import lint
from repro.lint import cli, markers

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIX = os.path.join(REPO, "tests", "lint_fixtures")


def lint_fixture(name, **kw):
    return lint.run_paths([os.path.join(FIX, name)], root=REPO, **kw)


# ------------------------------------------------- rule fixtures
# (rule, bad fixture, expected finding count, good fixture)
RULE_FIXTURES = [
    ("baked-traced-hparam", "bad_hparam.py", 2, "good_hparam.py"),
    ("mask-multiply-select", "bad_mask.py", 2, "good_mask.py"),
    ("float-byte-counter", "bad_counter.py", 3, "good_counter.py"),
    ("vmap-in-draw-exact", "bad_draw_exact.py", 2, "good_draw_exact.py"),
    ("interpret-not-routed", "bad_interpret.py", 2, "good_interpret.py"),
    ("unseeded-randomness", "bad_random.py", 4, "good_random.py"),
]


@pytest.mark.parametrize("rule,bad,count,_good",
                         RULE_FIXTURES, ids=[r[0] for r in RULE_FIXTURES])
def test_rule_fires_on_bad_fixture(rule, bad, count, _good):
    findings = [f for f in lint_fixture(bad) if f.rule == rule]
    assert len(findings) == count, [f.render() for f in findings]
    assert not any(f.suppressed for f in findings)


@pytest.mark.parametrize("rule,_bad,_count,good",
                         RULE_FIXTURES, ids=[r[0] for r in RULE_FIXTURES])
def test_rule_silent_on_good_fixture(rule, _bad, _count, good):
    findings = lint_fixture(good)
    assert findings == [], [f.render() for f in findings]


def test_parse_error_is_a_finding():
    findings = lint_fixture("bad_syntax.py")
    assert [f.rule for f in findings] == ["parse-error"]


@pytest.mark.parametrize("tree,expect_phantom",
                         [("registry_project_bad", True),
                          ("registry_project_good", False)])
def test_registry_kind_unpinned_project_rule(tree, expect_phantom):
    root = os.path.join(FIX, tree)
    findings = [f for f in lint.run_paths([root], root=root)
                if f.rule == "registry-kind-unpinned"]
    if expect_phantom:
        assert len(findings) == 1
        assert "'phantom'" in findings[0].message
        assert "transport_conformance" in findings[0].message \
            or "test_backend" in findings[0].message
    else:
        assert findings == []


def test_registry_rule_silent_outside_repo_layout(tmp_path):
    (tmp_path / "pyproject.toml").write_text("# marker\n")
    mod = tmp_path / "mod.py"
    mod.write_text("X = 1\n")
    assert lint.run_paths([str(mod)], root=str(tmp_path)) == []


# ------------------------------------------------- suppressions
def test_suppression_with_reason_is_honored():
    findings = lint_fixture("suppressed_ok.py")
    assert len(findings) == 2
    assert all(f.suppressed for f in findings), \
        [f.render() for f in findings]
    by_rule = {f.rule: f for f in findings}
    assert "trailing-comment" in by_rule["mask-multiply-select"].reason
    # the standalone suppression's wrapped reason is joined across lines
    assert "covering the next code line" in \
        by_rule["unseeded-randomness"].reason


def test_reasonless_suppression_is_an_error():
    findings = lint_fixture("suppressed_noreason.py")
    rules = sorted(f.rule for f in findings)
    assert rules == ["mask-multiply-select", "suppression-missing-reason"]
    # the reasonless comment does NOT suppress the underlying finding
    assert not any(f.suppressed for f in findings)


def test_unknown_rule_suppression_is_an_error():
    findings = lint_fixture("suppressed_unknown.py")
    assert [f.rule for f in findings] == ["suppression-unknown-rule"]
    assert "no-such-rule" in findings[0].message


def test_filewide_suppression_covers_whole_file():
    findings = lint_fixture("suppressed_filewide.py")
    assert len(findings) == 2
    assert all(f.suppressed and f.rule == "mask-multiply-select"
               for f in findings)


# ------------------------------------------------- registry / selection
def test_rule_names_cover_the_catalog():
    names = set(lint.rule_names())
    for rule, *_ in RULE_FIXTURES:
        assert rule in names
    assert {"registry-kind-unpinned", "parse-error",
            "suppression-missing-reason",
            "suppression-unknown-rule"} <= names
    docs = lint.rule_docs()
    assert set(docs) == names
    assert all(docs[n] for n in names)


def test_select_and_ignore_filter_rules():
    only = lint_fixture("bad_random.py", select="unseeded-randomness")
    assert {f.rule for f in only} == {"unseeded-randomness"}
    none = lint_fixture("bad_random.py", ignore="unseeded-randomness")
    assert none == []


def test_unknown_rule_selection_lists_valid_names():
    with pytest.raises(ValueError) as ei:
        lint_fixture("bad_random.py", select="bogus-rule")
    assert "unseeded-randomness" in str(ei.value)


# ------------------------------------------------- marker decorator
def test_draw_exact_marker_is_inert_metadata():
    @markers.draw_exact
    def fn(x):
        return x + 1

    assert fn(2) == 3
    assert getattr(fn, "__draw_exact__") is True


def test_repo_hot_paths_carry_the_marker():
    from repro.fed.runner import run_edge
    from repro.opt.transport import LowRankTransport
    from repro.sweep.engine import _run_group
    assert _run_group.__draw_exact__ and run_edge.__draw_exact__
    assert LowRankTransport.encode.__draw_exact__


# ------------------------------------------------- CLI + artifact
def test_cli_no_paths_is_usage_error(capsys):
    assert cli.main([]) == 2
    assert "no paths" in capsys.readouterr().err


def test_cli_list_rules(capsys):
    assert cli.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule, *_ in RULE_FIXTURES:
        assert rule in out


def test_cli_exit_codes(capsys):
    assert cli.main([os.path.join(FIX, "bad_mask.py")]) == 1
    assert cli.main([os.path.join(FIX, "good_mask.py")]) == 0
    assert cli.main(["--select", "nope", os.path.join(FIX, "good_mask.py")]
                    ) == 2
    capsys.readouterr()


def test_cli_json_artifact_schema(capsys):
    rc = cli.main(["--json", os.path.join(FIX, "bad_mask.py")])
    assert rc == 1
    data = json.loads(capsys.readouterr().out)
    assert data["schema"] == lint.SCHEMA
    assert data["counts"]["findings"] == 2
    assert data["counts"]["by_rule"] == {"mask-multiply-select": 2}
    assert all(f["rule"] == "mask-multiply-select"
               for f in data["findings"])


def test_artifact_round_trip(tmp_path, capsys):
    out = tmp_path / "findings.json"
    cli.main(["--json-file", str(out), os.path.join(FIX, "suppressed_ok.py"),
              os.path.join(FIX, "bad_random.py")])
    capsys.readouterr()
    data = lint.load_artifact(str(out))
    assert data["counts"]["findings"] == 4          # bad_random
    assert data["counts"]["suppressed"] == 2        # suppressed_ok
    assert all(f["reason"] for f in data["suppressed"])


def test_load_artifact_rejects_wrong_schema(tmp_path):
    p = tmp_path / "bogus.json"
    p.write_text(json.dumps({"schema": "something-else/v9"}))
    with pytest.raises(ValueError, match="something-else"):
        lint.load_artifact(str(p))


def _write_artifact(tmp_path, name, fixtures):
    out = tmp_path / name
    rc = cli.main(["--json-file", str(out)]
                  + [os.path.join(FIX, f) for f in fixtures])
    return str(out), rc


def test_lint_diff_gates_on_introduced_findings(tmp_path, capsys):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "lint_diff", os.path.join(REPO, "tools", "lint_diff.py"))
    lint_diff = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(lint_diff)

    old, _ = _write_artifact(tmp_path, "old.json", ["bad_mask.py"])
    new, _ = _write_artifact(tmp_path, "new.json",
                             ["bad_mask.py", "bad_random.py"])
    capsys.readouterr()

    # same findings -> clean; superset -> exit 1 naming the new ones
    assert lint_diff.main([old, old]) == 0
    assert "no findings introduced" in capsys.readouterr().out
    assert lint_diff.main([old, new]) == 1
    out = capsys.readouterr().out
    assert "INTRODUCED" in out and "unseeded-randomness" in out
    # shrinking back is clean and reports the resolutions
    assert lint_diff.main([new, old]) == 0
    assert "resolved" in capsys.readouterr().out


def test_lint_diff_reports_new_suppressions(tmp_path, capsys):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "lint_diff", os.path.join(REPO, "tools", "lint_diff.py"))
    lint_diff = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(lint_diff)

    # suppressed_ok's mask finding shares its message with an *active*
    # finding when the suppression is absent; simulate by diffing the
    # suppressed run against an artifact where it was active
    old, _ = _write_artifact(tmp_path, "old.json", ["suppressed_ok.py"])
    data = json.load(open(old))
    data["findings"] = data.pop("suppressed")
    data["suppressed"] = []
    forged = tmp_path / "forged_old.json"
    forged.write_text(json.dumps(data))
    new, _ = _write_artifact(tmp_path, "new.json", ["suppressed_ok.py"])
    capsys.readouterr()

    assert lint_diff.main([str(forged), new]) == 0
    out = capsys.readouterr().out
    assert "suppressed" in out and "reason:" in out


# ------------------------------------------------- repo self-check (tier 1)
def _repo_findings():
    paths = [os.path.join(REPO, d)
             for d in ("src", "benchmarks", "tests", "tools", "examples")]
    return lint.run_paths(paths, root=REPO)


def test_repo_is_lint_clean():
    """The enforcement point: any unsuppressed finding in the real tree
    fails tier 1. Reverting a lint-driven fix (e.g. flash_attention's
    interpret routing) re-fires it here."""
    findings = _repo_findings()
    active = [f for f in findings if not f.suppressed]
    assert active == [], "\n".join(f.render() for f in active)


def test_every_repo_suppression_carries_a_reason():
    for f in _repo_findings():
        if f.suppressed:
            assert f.reason and f.reason.strip(), f.render()


def test_rebaked_hparam_would_fail_the_selfcheck(tmp_path):
    """Acceptance regression: reintroducing the PR 4 bake (partial over a
    real kernel entry point) must produce an unsuppressed finding under the
    repo root, i.e. the self-check would catch the revert."""
    bad = tmp_path / "regressed_dispatch.py"
    bad.write_text(
        "import functools\n"
        "from repro.kernels import hb_update\n"
        "step = functools.partial(hb_update, alpha=0.1, beta=0.9)\n")
    findings = lint.run_paths([str(bad)], root=REPO)
    assert any(f.rule == "baked-traced-hparam" and not f.suppressed
               for f in findings)


def test_reverted_where_select_would_fail_the_selfcheck(tmp_path):
    """Acceptance regression: reverting a jnp.where select to the
    mask-multiply form fires mask-multiply-select."""
    bad = tmp_path / "regressed_select.py"
    bad.write_text("def pack(keep, pending):\n"
                   "    return keep * pending\n")
    findings = lint.run_paths([str(bad)], root=REPO)
    assert any(f.rule == "mask-multiply-select" and not f.suppressed
               for f in findings)

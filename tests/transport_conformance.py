"""Transport conformance suite: every registered transport, one contract.

Each transport kind in ``opt.TRANSPORT_KINDS`` must pass every test here
— adding a new stage to the registry automatically enrolls it (the
parametrization reads the registry at collection time), so the
backend × surface × spec matrix can't silently grow an uncovered cell.

The contract, per transport:

  * reference ↔ pallas **bit-identity** at f32 and f64 on the golden
    linreg task and on a pytree (NN) task with matrix leaves;
  * the row entry points (``prepare_row``/``encode_row``/
    ``feedback_row``, what ``repro.fed`` drives per client) agree with
    the matching worker slice of the batched step;
  * error-feedback residuals telescope: ``payload + new_err == pending``
    after a transmit — *bitwise* for ``exact_residual`` transports
    (dense/int8/top-k: each residual entry is an exact float subtraction
    by a Sterbenz-style argument, or exactly ``pending``/0), to
    tolerance for low-rank (its reconstruction is an arbitrary float);
  * ``payload_bytes`` is a static Python int and the split-int32
    ``CommStats`` counters accumulate it exactly past 2^24 bytes (where
    a single f32 cell would silently saturate);
  * specs round-trip through JSON with hyperparameters intact;
  * metrics collection is read-only (bit-identical trajectories on/off);
  * a quantize axis over the kind sweeps as ONE compiled program per
    static partition, and a task-scaled transport instance on the
    ``base_cfg`` survives the sweep (the engine must not clobber it with
    kind defaults).

Plus kernel-level pins (top-k select/pack + EF, low-rank EF residual):
pallas bit-identical to the ``ref.py`` oracle at f32/f64 — including
negative-zero handling — and the row entry draw-exact vs the M=1 batched
slice.

This module must NOT force ``jax_enable_x64``: CI runs it with
``JAX_ENABLE_X64`` 0 and 1, and the f64 tests skip at runtime when x64
is off. (Under the full tier-1 suite other modules enable x64 first.)
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import opt, sweep
from repro.core import simulator
from repro.core.accounting import CommStats
from repro.core.util import tree_worker_slice
from repro.data import paper_tasks
from repro.kernels import (censor, fused_step, hb_update, lowrank_ef,
                           quantize_ef, ref, topk_pack)

M = 5
ITERS = 40

# conformance-scale hyperparameters: small enough that compression is
# actually lossy on the d=20 golden task (k >= d would be a dense no-op)
CONFORMANCE_KW = {"topk": {"k": 8}, "lowrank": {"rank": 2}}
KINDS = sorted(opt.TRANSPORT_KINDS)


def make_transport(kind):
    return opt.make_transport(kind, **CONFORMANCE_KW.get(kind, {}))


def x64_enabled() -> bool:
    return bool(jax.config.read("jax_enable_x64"))


def require_x64():
    if not x64_enabled():
        pytest.skip("f64 leg needs JAX_ENABLE_X64=1")


@pytest.fixture(scope="module")
def linreg():
    return paper_tasks.make_linear_regression(m=M, n_per=30, d=20, seed=0)


def _cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def _as_f32(task):
    return task._replace(init_params=_cast_tree(task.init_params,
                                                jnp.float32),
                         worker_data=_cast_tree(task.worker_data,
                                                jnp.float32))


@pytest.fixture(scope="module")
def task32(linreg):
    return _as_f32(linreg.task)


def _assert_histories_equal(h1, h2):
    for f in ("objective", "mask", "comm_cum", "agg_grad_sqnorm"):
        np.testing.assert_array_equal(np.asarray(getattr(h1, f)),
                                      np.asarray(getattr(h2, f)), err_msg=f)
    for a, b in zip(jax.tree_util.tree_leaves(h1.final_params),
                    jax.tree_util.tree_leaves(h2.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _chb(alpha, kind, backend="reference"):
    return opt.make("chb", alpha, M, transport=make_transport(kind),
                    backend=backend)


# ------------------------------------------------------------ registry
def test_registry_has_at_least_four_transports():
    assert len(opt.transport_names()) >= 4
    assert {"dense", "int8", "topk", "lowrank"} <= set(opt.transport_names())


def test_unknown_transport_kind_raises():
    with pytest.raises(ValueError, match="unknown quantize mode"):
        opt.make_transport("int4")
    with pytest.raises(ValueError, match="unknown quantize mode"):
        sweep.ConfigGrid(alpha=[0.1], quantize=["int4"])


# --------------------------------------------------- backend bit-identity
@pytest.mark.parametrize("kind", KINDS)
def test_backend_bitwise_f32(linreg, task32, kind):
    _assert_histories_equal(
        simulator.run(_chb(linreg.alpha_paper, kind), task32, ITERS),
        simulator.run(_chb(linreg.alpha_paper, kind, "pallas"), task32,
                      ITERS))


@pytest.mark.parametrize("kind", KINDS)
def test_backend_bitwise_f64(linreg, kind):
    require_x64()
    _assert_histories_equal(
        simulator.run(_chb(linreg.alpha_paper, kind), linreg.task, ITERS),
        simulator.run(_chb(linreg.alpha_paper, kind, "pallas"), linreg.task,
                      ITERS))


@pytest.mark.parametrize("kind", KINDS)
def test_pytree_task_bitwise(kind):
    """Matrix leaves (the NN task) exercise the low-rank factor path and
    per-leaf top-k selection; both backends must still agree bitwise."""
    bn = paper_tasks.make_neural_network(m=4, n_per=40, d=8, hidden=6)
    t32 = _as_f32(bn.task)
    t = make_transport(kind)
    o_ref = opt.make("chb", 0.02, 4, transport=t)
    o_pal = opt.make("chb", 0.02, 4, transport=t, backend="pallas")
    _assert_histories_equal(simulator.run(o_ref, t32, 25),
                            simulator.run(o_pal, t32, 25))


# ------------------------------------------------------ row vs batched
def _rand_tree(key, m=None):
    """A two-leaf params pytree (matrix + vector); stacked when m given."""
    k1, k2 = jax.random.split(key)
    lead = () if m is None else (m,)
    return {"w": jax.random.normal(k1, lead + (6, 16), jnp.float32),
            "b": jax.random.normal(k2, lead + (16,), jnp.float32)}


@pytest.mark.parametrize("kind", KINDS)
def test_row_matches_batched_worker_slice(kind):
    """encode_row/feedback_row == the matching worker slice of the batched
    encode/feedback, for transmitted workers (the fed runtime only applies
    feedback on delivered uploads)."""
    t = make_transport(kind)
    params = _rand_tree(jax.random.PRNGKey(0))
    delta = _rand_tree(jax.random.PRNGKey(1), m=M)
    err = t.init(params, M)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0], jnp.float32)
    pending = t.prepare(delta, err)
    payload, aux = t.encode(pending, err)
    new_err = t.feedback(mask, pending, payload, aux, err)
    for i in range(M):
        err_row = tree_worker_slice(err, i) if t.stateful else ()
        d_row = tree_worker_slice(delta, i)
        p_row = t.prepare_row(d_row, err_row)
        for a, b in zip(jax.tree_util.tree_leaves(p_row),
                        jax.tree_util.tree_leaves(
                            tree_worker_slice(pending, i))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        q_row, aux_row = t.encode_row(p_row, err_row)
        for a, b in zip(jax.tree_util.tree_leaves(q_row),
                        jax.tree_util.tree_leaves(
                            tree_worker_slice(payload, i))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if not t.stateful or not mask[i]:
            continue
        ne_row = t.feedback_row(p_row, q_row, aux_row, err_row)
        for a, b in zip(jax.tree_util.tree_leaves(ne_row),
                        jax.tree_util.tree_leaves(
                            tree_worker_slice(new_err, i))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------ EF residual telescoping
@pytest.mark.parametrize("kind", KINDS)
def test_ef_residual_telescopes(kind):
    """Chained steps: after every transmit, ``payload + new_err`` equals
    the pending delta — exactly for ``exact_residual`` transports, to
    tolerance for low-rank — and censored workers carry their residual
    forward unchanged. Nothing is ever lost, only deferred."""
    t = make_transport(kind)
    params = _rand_tree(jax.random.PRNGKey(2))
    delta = _rand_tree(jax.random.PRNGKey(3), m=M)
    err = t.init(params, M)
    masks = [jnp.asarray(v, jnp.float32) for v in
             ([1, 1, 1, 1, 1], [1, 0, 1, 0, 1], [0, 0, 0, 0, 0],
              [1, 1, 0, 1, 1])]
    for mask in masks:
        pending = t.prepare(delta, err)
        payload, aux = t.encode(pending, err)
        new_err = t.feedback(mask, pending, payload, aux, err)
        if t.stateful:
            bank_old = t.ef_bank(err)
            bank_new = t.ef_bank(new_err)
            mk = np.asarray(mask)
            for p, q, e0, e1 in zip(
                    jax.tree_util.tree_leaves(pending),
                    jax.tree_util.tree_leaves(payload),
                    jax.tree_util.tree_leaves(bank_old),
                    jax.tree_util.tree_leaves(bank_new)):
                p, q = np.asarray(p), np.asarray(q)
                e0, e1 = np.asarray(e0), np.asarray(e1)
                tx = mk != 0
                if t.exact_residual:
                    np.testing.assert_array_equal(q[tx] + e1[tx], p[tx])
                else:
                    np.testing.assert_allclose(q[tx] + e1[tx], p[tx],
                                               rtol=1e-5, atol=1e-6)
                np.testing.assert_array_equal(e1[~tx], e0[~tx])
        err = new_err


# --------------------------------------------------------- byte counters
# hyperparameters scaled so every transport ships a large payload (the
# counter contract is about magnitude, not compression)
BYTE_KW = {"topk": {"k": 1 << 21}, "lowrank": {"rank": 2}}


@pytest.mark.parametrize("kind", KINDS)
def test_byte_counter_exact_past_2_24(kind):
    """``payload_bytes`` is a static Python int and the split-int32
    counters stay exact beyond 2^24 bytes — where a single f32 counter
    cell loses integer precision and small increments stop registering."""
    t = opt.make_transport(kind, **BYTE_KW.get(kind, {}))
    params = {"w": jnp.zeros((1024, 1024), jnp.float32),
              "b": jnp.zeros((1 << 20,), jnp.float32)}
    pb = t.payload_bytes(params)
    assert isinstance(pb, int) and pb > 0
    cs = CommStats.init(M)
    mask = jnp.ones((M,), jnp.float32)
    steps = (1 << 24) // (pb * M) + 3
    for _ in range(steps):
        cs = cs.update(mask, pb)
    expected = steps * M * pb
    assert expected > 1 << 24
    assert cs.uplink_bytes_exact() == expected


# ------------------------------------------------------------ spec wire
@pytest.mark.parametrize("kind", KINDS)
def test_spec_roundtrip_json(kind):
    o = _chb(0.05, kind)
    spec = opt.to_spec(o)
    assert spec["transport"]["kind"] == kind
    for key, val in CONFORMANCE_KW.get(kind, {}).items():
        assert spec["transport"][key] == val
    assert opt.from_spec(spec) == o
    assert opt.from_spec(json.loads(json.dumps(spec))) == o


# --------------------------------------------------- metrics read-only
@pytest.mark.parametrize("kind", KINDS)
def test_metrics_read_only_bit_identity(linreg, task32, kind):
    o = _chb(linreg.alpha_paper, kind)
    h_off = simulator.run(o, task32, 25)
    h_on = simulator.run(o, task32, 25, collect_metrics=True)
    _assert_histories_equal(h_off, h_on)
    if make_transport(kind).stateful:
        key = f"transport/{kind}/ef_residual_sqnorm"
        assert key in h_on.metrics, sorted(h_on.metrics)


# ------------------------------------------------------------ sweep axis
@pytest.mark.parametrize("kind", KINDS)
def test_sweep_one_program_and_base_transport_survives(linreg, task32, kind):
    """A quantize axis over one kind compiles ONE program, and the
    base_cfg's task-scaled transport instance (k=8 / rank=2, not the kind
    defaults) is the one the sweep actually runs. Per-point trajectories
    match ``simulator.run`` bitwise at f64 (the PR-2 exactness contract);
    at f32 traced-vs-static hyperparameters agree only to the ulp, for
    every transport alike."""
    a = linreg.alpha_paper
    base = _chb(a, kind)
    grid = sweep.ConfigGrid(alpha=[a, 0.5 * a], beta=[0.4], eps1=[0.5],
                            quantize=[kind])
    task = linreg.task if x64_enabled() else task32
    res = sweep.run_sweep(grid, task, num_iters=25, base_cfg=base)
    assert res.num_programs == 1
    for i, pt in enumerate(res.points):
        assert res.specs[i]["transport"] == opt.to_spec(base)["transport"]
        o = base.with_hparams(alpha=pt.alpha, beta=pt.beta, eps1=pt.eps1)
        h = simulator.run(o, task, 25)
        if x64_enabled():
            np.testing.assert_array_equal(
                np.asarray(h.objective), np.asarray(res.history(i).objective))
        else:
            np.testing.assert_allclose(
                np.asarray(h.objective), np.asarray(res.history(i).objective),
                rtol=1e-5)


# ----------------------------------------------------- kernel-level pins
def _kernel_inputs(dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    pending = jax.random.normal(k1, (4, 300), dtype)
    # salt in negative zeros: a multiply-based select (x * keep) would
    # flip their sign and break bit-parity with the reference
    pending = pending.at[:, 7].set(jnp.asarray(-0.0, dtype))
    err = jax.random.normal(k2, (4, 300), dtype) * 0.1
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
    return pending, err, mask


@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_topk_kernel_matches_oracle(dtype):
    if dtype == "float64":
        require_x64()
    dt = jnp.dtype(dtype)
    pending, err, mask = _kernel_inputs(dt)
    from repro.opt.transport import tree_topk_keep
    keep = tree_topk_keep(pending, 32)
    got_q, got_e = topk_pack.select_pack_ef_batched(pending, err, keep,
                                                    mask)
    want_q, want_e = ref.select_pack_ef_batched(pending, err, keep, mask)
    for got, want in ((got_q, want_q), (got_e, want_e)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.signbit(np.asarray(got)),
                                      np.signbit(np.asarray(want)))
    # row entry: draw-exact vs the M=1 slice of the batched call
    row_q, row_e = topk_pack.select_pack_ef_row(pending[2], err[2], keep[2])
    full_q, full_e = topk_pack.select_pack_ef_batched(
        pending, err, keep, jnp.ones((4,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(row_q), np.asarray(full_q[2]))
    np.testing.assert_array_equal(np.asarray(row_e), np.asarray(full_e[2]))


@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_lowrank_kernel_matches_oracle(dtype):
    if dtype == "float64":
        require_x64()
    dt = jnp.dtype(dtype)
    pending, err, mask = _kernel_inputs(dt, seed=1)
    payload = pending * 0.75     # stand-in reconstruction
    got = lowrank_ef.residual_ef_batched(pending, payload, err, mask)
    want = ref.residual_ef_batched(pending, payload, err, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    row = lowrank_ef.residual_ef_row(pending[1], payload[1], err[1])
    full = lowrank_ef.residual_ef_batched(pending, payload, err,
                                          jnp.ones((4,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(row), np.asarray(full[1]))


# ------------------------------------------------- fused-step conformance
# The one-sweep megakernel (kernels/fused_step.py) is the default pallas
# route for dense and int8+EF; topk/lowrank keep the staged chain. Every
# kind is enrolled here: the trajectory tests pin fused == force_staged()
# bit-for-bit (a no-op for the staged kinds, a real contract for the
# fused ones), and the kernel pins compare the megakernel against the
# staged kernel chain AND the ref.py oracle, element-for-element.
@pytest.mark.parametrize("kind", KINDS)
def test_fused_matches_staged_trajectory_f32(linreg, task32, kind):
    o = _chb(linreg.alpha_paper, kind, "pallas")
    h_fused = simulator.run(o, task32, ITERS)
    with fused_step.force_staged():
        h_staged = simulator.run(o, task32, ITERS)
    _assert_histories_equal(h_fused, h_staged)


@pytest.mark.parametrize("kind", ["dense", "int8"])
def test_fused_matches_staged_trajectory_f64(linreg, kind):
    require_x64()
    o = _chb(linreg.alpha_paper, kind, "pallas")
    h_fused = simulator.run(o, linreg.task, ITERS)
    with fused_step.force_staged():
        h_staged = simulator.run(o, linreg.task, ITERS)
    _assert_histories_equal(h_fused, h_staged)


@pytest.mark.parametrize("kind", ["dense", "int8"])
def test_fused_metrics_read_only(linreg, task32, kind):
    """Metrics collection must stay read-only on the fused route too."""
    o = _chb(linreg.alpha_paper, kind, "pallas")
    _assert_histories_equal(simulator.run(o, task32, 25),
                            simulator.run(o, task32, 25,
                                          collect_metrics=True))


def _fused_inputs(dtype, m=5, seed=4):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    g = jax.random.normal(keys[0], (m, 300), dtype)
    # salt negative zeros: the censored rows of the bank advance and the
    # quantizer's round-trip must preserve their sign bit
    g = g.at[:, 11].set(jnp.asarray(-0.0, dtype))
    ghat = jax.random.normal(keys[1], (m, 300), dtype) * 0.5
    err = jax.random.normal(keys[2], (m, 300), dtype) * 0.1
    theta = jax.random.normal(keys[3], (300,), dtype)
    prev = theta - jax.random.normal(keys[4], (300,), dtype) * 0.01
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0][:m], jnp.float32)
    return g, ghat, err, theta, prev, mask


@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_fused_dense_kernel_matches_staged_and_oracle(dtype):
    if dtype == "float64":
        require_x64()
    g, ghat, _, theta, prev, mask = _fused_inputs(jnp.dtype(dtype))

    # ONE compiled program computes all three routes — how they coexist
    # in real use (the whole step is inside one scan jit), and the only
    # granularity at which XLA's FMA-contraction choices are pinned: a
    # separately-jitted epilogue may contract ``t - alpha*agg``
    # differently from the same expression inlined next to the staged
    # kernels (the trajectory tests cover the cross-program contract)
    @jax.jit
    def all_routes(g, ghat, theta, prev, mask):
        alpha, beta = 0.05, 0.4
        fused = fused_step.fused_dense_step(g, ghat, theta, prev, mask,
                                            alpha, beta)
        # staged kernel chain: bank advance -> eq.(5) sum -> eq.(4) kernel
        ng = censor.censor_bank_advance(g, ghat, mask)
        agg = jnp.sum(ng, axis=0)
        staged = (ng, agg,
                  hb_update.hb_update(theta, agg, prev, alpha, beta))
        oracle = ref.fused_dense_step(g, ghat, theta, prev, mask,
                                      alpha, beta)
        return fused, staged, oracle

    got, staged, want = all_routes(g, ghat, theta, prev, mask)
    for got_x, staged_x, want_x in zip(got, staged, want):
        np.testing.assert_array_equal(np.asarray(got_x),
                                      np.asarray(staged_x))
        np.testing.assert_array_equal(np.asarray(got_x), np.asarray(want_x))
        np.testing.assert_array_equal(np.signbit(np.asarray(got_x)),
                                      np.signbit(np.asarray(want_x)))


@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_fused_int8_kernel_matches_staged_and_oracle(dtype):
    if dtype == "float64":
        require_x64()
    g, ghat, err, theta, prev, mask = _fused_inputs(jnp.dtype(dtype),
                                                    seed=5)

    # one compiled program for all routes (see the dense test above)
    @jax.jit
    def all_routes(g, ghat, err, theta, prev, mask):
        alpha, beta = 0.05, 0.4
        # sweep 1: the stats kernel vs the staged pending materialization
        sq, am = fused_step.int8_stats_batched(g, ghat, err)
        pending = (g.astype(ghat.dtype) - ghat) + err.astype(ghat.dtype)
        staged_stats = (censor.sqnorm_batched(pending),
                        quantize_ef.absmax_batched(pending))
        scale = jnp.where(am > 0, am / 127.0, 1.0).astype(jnp.float32)
        # sweep 2: the megakernel vs the staged chain and the oracle
        fused = fused_step.fused_int8_step(g, ghat, err, theta, prev,
                                           mask, scale, alpha, beta)
        payload, ne = quantize_ef.quantize_ef_batched(pending, err, mask,
                                                      scale)
        ng = censor.bank_advance(ghat, payload, mask)
        agg = jnp.sum(ng, axis=0)
        staged = (ng, ne, agg,
                  hb_update.hb_update(theta, agg, prev, alpha, beta))
        oracle = ref.fused_int8_step(g, ghat, err, theta, prev, mask,
                                     scale, alpha, beta)
        return (sq, am), staged_stats, fused, staged, oracle, payload, \
            pending

    ((got_sq, got_am), staged_stats, got, staged, want, payload,
     pending) = all_routes(g, ghat, err, theta, prev, mask)
    np.testing.assert_array_equal(np.asarray(got_sq),
                                  np.asarray(staged_stats[0]))
    np.testing.assert_array_equal(np.asarray(got_am),
                                  np.asarray(staged_stats[1]))
    for got_x, staged_x, want_x in zip(got, staged, want):
        np.testing.assert_array_equal(np.asarray(got_x),
                                      np.asarray(staged_x))
        np.testing.assert_array_equal(np.asarray(got_x), np.asarray(want_x))
    # EF telescoping survives fusion: the staged payload (bitwise what
    # the megakernel applies in-register) plus the fused residual
    # reconstructs pending on transmitted workers — exactly at f64; at
    # f32 the final ``payload + err`` re-rounding can cost an ulp on
    # arbitrary (pending, err) data, so only closeness is asserted here
    # (test_ef_residual_telescopes pins the exact f32 contract on the
    # transport's own chained construction, which the fused route
    # reproduces bitwise via the staged-equality asserts above)
    tx = np.asarray(mask) != 0
    recon = np.asarray(payload)[tx] + np.asarray(got[1])[tx]
    if dtype == "float64":
        np.testing.assert_array_equal(recon, np.asarray(pending)[tx])
    else:
        np.testing.assert_allclose(recon, np.asarray(pending)[tx],
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_fused_kernels_row_slice_draw_exact(dtype):
    """M=1 single-worker runs of the megakernels reproduce the matching
    worker slice of the M=5 batched call bit-for-bit (the property the
    ``repro.fed`` event runtime's per-client sends rely on)."""
    if dtype == "float64":
        require_x64()
    g, ghat, err, theta, prev, _ = _fused_inputs(jnp.dtype(dtype), seed=6)
    ones = jnp.ones((g.shape[0],), jnp.float32)
    one = jnp.ones((1,), jnp.float32)
    full = fused_step.fused_dense_step(g, ghat, theta, prev, ones,
                                       0.05, 0.4)
    sq_f, am_f = fused_step.int8_stats_batched(g, ghat, err)
    scale = jnp.where(am_f > 0, am_f / 127.0, 1.0).astype(jnp.float32)
    full8 = fused_step.fused_int8_step(g, ghat, err, theta, prev, ones,
                                       scale, 0.05, 0.4)
    for i in range(g.shape[0]):
        row = fused_step.fused_dense_step(
            g[i:i + 1], ghat[i:i + 1], theta, prev, one, 0.05, 0.4)
        np.testing.assert_array_equal(np.asarray(row[0][0]),
                                      np.asarray(full[0][i]))
        sq_r, am_r = fused_step.int8_stats_batched(
            g[i:i + 1], ghat[i:i + 1], err[i:i + 1])
        np.testing.assert_array_equal(np.asarray(sq_r[0]),
                                      np.asarray(sq_f[i]))
        np.testing.assert_array_equal(np.asarray(am_r[0]),
                                      np.asarray(am_f[i]))
        row8 = fused_step.fused_int8_step(
            g[i:i + 1], ghat[i:i + 1], err[i:i + 1], theta, prev, one,
            scale[i:i + 1], 0.05, 0.4)
        np.testing.assert_array_equal(np.asarray(row8[0][0]),
                                      np.asarray(full8[0][i]))
        np.testing.assert_array_equal(np.asarray(row8[1][0]),
                                      np.asarray(full8[1][i]))


# ------------------------------------- int8+EF property tests (hypothesis)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    finite = st.floats(-1e6, 1e6, allow_nan=False, allow_infinity=False,
                       width=32)

    @settings(max_examples=50, deadline=None)
    @given(xs=st.lists(finite, min_size=1, max_size=64),
           seed=st.integers(0, 100))
    def test_property_int8_roundtrip_reconstructs_f64(xs, seed):
        """f64 quantize→dequantize + residual reconstructs the input
        EXACTLY: payload + new_err == pending bitwise (the residual
        subtraction is exact — Sterbenz lemma territory — because payload
        and pending share an exponent window)."""
        require_x64()
        t = opt.make_transport("int8")
        pending = jnp.asarray(xs, jnp.float64)[None]
        err = jnp.zeros_like(pending)
        mask = jnp.ones((1,), jnp.float32)
        payload, aux = t.encode(pending, err)
        new_err = t.feedback(mask, pending, payload, aux, err)
        np.testing.assert_array_equal(
            np.asarray(payload) + np.asarray(new_err), np.asarray(pending))

    @settings(max_examples=50, deadline=None)
    @given(xs=st.lists(finite, min_size=1, max_size=64),
           steps=st.integers(2, 8))
    def test_property_int8_ef_residual_bounded_constant_input(xs, steps):
        """Repeated application to a constant input: each round's residual
        is bounded elementwise by half the round's quantization step
        (scale/2, from round-to-nearest), so the EF bank never accumulates
        — and re-encoding the SAME pending is idempotent (the unchained
        residual sequence is trivially monotone)."""
        t = opt.make_transport("int8")
        delta = jnp.asarray(xs, jnp.float32)[None]
        err = jnp.zeros_like(delta)
        mask = jnp.ones((1,), jnp.float32)
        for _ in range(steps):
            pending = t.prepare(delta, err)
            payload, aux = t.encode(pending, err)
            err = t.feedback(mask, pending, payload, aux, err)
            amax = float(jnp.max(jnp.abs(pending)))
            scale = amax / 127.0 if amax > 0 else 1.0
            bound = 0.5 * scale * (1 + 1e-6) + 1e-30
            assert float(jnp.max(jnp.abs(err))) <= bound
        # idempotence: encoding the same pending twice gives one residual
        p1, _ = t.encode(pending, err)
        p2, _ = t.encode(pending, err)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
else:   # pragma: no cover - dev-deps-only skip marker
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_int8_ef():
        pass


# ------------------------------------------------- benchmark curve smoke
def test_benchmark_transport_curves_and_spec_roundtrip():
    """``compare_algorithms`` grows one ``chb_<kind>`` curve per non-dense
    registry transport, each carrying a ``from_spec``-able registry spec
    whose task-scaled transport hyperparameters survived the sweep."""
    from benchmarks import common as bcommon

    bundle = paper_tasks.make_linear_regression(m=4, n_per=20, d=10, seed=0)
    res = bcommon.compare_algorithms(
        bundle, num_iters=200, tol=1e-3, fstar_iters=2000,
        transports=("int8", "topk", "lowrank"))
    curves = [a for a in bcommon.CURVES if a in res]
    assert curves == bcommon.CURVES
    for name in curves:
        spec = res[name]["spec"]
        rebuilt = opt.from_spec(spec)
        assert opt.to_spec(rebuilt) == spec
        assert isinstance(res[name]["uplink_bytes"], int)
    # the task-scaled instances (not the registry defaults) are what ran
    n = bcommon.task_params_count(bundle.task)
    assert res["chb_topk"]["spec"]["transport"]["k"] == max(1, 2 * n // 5)
    assert res["chb_lowrank"]["spec"]["transport"]["rank"] == 2

"""The mesh-sharded federated runtime's single-shard contracts.

Anchor (a) lives here: ``fed.run_mesh`` sharded over ONE device under the
ideal scenario is **bit-identical** to ``core.simulator.run`` — objective,
censor masks, aggregate norms, uplink counts, final params — across
algorithms, backends, and transports. Everything multi-device (anchor (b),
K-shard invariance) runs in subprocesses in tests/test_distributed.py.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import fed, opt
from repro.core import simulator
from repro.data import edge_tasks, paper_tasks
from repro.fed.clients import uniform_vector_population
from repro.fed.mesh import MeshScenario, run_mesh
from repro.launch.mesh import make_client_mesh

M = 5


@pytest.fixture(scope="module")
def bundle():
    return paper_tasks.make_linear_regression(m=M, n_per=30, d=20, seed=0)


def _leaves_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("backend", sorted(opt.BACKENDS))
@pytest.mark.parametrize("algo", ["chb", "lag", "csgd"])
def test_sync_anchor_bitwise_dense(bundle, algo, backend):
    """Ideal scenario, K=1: run_mesh == simulator.run bit-for-bit."""
    o = opt.make(algo, bundle.alpha_paper, M, backend=backend)
    hist = simulator.run(o, bundle.task, 12)
    mh = run_mesh(o, bundle.task, 12)
    np.testing.assert_array_equal(np.asarray(hist.objective), mh.objective)
    np.testing.assert_array_equal(
        np.asarray(hist.mask).astype(np.int8), mh.mask)
    np.testing.assert_array_equal(np.asarray(hist.agg_grad_sqnorm),
                                  mh.agg_grad_sqnorm)
    np.testing.assert_array_equal(np.asarray(hist.comm_cum), mh.comm_cum)
    _leaves_equal(hist.final_params, mh.final_params)
    assert mh.quorum_met.all()
    assert (mh.participated == M).all()
    np.testing.assert_array_equal(mh.attempted, mh.delivered)


@pytest.mark.parametrize("backend", sorted(opt.BACKENDS))
def test_sync_anchor_bitwise_int8(bundle, backend):
    """The quantized transport rides the same anchor: shard_step's staged
    kernels must reproduce the fused step's bits through EF residuals."""
    o = opt.make("chb", bundle.alpha_paper, M, quantize="int8",
                 backend=backend)
    hist = simulator.run(o, bundle.task, 12)
    mh = run_mesh(o, bundle.task, 12)
    np.testing.assert_array_equal(np.asarray(hist.objective), mh.objective)
    np.testing.assert_array_equal(
        np.asarray(hist.mask).astype(np.int8), mh.mask)
    _leaves_equal(hist.final_params, mh.final_params)


def test_donation_is_bit_identical(bundle):
    """``donate=True`` may only change buffer reuse, never a rounding —
    including the prev_params overwrite after a quorum round."""
    o = opt.make("chb", bundle.alpha_paper, M)
    sc = MeshScenario(participation=0.7, loss_prob=0.3, quorum=0.6, seed=5)
    plain = run_mesh(o, bundle.task, 15, scenario=sc)
    donated = run_mesh(o, bundle.task, 15, scenario=sc, donate=True)
    np.testing.assert_array_equal(plain.objective, donated.objective)
    np.testing.assert_array_equal(plain.mask, donated.mask)
    np.testing.assert_array_equal(plain.quorum_met, donated.quorum_met)
    _leaves_equal(plain.final_params, donated.final_params)


def test_bake_data_off_is_allclose_not_required_bitwise(bundle):
    """``bake_data=False`` (argument-passed data, one shared trace) stays
    within reduction-order ulps of the baked default; masks and counts
    are exactly equal (integer decisions survive the ulp)."""
    o = opt.make("chb", bundle.alpha_paper, M)
    sc = MeshScenario(participation=0.8, loss_prob=0.1, seed=2)
    baked = run_mesh(o, bundle.task, 12, scenario=sc)
    unbaked = run_mesh(o, bundle.task, 12, scenario=sc, bake_data=False)
    np.testing.assert_array_equal(baked.mask, unbaked.mask)
    np.testing.assert_array_equal(baked.participated, unbaked.participated)
    np.testing.assert_allclose(baked.objective, unbaked.objective,
                               rtol=1e-12)


def test_scenario_draws_replay_exactly(bundle):
    """Same scenario → same draws, run to run: the per-(seed, round, id)
    key folding has no hidden state."""
    o = opt.make("chb", bundle.alpha_paper, M)
    sc = MeshScenario(participation=0.6, loss_prob=0.25, seed=11)
    a = run_mesh(o, bundle.task, 10, scenario=sc)
    b = run_mesh(o, bundle.task, 10, scenario=sc)
    np.testing.assert_array_equal(a.mask, b.mask)
    np.testing.assert_array_equal(a.objective, b.objective)
    # and a different seed actually changes the draws
    c = run_mesh(o, bundle.task, 10,
                 scenario=MeshScenario(participation=0.6, loss_prob=0.25,
                                       seed=12))
    assert not np.array_equal(a.mask, c.mask)


def test_quorum_semantics_pinned_by_counts(bundle):
    """Replay fed_sweep's quorum rule from the recorded counts: met iff
    ``arrived >= ceil(quorum * cohort)`` with censored beacons counting
    and drops not; frozen rounds freeze the objective."""
    o = opt.make("chb", bundle.alpha_paper, M)
    sc = MeshScenario(participation=0.8, loss_prob=0.4, quorum=0.7, seed=7)
    mh = run_mesh(o, bundle.task, 30, scenario=sc)
    arrived = mh.participated - (mh.attempted - mh.delivered)
    want = (arrived >= np.ceil(sc.quorum * mh.participated)) \
        & (mh.participated > 0)
    np.testing.assert_array_equal(mh.quorum_met, want)
    assert not mh.quorum_met.all(), "scenario too easy to pin the gate"
    # a failed round k freezes theta, so round k+1 re-evaluates the same
    # objective value
    frozen = np.nonzero(~mh.quorum_met[:-1])[0]
    np.testing.assert_array_equal(mh.objective[frozen + 1],
                                  mh.objective[frozen])
    assert (mh.delivered <= mh.attempted).all()
    assert (mh.attempted <= mh.participated).all()


def test_quorum_need_is_the_shared_definition():
    """One quorum definition across the event runtime and the mesh."""
    assert fed.quorum_need(1.0, 7) == 7
    assert fed.quorum_need(0.5, 7) == 4
    assert fed.quorum_need(0.2, 3) == 1
    assert fed.quorum_need(0.1, 0) == 1   # floor: never wait on nobody


def test_accounting_bytes_energy_wall(bundle):
    """Bytes are exact attempted×payload ints; energy and wall-clock are
    monotone and follow the shared EnergyModel.round_energy split."""
    o = opt.make("chb", bundle.alpha_paper, M)
    sc = MeshScenario(participation=0.7, loss_prob=0.2, seed=3)
    pop = uniform_vector_population(M, compute_mean_s=0.5,
                                   straggler_frac=0.2)
    ch = fed.ChannelConfig()
    em = fed.EnergyModel()
    mh = run_mesh(o, bundle.task, 10, scenario=sc, population=pop,
                  channel=ch, energy=em)
    payload = o.transport.payload_bytes(bundle.task.init_params)
    np.testing.assert_array_equal(mh.bytes_cum,
                                  np.cumsum(mh.attempted) * payload)
    np.testing.assert_array_equal(mh.comm_cum, np.cumsum(mh.attempted))
    assert (np.diff(mh.wall_clock) > 0).all()
    assert (np.diff(mh.energy_cum) > 0).all()
    # radio joules alone lower-bound the total (compute joules are >= 0)
    radio = np.cumsum(em.round_energy(mh.attempted, mh.participated,
                                      payload))
    assert (mh.energy_cum >= radio - 1e-9).all()


def test_collect_metrics_merges_to_simulator_bag(bundle):
    """K=1 sync: the merged per-round MetricBag equals the simulator's
    (weighted mean over one shard is the identity)."""
    o = opt.make("chb", bundle.alpha_paper, M)
    hist = simulator.run(o, bundle.task, 8, collect_metrics=True)
    mh = run_mesh(o, bundle.task, 8, collect_metrics=True)
    assert len(mh.metrics) == 8
    for k in ("censor_rate", "bank_sqnorm", "agg_grad_sqnorm",
              "step_sqnorm"):
        sim_series = np.asarray(hist.metrics[k])
        mesh_series = np.asarray([bag[k] for bag in mh.metrics])
        np.testing.assert_allclose(mesh_series, sim_series, rtol=1e-12,
                                   err_msg=k)


def test_rejects_non_composed_and_adaptive(bundle):
    import dataclasses as dc

    from repro.opt.api import FedOptimizer

    @dc.dataclass(frozen=True)
    class Wrapped(FedOptimizer):
        inner: object

        def init(self, params):
            return self.inner.init(params)

        def step(self, state, params, grads):
            return self.inner.step(state, params, grads)

    with pytest.raises(TypeError, match="ComposedOptimizer"):
        run_mesh(Wrapped(opt.make("chb", bundle.alpha_paper, M)),
                 bundle.task, 2)
    adaptive = opt.ComposedOptimizer(
        censor=opt.AdaptiveCensor(0.25), transport=opt.DenseTransport(),
        server=opt.HeavyBall(bundle.alpha_paper, 0.4), num_workers=M)
    with pytest.raises(NotImplementedError, match="adaptive"):
        run_mesh(adaptive, bundle.task, 2)


def test_rejects_mismatched_sizes(bundle):
    o = opt.make("chb", bundle.alpha_paper, M + 1)
    with pytest.raises(ValueError, match="num_workers"):
        run_mesh(o, bundle.task, 2)
    o = opt.make("chb", bundle.alpha_paper, M)
    with pytest.raises(ValueError, match="clients"):
        run_mesh(o, bundle.task, 2,
                 population=uniform_vector_population(M + 2))


def test_mesh_larger_than_device_count_raises_loudly():
    """The single-device pytest process cannot host a 2-shard mesh — the
    error must name the XLA_FLAGS escape hatch, not crash in XLA."""
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_client_mesh(2)


def test_scenario_validation():
    with pytest.raises(ValueError, match="participation"):
        MeshScenario(participation=0.0)
    with pytest.raises(ValueError, match="loss_prob"):
        MeshScenario(loss_prob=1.0)
    with pytest.raises(ValueError, match="quorum"):
        MeshScenario(quorum=1.5)
    assert MeshScenario().sync_draws
    assert not MeshScenario(participation=0.9).sync_draws
    assert not MeshScenario(loss_prob=0.1).sync_draws


def test_vector_population_shapes_and_conversion():
    pop = uniform_vector_population(10, straggler_frac=0.3, seed=1)
    assert pop.num_clients == 10
    assert pop.compute_mean_s.shape == (10,)
    assert (pop.compute_mean_s > 0).all()
    from repro.fed.clients import uniform_population
    vec = uniform_population(4).as_vector()
    assert vec.num_clients == 4
    with pytest.raises(ValueError):
        fed.VectorPopulation(np.ones(3), np.ones(4))
    with pytest.raises(ValueError):
        fed.VectorPopulation(np.ones(3), np.ones(3), participation=0.0)


def test_edge_quadratics_task():
    """The O(M·d) ladder task: grads match autodiff, f* is closed-form,
    and the mesh runtime drives it to the optimum."""
    task = edge_tasks.make_edge_quadratics(64, d=8, seed=4)
    theta = jnp.linspace(-1.0, 1.0, 8)
    row = jax.tree_util.tree_map(lambda x: x[3], task.worker_data)
    auto = jax.grad(task.loss_fn)(theta, row)
    np.testing.assert_allclose(np.asarray(task.grad_fn(theta, row)),
                               np.asarray(auto), rtol=1e-12)
    fstar = edge_tasks.edge_quadratics_fstar(task)
    o = opt.make("csgd", 1.0 / 64, 64)
    mh = run_mesh(o, task, 60, collect_mask=False)
    assert mh.objective[-1] - fstar < 1e-3 * mh.objective[0]
    assert mh.mask is None


def test_edge_linreg_task_runs():
    task = edge_tasks.make_edge_linreg(32, n_per=4, d=8, seed=2)
    o = opt.make("chb", 1.0 / (32 * 4), 32)
    mh = run_mesh(o, task, 40)
    assert mh.objective[-1] < mh.objective[0]
    assert np.isfinite(mh.objective).all()

"""Per-kernel validation against the ``kernels/ref.py`` oracles.

Pallas kernels run in interpret mode on CPU. Two comparison regimes:

  * ``allclose`` for reductions whose tile-partial tree reorders float
    sums (sqnorms);
  * **bitwise**, with both sides jitted, for everything elementwise
    (select, bank advances, hb update, quantize+EF) — jitting both sides
    matters on CPU because XLA may contract mul+add chains differently in
    eager vs compiled programs, which is a property of the harness, not
    of the kernels.

Property-based tests (hypothesis) are skipped when the dev deps are
absent; everything else runs everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import censor, flash_attention, hb_update, ops, \
    quantize_ef, ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests need the dev deps
    HAVE_HYPOTHESIS = False

SHAPES = [(128,), (1000,), (8, 128), (3, 1000), (5, 7, 11), (2, 256, 130)]
DTYPES = [jnp.float32, jnp.bfloat16]
# padding edge cases: exact tile multiples, sub-lane tails, >1 tile with a
# ragged tail (not a multiple of rows*128), tiny tensors
BATCH_SHAPES = [(3, 20), (5, 128), (4, 1000), (2, 7, 33), (3, 300, 129)]


def _pair(shape, dtype, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.normal(k1, shape).astype(dtype)
    h = jax.random.normal(k2, shape).astype(dtype)
    return g, h


def _bits_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got, np.float64),
                                  np.asarray(want, np.float64))
    assert got.dtype == want.dtype and got.shape == want.shape


# ------------------------------------------------- single-tensor kernels
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_censor_delta_sqnorm(shape, dtype):
    g, h = _pair(shape, dtype)
    got = censor.censor_delta_sqnorm(g, h, interpret=True)
    want = ref.censor_delta_sqnorm(g, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("transmit", [0, 1])
def test_censor_select(shape, dtype, transmit):
    """bf16 and f32, ragged and aligned shapes: bit-identical to oracle."""
    g, h = _pair(shape, dtype, seed=1)
    got = censor.censor_select(g, h, jnp.asarray(transmit), interpret=True)
    want = ref.censor_select(g, h, jnp.asarray(transmit))
    _bits_equal(got, want)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_hb_update(shape, dtype):
    """Jit-vs-jit bitwise vs the oracle (bf16 upcasts to f32 in both)."""
    g, h = _pair(shape, dtype, seed=2)
    p = (g * 0.9).astype(dtype)
    got = jax.jit(hb_update.hb_update)(g, h, p, 0.1, 0.4)
    want = jax.jit(ref.hb_update)(g, h, p, 0.1, 0.4)
    _bits_equal(got, want)


def test_hb_update_traced_scalars_no_retrace():
    """alpha/beta are operands: a 5-point alpha grid compiles once."""
    t, n = _pair((3, 257), jnp.float32, seed=3)
    p = (t * 0.5).astype(jnp.float32)
    traces = []

    @jax.jit
    def step(t, n, p, a, b):
        traces.append(1)           # ticks at trace time only
        return hb_update.hb_update(t, n, p, a, b)

    outs = [step(t, n, p, jnp.float32(a), jnp.float32(0.4))
            for a in (0.1, 0.2, 0.3, 0.4, 0.5)]
    assert len(traces) == 1
    # and the sweep actually produced distinct updates
    assert not np.array_equal(np.asarray(outs[0]), np.asarray(outs[-1]))


def test_hb_param_update_wrapper_no_retrace():
    """The jitted ops wrapper takes traced hparams (the PR-2 regression:
    static_argnames alpha/beta recompiled every grid point)."""
    t, n = _pair((500,), jnp.float32, seed=4)
    p = (t * 0.5).astype(jnp.float32)
    before = ops.hb_param_update._cache_size()
    for a in (0.1, 0.2, 0.3, 0.4, 0.5):
        ops.hb_param_update(t, n, p, jnp.float32(a), jnp.float32(0.4))
    # one new compilation for the shape — not one per alpha
    assert ops.hb_param_update._cache_size() == before + 1


# ---------------------------------------------- leading-M batched kernels
@pytest.mark.parametrize("shape", BATCH_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_censor_delta_sqnorm_batched(shape, dtype):
    g, h = _pair(shape, dtype, seed=5)
    got = censor.censor_delta_sqnorm_batched(g, h, interpret=True)
    want = ref.censor_delta_sqnorm_batched(g, h)
    assert got.shape == (shape[0],) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("shape", BATCH_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bank_advance_kernels_bitwise(shape, dtype):
    g, h = _pair(shape, dtype, seed=6)
    mask = (jnp.arange(shape[0]) % 2).astype(jnp.float32)
    _bits_equal(jax.jit(censor.censor_bank_advance)(g, h, mask),
                jax.jit(ref.censor_bank_advance)(g, h, mask))
    _bits_equal(jax.jit(censor.bank_advance)(h, g, mask),
                jax.jit(ref.bank_advance)(h, g, mask))


@pytest.mark.parametrize("shape", BATCH_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_ef_batched_bitwise(shape, dtype):
    p, e = _pair(shape, dtype, seed=7)
    e = (e * 0.01).astype(dtype)
    mask = (1.0 - jnp.arange(shape[0]) % 2).astype(jnp.float32)
    amax = quantize_ef.absmax_batched(p, interpret=True)
    _bits_equal(amax, ref.absmax_batched(p))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    got_q, got_e = jax.jit(quantize_ef.quantize_ef_batched)(p, e, mask,
                                                            scale)
    want_q, want_e = jax.jit(ref.quantize_ef_batched)(p, e, mask, scale)
    _bits_equal(got_q, want_q)
    _bits_equal(got_e, want_e)


def test_int8_tree_matches_core_quantize():
    """ops.tree_int8_roundtrip_ef payload == core/quantize per-worker
    round-trip, bit-for-bit (at mask=1 the err leaf is the residual)."""
    from repro.core.quantize import tree_quantize_roundtrip_per_worker
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 17, 9), jnp.float32)
    tree = {"w": x, "b": x[:, 0]}
    zeros = jax.tree_util.tree_map(jnp.zeros_like, tree)
    payload, new_err = jax.jit(ops.tree_int8_roundtrip_ef)(
        tree, zeros, jnp.ones((4,)))
    want = jax.jit(tree_quantize_roundtrip_per_worker)(tree)
    for k in tree:
        _bits_equal(payload[k], want[k])
        # the residual is a cancellation — XLA may or may not contract
        # p - q*scale into an fma depending on the surrounding graph, so
        # only the like-for-like program comparison is bitwise (see
        # test_quantize_ef_batched_bitwise / tests/test_backend.py)
        np.testing.assert_allclose(np.asarray(new_err[k]),
                                   np.asarray(tree[k] - want[k]),
                                   rtol=0, atol=1e-6)


def test_row_matches_batched_bitwise():
    """The fed runtime's M=1 row sqnorm == the batched per-worker slice,
    bit-for-bit — what keeps event-runtime censor decisions draw-exact."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(9), (5, 40, 7)),
            "b": jax.random.normal(jax.random.PRNGKey(10), (5, 203))}
    batched = ops.tree_sqnorms(tree)
    for i in range(5):
        row = ops.tree_sqnorm_row(
            jax.tree_util.tree_map(lambda x, i=i: x[i], tree))
        assert np.asarray(row) == np.asarray(batched)[i]


def test_tree_delta_sqnorms_matches_core_censoring():
    """Fused (g, h) variant vs core.censoring.delta_sqnorms on the
    materialized delta tree."""
    from repro.core.censoring import delta_sqnorms
    g = {"w": jax.random.normal(jax.random.PRNGKey(11), (3, 50, 4))}
    h = {"w": jax.random.normal(jax.random.PRNGKey(12), (3, 50, 4))}
    delta = jax.tree_util.tree_map(jnp.subtract, g, h)
    np.testing.assert_allclose(np.asarray(ops.tree_delta_sqnorms(g, h)),
                               np.asarray(delta_sqnorms(delta)), rtol=1e-6)


# ------------------------------------------------------- zero-size leaves
def test_zero_size_leaves():
    """DenseTransport err leaves are (0,); every kernel must pass them
    through without launching a grid."""
    m = 3
    z2 = jnp.zeros((m, 0), jnp.float32)
    z1 = jnp.zeros((0,), jnp.float32)
    ones = jnp.ones((m,), jnp.float32)
    assert censor.censor_delta_sqnorm(z1, z1).shape == ()
    assert censor.censor_select(z1, z1, jnp.asarray(1)).shape == (0,)
    assert hb_update.hb_update(z1, z1, z1, 0.1, 0.4).shape == (0,)
    np.testing.assert_array_equal(
        np.asarray(censor.censor_delta_sqnorm_batched(z2, z2)),
        np.zeros((m,), np.float32))
    np.testing.assert_array_equal(np.asarray(censor.sqnorm_batched(z2)),
                                  np.zeros((m,), np.float32))
    assert censor.censor_bank_advance(z2, z2, ones).shape == (m, 0)
    assert censor.bank_advance(z2, z2, ones).shape == (m, 0)
    assert quantize_ef.absmax_batched(z2).shape == (m,)
    q, e = quantize_ef.quantize_ef_batched(z2, z2, ones, ones)
    assert q.shape == e.shape == (m, 0)
    # tree dispatch with a mixed tree (a real leaf + an empty one)
    tree = {"w": jnp.ones((m, 8)), "e": z2}
    out = ops.tree_sqnorms(tree)
    np.testing.assert_allclose(np.asarray(out), np.full((m,), 8.0))


def test_interpret_default_shared():
    """Direct kernel calls and ops wrappers resolve interpret identically
    (no silent interpreter performance on TPU, no Mosaic on CPU)."""
    from repro.kernels.common import interpret_default, resolve_interpret
    assert ops._interpret_default is interpret_default
    assert resolve_interpret(None) == interpret_default()
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # on this CPU container the shared default is interpret mode
    if jax.default_backend() != "tpu":
        assert interpret_default() is True


# ------------------------------------------------- flash-attention kernel
@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_kernel(h, kh, causal, window, dtype):
    key = jax.random.PRNGKey(3)
    b, l, d = 2, 128, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, l, d)).astype(dtype)
    k = jax.random.normal(kk, (b, kh, l, d)).astype(dtype)
    v = jax.random.normal(kv, (b, kh, l, d)).astype(dtype)
    got = flash_attention.flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_block=32, kv_block=64,
        interpret=True)
    want = ref.flash_attention_fwd(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_rectangular_kv():
    """cross-attention shape: Lq != S."""
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (1, 4, 64, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 16))
    got = flash_attention.flash_attention_pallas(
        q, k, v, causal=False, q_block=32, kv_block=64, interpret=True)
    want = ref.flash_attention_fwd(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------------- property-based (hypo)
if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 5000), seed=st.integers(0, 100),
           dtype_i=st.integers(0, 1))
    def test_property_censor_roundtrip(n, seed, dtype_i):
        """select(g,h,1)==g, select(g,h,0)==h, sqnorm matches, any shape."""
        dtype = DTYPES[dtype_i]
        g, h = _pair((n,), dtype, seed=seed)
        np.testing.assert_array_equal(
            np.asarray(censor.censor_select(g, h, jnp.asarray(1),
                                            interpret=True)),
            np.asarray(g.astype(h.dtype)))
        np.testing.assert_array_equal(
            np.asarray(censor.censor_select(g, h, jnp.asarray(0),
                                            interpret=True)),
            np.asarray(h))
        got = censor.censor_delta_sqnorm(g, h, interpret=True)
        want = ref.censor_delta_sqnorm(g, h)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(rows=st.integers(1, 64), alpha=st.floats(1e-4, 1.0),
           beta=st.floats(0.0, 0.99), seed=st.integers(0, 100))
    def test_property_hb_update(rows, alpha, beta, seed):
        g, h = _pair((rows, 33), jnp.float32, seed=seed)
        p = (g * 0.5).astype(jnp.float32)
        got = hb_update.hb_update(g, h, p, alpha, beta, interpret=True)
        want = ref.hb_update(g, h, p, alpha, beta)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
else:   # pragma: no cover - dev-deps-only skip marker
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_kernels():
        pass


# ---------------------------------------------------- decode attention kernel
from repro.kernels import decode_attention as da


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2)])
@pytest.mark.parametrize("pos", [5, 63, 200])
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_attention_kernel(h, kh, pos, dtype):
    key = jax.random.PRNGKey(7)
    b, c, d = 2, 128, 32
    q = jax.random.normal(key, (b, h, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kh, c, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kh, c, d)).astype(dtype)
    from repro.models.kvcache import slot_positions
    cpos = slot_positions(jnp.asarray(pos + 1), c)
    got = da.decode_attention_pallas(q, k, v, cpos, jnp.asarray(pos),
                                     block=32, interpret=True)
    want = da.decode_attention_ref(q, k, v, cpos, jnp.asarray(pos))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_matches_model_layer():
    """Kernel semantics == the model's decode_attention math."""
    from repro.configs.base import ModelConfig
    from repro.models import layers
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=97, layer_pattern="A", scan_period=1,
                      dtype="float32")
    p = layers.init_attention(jax.random.PRNGKey(0), cfg)
    b, c = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, 64)) * 0.5
    kc = jax.random.normal(jax.random.PRNGKey(2), (b, c, 2, 16))
    vc = jax.random.normal(jax.random.PRNGKey(3), (b, c, 2, 16))
    pos = jnp.asarray(20)
    from repro.models.kvcache import slot_positions
    cpos = slot_positions(pos + 1, c)
    ref_out = layers.decode_attention(p, cfg, x, kc, vc, cpos, pos)
    # kernel path: q projection + rope identical to the layer, then kernel
    q = (x @ p["wq"]).reshape(b, 1, 4, 16)
    q = layers.rope(q, pos[None].astype(jnp.int32), cfg.rope_theta)
    o = da.decode_attention_pallas(q[:, 0].reshape(b, 4, 16),
                                   kc.transpose(0, 2, 1, 3),
                                   vc.transpose(0, 2, 1, 3),
                                   cpos, pos, block=32)
    got = (o.reshape(b, 1, 64) @ p["wo"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)

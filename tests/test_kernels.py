"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py
oracles (Pallas kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st

from repro.kernels import censor, flash_attention, hb_update, ref

SHAPES = [(128,), (1000,), (8, 128), (3, 1000), (5, 7, 11), (2, 256, 130)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _pair(shape, dtype, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.normal(k1, shape).astype(dtype)
    h = jax.random.normal(k2, shape).astype(dtype)
    return g, h


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_censor_delta_sqnorm(shape, dtype):
    g, h = _pair(shape, dtype)
    got = censor.censor_delta_sqnorm(g, h, interpret=True)
    want = ref.censor_delta_sqnorm(g, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("transmit", [0, 1])
def test_censor_select(shape, dtype, transmit):
    g, h = _pair(shape, dtype, seed=1)
    got = censor.censor_select(g, h, jnp.asarray(transmit), interpret=True)
    want = ref.censor_select(g, h, jnp.asarray(transmit))
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_hb_update(shape, dtype):
    g, h = _pair(shape, dtype, seed=2)
    p = (g * 0.9).astype(dtype)
    got = hb_update.hb_update(g, h, p, 0.1, 0.4, interpret=True)
    want = ref.hb_update(g, h, p, 0.1, 0.4)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_kernel(h, kh, causal, window, dtype):
    key = jax.random.PRNGKey(3)
    b, l, d = 2, 128, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, l, d)).astype(dtype)
    k = jax.random.normal(kk, (b, kh, l, d)).astype(dtype)
    v = jax.random.normal(kv, (b, kh, l, d)).astype(dtype)
    got = flash_attention.flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_block=32, kv_block=64,
        interpret=True)
    want = ref.flash_attention_fwd(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_rectangular_kv():
    """cross-attention shape: Lq != S."""
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (1, 4, 64, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 16))
    got = flash_attention.flash_attention_pallas(
        q, k, v, causal=False, q_block=32, kv_block=64, interpret=True)
    want = ref.flash_attention_fwd(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 100),
       dtype_i=st.integers(0, 1))
def test_property_censor_roundtrip(n, seed, dtype_i):
    """select(g,h,1)==g, select(g,h,0)==h, sqnorm matches, any shape."""
    dtype = DTYPES[dtype_i]
    g, h = _pair((n,), dtype, seed=seed)
    np.testing.assert_array_equal(
        np.asarray(censor.censor_select(g, h, jnp.asarray(1),
                                        interpret=True)),
        np.asarray(g.astype(h.dtype)))
    np.testing.assert_array_equal(
        np.asarray(censor.censor_select(g, h, jnp.asarray(0),
                                        interpret=True)),
        np.asarray(h))
    got = censor.censor_delta_sqnorm(g, h, interpret=True)
    want = ref.censor_delta_sqnorm(g, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 64), alpha=st.floats(1e-4, 1.0),
       beta=st.floats(0.0, 0.99), seed=st.integers(0, 100))
def test_property_hb_update(rows, alpha, beta, seed):
    g, h = _pair((rows, 33), jnp.float32, seed=seed)
    p = (g * 0.5).astype(jnp.float32)
    got = hb_update.hb_update(g, h, p, alpha, beta, interpret=True)
    want = ref.hb_update(g, h, p, alpha, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------- decode attention kernel
from repro.kernels import decode_attention as da


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2)])
@pytest.mark.parametrize("pos", [5, 63, 200])
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_attention_kernel(h, kh, pos, dtype):
    key = jax.random.PRNGKey(7)
    b, c, d = 2, 128, 32
    q = jax.random.normal(key, (b, h, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kh, c, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kh, c, d)).astype(dtype)
    from repro.models.kvcache import slot_positions
    cpos = slot_positions(jnp.asarray(pos + 1), c)
    got = da.decode_attention_pallas(q, k, v, cpos, jnp.asarray(pos),
                                     block=32, interpret=True)
    want = da.decode_attention_ref(q, k, v, cpos, jnp.asarray(pos))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_matches_model_layer():
    """Kernel semantics == the model's decode_attention math."""
    from repro.configs.base import ModelConfig
    from repro.models import layers
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=97, layer_pattern="A", scan_period=1,
                      dtype="float32")
    p = layers.init_attention(jax.random.PRNGKey(0), cfg)
    b, c = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, 64)) * 0.5
    kc = jax.random.normal(jax.random.PRNGKey(2), (b, c, 2, 16))
    vc = jax.random.normal(jax.random.PRNGKey(3), (b, c, 2, 16))
    pos = jnp.asarray(20)
    from repro.models.kvcache import slot_positions
    cpos = slot_positions(pos + 1, c)
    ref_out = layers.decode_attention(p, cfg, x, kc, vc, cpos, pos)
    # kernel path: q projection + rope identical to the layer, then kernel
    q = (x @ p["wq"]).reshape(b, 1, 4, 16)
    q = layers.rope(q, pos[None].astype(jnp.int32), cfg.rope_theta)
    o = da.decode_attention_pallas(q[:, 0].reshape(b, 4, 16),
                                   kc.transpose(0, 2, 1, 3),
                                   vc.transpose(0, 2, 1, 3),
                                   cpos, pos, block=32)
    got = (o.reshape(b, 1, 64) @ p["wo"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)

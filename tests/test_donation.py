"""Buffer-donation safety: fused-step perf must never change bits.

``train/trainer.py`` jits its step with ``donate_argnums=(0, 1)`` and
``simulator.run(donate=True)`` donates ``init_params`` into the scan —
so XLA may overwrite any donated input buffer as soon as it likes. The
one invariant that makes this safe is the step-0 copy guard: every
``init`` (``ComposedOptimizer.init``, ``distributed.init_scan_state``)
copies ``prev_params`` instead of aliasing ``params``, because theta^{-1}
must survive the write of theta^1 into the donated theta^0 buffer.

These are regression tests for that guard: they pin the non-aliasing
property directly (buffer pointers, not values) and pin that donation is
a pure perf knob — donated and undonated runs are bit-identical.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import opt
from repro.core import distributed, simulator
from repro.core.chb import FedOptConfig
from repro.data import paper_tasks

M = 4


def _ptrs(tree):
    return {x.unsafe_buffer_pointer()
            for x in jax.tree_util.tree_leaves(tree)
            if hasattr(x, "unsafe_buffer_pointer")}


@pytest.fixture(scope="module")
def bundle():
    return paper_tasks.make_linear_regression(m=M, n_per=20, d=12, seed=3)


@pytest.mark.parametrize("backend", sorted(opt.BACKENDS))
def test_opt_init_prev_params_never_aliases_params(backend):
    """``OptState.prev_params`` buffers are disjoint from ``params`` at
    step 0 — the donated-theta^0 aliasing guard."""
    params = {"w": jnp.arange(12.0, dtype=jnp.float32),
              "b": jnp.ones((3,), jnp.float32)}
    o = opt.make("chb", 0.05, M, backend=backend)
    state = o.init(params)
    assert not (_ptrs(state.prev_params) & _ptrs(params))
    # and the values still agree: the guard is a copy, not a recompute
    for a, b in zip(jax.tree_util.tree_leaves(state.prev_params),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distributed_init_scan_state_never_aliases_params():
    params = {"w": jnp.arange(8.0, dtype=jnp.float32)}
    cfg = FedOptConfig(alpha=0.05, num_workers=M)
    state = distributed.init_scan_state(cfg, params)
    assert not (_ptrs(state.prev_params) & _ptrs(params))


@pytest.mark.parametrize("backend", sorted(opt.BACKENDS))
@pytest.mark.parametrize("quantize", [None, "int8"])
def test_simulator_donation_is_bit_identical(bundle, backend, quantize):
    """``run(donate=True)`` == ``run(donate=False)`` bit-for-bit; donation
    may only change buffer reuse, never a single rounding."""
    o = opt.make("chb", bundle.alpha_paper, M, quantize=quantize,
                 backend=backend)
    h_plain = simulator.run(o, bundle.task, 30)
    # a fresh task copy: donate=True invalidates its init_params buffers
    donated_task = bundle.task._replace(
        init_params=jax.tree_util.tree_map(jnp.copy,
                                           bundle.task.init_params))
    h_donated = simulator.run(o, donated_task, 30, donate=True)
    for f in ("objective", "mask", "comm_cum", "agg_grad_sqnorm"):
        np.testing.assert_array_equal(np.asarray(getattr(h_plain, f)),
                                      np.asarray(getattr(h_donated, f)),
                                      err_msg=f)
    for a, b in zip(jax.tree_util.tree_leaves(h_plain.final_params),
                    jax.tree_util.tree_leaves(h_donated.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_donation_flag_is_bit_identical():
    """The trainer's ``donate`` knob (default on) must not change the
    training trajectory — same losses, same uplink counts."""
    from repro.configs import get
    from repro.train import trainer

    cfg = get("chb-paper-lm-124m").reduced()
    losses = {}
    for donate in (True, False):
        tc = trainer.TrainConfig(algorithm="chb", num_workers=2,
                                 global_batch=4, seq_len=16, steps=6,
                                 log_every=2, donate=donate)
        _, state, hist = trainer.train(cfg, tc, verbose=False)
        losses[donate] = ([rec["loss"] for rec in hist],
                          int(state.comm.total_uplinks))
    assert losses[True] == losses[False]


@pytest.mark.parametrize("quantize", [None, "int8"])
def test_fed_mesh_donation_is_bit_identical(bundle, quantize):
    """``run_mesh(donate=True)`` donates each shard's client bank into
    its round program; like the simulator knob it must be bit-neutral —
    including the copy guarding the post-quorum prev_params overwrite."""
    from repro.fed.mesh import MeshScenario, run_mesh
    o = opt.make("chb", bundle.alpha_paper, M, quantize=quantize)
    sc = MeshScenario(participation=0.75, loss_prob=0.2, quorum=0.6,
                      seed=9)
    plain = run_mesh(o, bundle.task, 12, scenario=sc)
    donated = run_mesh(o, bundle.task, 12, scenario=sc, donate=True)
    for f in ("objective", "mask", "quorum_met", "agg_grad_sqnorm",
              "attempted", "delivered"):
        np.testing.assert_array_equal(getattr(plain, f), getattr(donated, f),
                                      err_msg=f)
    for a, b in zip(jax.tree_util.tree_leaves(plain.final_params),
                    jax.tree_util.tree_leaves(donated.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

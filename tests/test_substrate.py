"""Substrate coverage: checkpointing, data pipeline, sharding rules,
HLO analyzer, accounting."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core.accounting import CommStats
from repro.data import lm_data
from repro.launch import hlo_analysis as ha
from repro.launch import sharding as shr


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.asarray(2.5, jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, tree, metadata={"step": 7})
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = ckpt.restore(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert ckpt.load_metadata(path)["step"] == 7


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    path = os.path.join(tmp_path, "ck2")
    ckpt.save(path, {"a": jnp.ones((3,))})
    with pytest.raises(AssertionError):
        ckpt.restore(path, {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})


# ------------------------------------------------------------------- data
def test_markov_lm_is_learnable_and_deterministic():
    lm1 = lm_data.MarkovLM(vocab_size=64, branch=4, seed=3)
    lm2 = lm_data.MarkovLM(vocab_size=64, branch=4, seed=3)
    np.testing.assert_array_equal(lm1.next_tokens, lm2.next_tokens)
    rng = np.random.default_rng(0)
    toks = lm1.sample(rng, 8, 100)
    # every transition must be one of the 4 successors of the previous state
    for b in range(8):
        for t in range(100):
            assert toks[b, t + 1] in lm1.next_tokens[toks[b, t]]
    assert lm1.entropy_floor() == pytest.approx(np.log(4))


def test_batch_iterator_worker_chunking():
    from repro.configs import get
    cfg = get("chb-paper-lm-124m").reduced()
    it = lm_data.batch_iterator(cfg, global_batch=8, seq_len=16,
                                num_workers=4)
    b = next(it)
    assert b["tokens"].shape == (4, 2, 16)
    assert b["labels"].shape == (4, 2, 16)
    # labels are next-token shifted
    flat_t = np.asarray(b["tokens"]).reshape(8, 16)
    flat_l = np.asarray(b["labels"]).reshape(8, 16)
    np.testing.assert_array_equal(flat_t[:, 1:], flat_l[:, :-1])


# --------------------------------------------------------- sharding rules
class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_spec_rules():
    m = _FakeMesh()
    # 2D weight: fsdp x tp
    assert tuple(shr.param_spec("['blocks']['l0']['mixer']['wq']",
                                (1, 4096, 8192), m)) == \
        (None, "data", "model")
    # norm: replicated
    assert tuple(shr.param_spec("['blocks']['l0']['norm1']['scale']",
                                (1, 4096), m)) == (None, None)
    # non-divisible dims fall back to None
    spec = shr.param_spec("['embed']", (50280, 1536), m)
    assert tuple(spec) == (None, "model")
    # gather-safe embeddings: single-axis only
    spec = shr.param_spec("['embed']", (151936, 4096), m, gather_safe=True)
    assert tuple(spec) == (None, "model")
    spec = shr.param_spec("['embed']", (151936, 4096), m)
    assert tuple(spec) == ("data", "model")


# ------------------------------------------------------------ hlo analyzer
def test_hlo_analyzer_scan_trip_counts():
    W = jnp.ones((32, 32))
    x = jnp.ones((4, 32))

    def scanned(x, Ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, Ws)[0]

    Ws = jnp.stack([W] * 5)
    txt = jax.jit(scanned).lower(x, Ws).compile().as_text()
    r = ha.analyze(txt)
    assert r["flops"] == 5 * 2 * 4 * 32 * 32
    assert r["collective_bytes"] == 0


def test_hlo_analyzer_grad_through_scan():
    W = jnp.ones((16, 16))
    x = jnp.ones((2, 16))

    def loss(x, Ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jnp.sum(jax.lax.scan(body, x, Ws)[0] ** 2)

    Ws = jnp.stack([W] * 3)
    txt = jax.jit(jax.grad(loss, argnums=(0, 1))).lower(x, Ws)\
        .compile().as_text()
    r = ha.analyze(txt)
    # fwd (3 dots) + bwd (2 dots per step: dh and dW)
    assert r["flops"] == 9 * 2 * 2 * 16 * 16


def test_shape_bytes_parse():
    assert ha.shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert ha.shape_bytes("(f32[4]{0}, s32[2,2]{1,0})") == 16 + 16
    assert ha.shape_bytes("pred[]") == 1


# ------------------------------------------------------------- accounting
def test_comm_stats_savings():
    s = CommStats.init(4)
    for _ in range(10):
        s = s.update(jnp.asarray([1.0, 0.0, 0.0, 0.0]), payload_bytes=100)
    assert int(s.total_uplinks) == 10
    assert float(s.savings_vs_dense()) == pytest.approx(0.75)
    assert float(s.uplink_bytes) == pytest.approx(1000.0)
    assert int(s.downlink_count) == 10

"""End-to-end trainer integration: CHB training loop + checkpoint round-trip
+ algorithm switching, on the reduced paper LM (CPU)."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get
from repro.train.trainer import TrainConfig, make_fed_config, train


@pytest.fixture(scope="module")
def cfg():
    return get("chb-paper-lm-124m").reduced()


def test_train_loop_loss_decreases(cfg):
    tc = TrainConfig(algorithm="chb", num_workers=2, alpha=0.05,
                     global_batch=8, seq_len=64, steps=40, log_every=39)
    params, state, hist = train(cfg, tc, verbose=False)
    assert hist[0]["loss"] > hist[-1]["loss"], hist
    assert int(state.comm.iterations) == 40


def test_trainer_checkpoint_roundtrip(cfg, tmp_path):
    tc = TrainConfig(algorithm="hb", num_workers=2, alpha=0.05,
                     global_batch=4, seq_len=32, steps=11, log_every=10,
                     ckpt_every=10, ckpt_path=os.path.join(tmp_path, "run"))
    params, state, hist = train(cfg, tc, verbose=False)
    path = os.path.join(tmp_path, "run_step10")
    like = jax.eval_shape(lambda: {"params": params})
    restored = ckpt.restore(path, like)["params"]
    assert (jax.tree_util.tree_structure(restored)
            == jax.tree_util.tree_structure(params))
    meta = ckpt.load_metadata(path)
    assert meta["step"] == 10 and meta["arch"] == cfg.name
    # restored params are usable: one more loss evaluation is finite
    from repro.data import lm_data
    from repro.models import model
    batch = next(lm_data.batch_iterator(cfg, global_batch=2, seq_len=32))
    loss, _ = model.train_loss(restored, cfg, batch, remat="none")
    assert np.isfinite(float(loss))


def test_algorithm_selection(cfg):
    """gd/hb/lag/chb all produce the right FedOptConfig shape."""
    for algo, beta_pos, eps_pos in [("gd", False, False), ("hb", True, False),
                                    ("lag", False, True), ("chb", True, True)]:
        tc = TrainConfig(algorithm=algo, num_workers=3, alpha=0.01)
        f = make_fed_config(tc)
        assert (f.beta > 0) == beta_pos, algo
        assert (f.eps1 > 0) == eps_pos, algo
        assert f.num_workers == 3

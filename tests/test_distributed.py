"""Distributed CHB strategies, run in subprocesses with 8 fake devices
(so the main pytest process keeps its single-device view)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return json.loads(r.stdout.splitlines()[-1])


COMMON = textwrap.dedent("""
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get
    from repro.core import chb, distributed
    from repro.core.chb import FedOptConfig
    from repro.launch import sharding as shr
    from repro.launch import mesh as mk
    from repro.models import model
    from repro.data import lm_data

    cfg = get("chb-paper-lm-124m").reduced()
    fcfg = FedOptConfig(alpha=0.02, beta=0.4, eps1=2.0, num_workers=2)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    def loss_fn(p, b):
        return model.train_loss(p, cfg, b, remat="none")[0]
    lm = lm_data.MarkovLM(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    raw = [lm.sample(rng, 8, 32) for _ in range(4)]
    batches = [{"tokens": jnp.asarray(r[:, :-1]),
                "labels": jnp.asarray(r[:, 1:])} for r in raw]
""")


def test_scan_strategy_matches_single_device_reference():
    """jit-sharded scan strategy on an 8-device mesh must equal the
    unsharded single-device run bit-for-bit in structure and closely in
    value."""
    code = COMMON + textwrap.dedent("""
        # reference: no mesh
        ref_state = distributed.init_scan_state(fcfg, params)
        ref_step = jax.jit(distributed.make_scan_step(fcfg, loss_fn))
        rp, rs = params, ref_state
        ref_losses, ref_tx = [], []
        for b in batches:
            wb = {k: v.reshape(2, 4, -1) for k, v in b.items()}
            rp, rs, m = ref_step(rp, rs, wb)
            ref_losses.append(float(m["loss"])); ref_tx.append(float(m["transmitted"]))

        # sharded: (4,2) mesh
        mesh = mk.make_auto_mesh((4,2), ("data","model"))
        sh = shr.params_shardings(jax.eval_shape(lambda: params), mesh)
        p2 = jax.tree_util.tree_map(jax.device_put, params, sh)
        st2 = distributed.init_scan_state(fcfg, p2)
        step2 = jax.jit(distributed.make_scan_step(fcfg, loss_fn))
        losses, txs = [], []
        with mesh:
            for b in batches:
                wb = {k: jax.device_put(v.reshape(2, 4, -1),
                                        NamedSharding(mesh, P(None, "data")))
                      for k, v in b.items()}
                p2, st2, m = step2(p2, st2, wb)
                losses.append(float(m["loss"])); txs.append(float(m["transmitted"]))
        print(json.dumps({"ref_losses": ref_losses, "losses": losses,
                          "ref_tx": ref_tx, "tx": txs}))
    """)
    out = run_sub(code)
    import numpy as np
    np.testing.assert_allclose(out["losses"], out["ref_losses"],
                               rtol=2e-4, atol=2e-4)
    assert out["tx"] == out["ref_tx"]


def test_pod_strategy_matches_scan_strategy():
    """Pod strategy (shard_map manual over pod, workers=pods) must agree
    with the scan strategy (workers=batch groups) given identical data
    splits, on a (2,2,2) mesh."""
    import jax as _jax
    if not hasattr(_jax, "shard_map"):
        pytest.skip("partial-manual shard_map (auto=...) trips an XLA "
                    "SPMD-partitioner CHECK on jax 0.4.x; pod strategy "
                    "needs the top-level jax.shard_map API")
    code = COMMON + textwrap.dedent("""
        mesh = mk.make_auto_mesh((2,2,2), ("pod","data","model"))
        shp = shr.params_shardings(jax.eval_shape(lambda: params), mesh,
                                   fsdp_axes=("data",), gather_safe=True)
        # scan strategy reference (workers = 2 groups, same split as pods)
        p1 = jax.tree_util.tree_map(jax.device_put, params, shp)
        st1 = distributed.init_scan_state(fcfg, p1)
        step1 = jax.jit(distributed.make_scan_step(fcfg, loss_fn))
        # pod strategy
        p2 = jax.tree_util.tree_map(jax.device_put, params, shp)
        st2 = distributed.init_pod_state(fcfg, p2, mesh)
        step2 = jax.jit(distributed.make_pod_step(fcfg, loss_fn, mesh))
        l1s, l2s, t1s, t2s = [], [], [], []
        with mesh:
            for b in batches:
                wb = {k: v.reshape(2, 4, -1) for k, v in b.items()}
                p1, st1, m1 = step1(p1, st1, wb)
                fb = {k: jax.device_put(v, NamedSharding(mesh, P(("pod","data"))))
                      for k, v in b.items()}
                p2, st2, m2 = step2(p2, st2, fb)
                l1s.append(float(m1["loss"])); l2s.append(float(m2["loss"]))
                t1s.append(float(m1["transmitted"])); t2s.append(float(m2["transmitted"]))
        d = max(abs(a-b) for a, b in zip(l1s, l2s))
        print(json.dumps({"l1": l1s, "l2": l2s, "t1": t1s, "t2": t2s,
                          "maxdiff": d}))
    """)
    out = run_sub(code)
    assert out["maxdiff"] < 3e-3, out
    assert out["t1"] == out["t2"]


def test_quantized_scan_strategy_runs():
    code = COMMON + textwrap.dedent("""
        import dataclasses
        fq = dataclasses.replace(fcfg, quantize="int8")
        st = distributed.init_scan_state(fq, params)
        step = jax.jit(distributed.make_scan_step(fq, loss_fn))
        p = params
        losses = []
        for b in batches:
            wb = {k: v.reshape(2, 4, -1) for k, v in b.items()}
            p, st, m = step(p, st, wb)
            losses.append(float(m["loss"]))
        ok = all(np.isfinite(losses))
        print(json.dumps({"ok": bool(ok), "losses": losses,
            "bytes": float(st.comm.uplink_bytes)}))
    """)
    out = run_sub(code)
    assert out["ok"] and out["bytes"] > 0

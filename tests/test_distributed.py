"""Distributed CHB strategies, run in subprocesses with 8 fake devices
(so the main pytest process keeps its single-device view)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return json.loads(r.stdout.splitlines()[-1])


COMMON = textwrap.dedent("""
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get
    from repro.core import chb, distributed
    from repro.core.chb import FedOptConfig
    from repro.launch import sharding as shr
    from repro.launch import mesh as mk
    from repro.models import model
    from repro.data import lm_data

    cfg = get("chb-paper-lm-124m").reduced()
    fcfg = FedOptConfig(alpha=0.02, beta=0.4, eps1=2.0, num_workers=2)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    def loss_fn(p, b):
        return model.train_loss(p, cfg, b, remat="none")[0]
    lm = lm_data.MarkovLM(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    raw = [lm.sample(rng, 8, 32) for _ in range(4)]
    batches = [{"tokens": jnp.asarray(r[:, :-1]),
                "labels": jnp.asarray(r[:, 1:])} for r in raw]
""")


def test_scan_strategy_matches_single_device_reference():
    """jit-sharded scan strategy on an 8-device mesh must equal the
    unsharded single-device run bit-for-bit in structure and closely in
    value."""
    code = COMMON + textwrap.dedent("""
        # reference: no mesh
        ref_state = distributed.init_scan_state(fcfg, params)
        ref_step = jax.jit(distributed.make_scan_step(fcfg, loss_fn))
        rp, rs = params, ref_state
        ref_losses, ref_tx = [], []
        for b in batches:
            wb = {k: v.reshape(2, 4, -1) for k, v in b.items()}
            rp, rs, m = ref_step(rp, rs, wb)
            ref_losses.append(float(m["loss"])); ref_tx.append(float(m["transmitted"]))

        # sharded: (4,2) mesh
        mesh = mk.make_auto_mesh((4,2), ("data","model"))
        sh = shr.params_shardings(jax.eval_shape(lambda: params), mesh)
        p2 = jax.tree_util.tree_map(jax.device_put, params, sh)
        st2 = distributed.init_scan_state(fcfg, p2)
        step2 = jax.jit(distributed.make_scan_step(fcfg, loss_fn))
        losses, txs = [], []
        with mesh:
            for b in batches:
                wb = {k: jax.device_put(v.reshape(2, 4, -1),
                                        NamedSharding(mesh, P(None, "data")))
                      for k, v in b.items()}
                p2, st2, m = step2(p2, st2, wb)
                losses.append(float(m["loss"])); txs.append(float(m["transmitted"]))
        print(json.dumps({"ref_losses": ref_losses, "losses": losses,
                          "ref_tx": ref_tx, "tx": txs}))
    """)
    out = run_sub(code)
    import numpy as np
    np.testing.assert_allclose(out["losses"], out["ref_losses"],
                               rtol=2e-4, atol=2e-4)
    assert out["tx"] == out["ref_tx"]


def test_pod_strategy_matches_scan_strategy():
    """Pod strategy (shard_map manual over pod, workers=pods) must agree
    with the scan strategy (workers=batch groups) given identical data
    splits, on a (2,2,2) mesh."""
    import jax as _jax
    if not hasattr(_jax, "shard_map"):
        pytest.skip("partial-manual shard_map (auto=...) trips an XLA "
                    "SPMD-partitioner CHECK on jax 0.4.x; pod strategy "
                    "needs the top-level jax.shard_map API")
    code = COMMON + textwrap.dedent("""
        mesh = mk.make_auto_mesh((2,2,2), ("pod","data","model"))
        shp = shr.params_shardings(jax.eval_shape(lambda: params), mesh,
                                   fsdp_axes=("data",), gather_safe=True)
        # scan strategy reference (workers = 2 groups, same split as pods)
        p1 = jax.tree_util.tree_map(jax.device_put, params, shp)
        st1 = distributed.init_scan_state(fcfg, p1)
        step1 = jax.jit(distributed.make_scan_step(fcfg, loss_fn))
        # pod strategy
        p2 = jax.tree_util.tree_map(jax.device_put, params, shp)
        st2 = distributed.init_pod_state(fcfg, p2, mesh)
        step2 = jax.jit(distributed.make_pod_step(fcfg, loss_fn, mesh))
        l1s, l2s, t1s, t2s = [], [], [], []
        with mesh:
            for b in batches:
                wb = {k: v.reshape(2, 4, -1) for k, v in b.items()}
                p1, st1, m1 = step1(p1, st1, wb)
                fb = {k: jax.device_put(v, NamedSharding(mesh, P(("pod","data"))))
                      for k, v in b.items()}
                p2, st2, m2 = step2(p2, st2, fb)
                l1s.append(float(m1["loss"])); l2s.append(float(m2["loss"]))
                t1s.append(float(m1["transmitted"])); t2s.append(float(m2["transmitted"]))
        d = max(abs(a-b) for a, b in zip(l1s, l2s))
        print(json.dumps({"l1": l1s, "l2": l2s, "t1": t1s, "t2": t2s,
                          "maxdiff": d}))
    """)
    out = run_sub(code)
    assert out["maxdiff"] < 3e-3, out
    assert out["t1"] == out["t2"]


def test_quantized_scan_strategy_runs():
    code = COMMON + textwrap.dedent("""
        import dataclasses
        fq = dataclasses.replace(fcfg, quantize="int8")
        st = distributed.init_scan_state(fq, params)
        step = jax.jit(distributed.make_scan_step(fq, loss_fn))
        p = params
        losses = []
        for b in batches:
            wb = {k: v.reshape(2, 4, -1) for k, v in b.items()}
            p, st, m = step(p, st, wb)
            losses.append(float(m["loss"]))
        ok = all(np.isfinite(losses))
        print(json.dumps({"ok": bool(ok), "losses": losses,
            "bytes": float(st.comm.uplink_bytes)}))
    """)
    out = run_sub(code)
    assert out["ok"] and out["bytes"] > 0


# ===================================================== fed mesh runtime
FED_COMMON = textwrap.dedent("""
    import jax, json
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    import repro.opt as ropt
    from repro.core import simulator
    from repro.data import paper_tasks
    from repro.fed.mesh import run_mesh, MeshScenario
    from repro.launch.mesh import make_client_mesh

    bundle = paper_tasks.make_linear_regression(m=8, n_per=20, d=12, seed=1)
    task = bundle.task
    opt = ropt.make("chb", bundle.alpha_paper, num_workers=8)
""")


def test_fed_mesh_shard_count_invariance():
    """Anchor (b): K in {1, 2, 8} draws the same masks for every client
    (bit-equal), same counts/quorum decisions, and float trajectories
    within the K-way fold's reduction-order ulps."""
    code = FED_COMMON + textwrap.dedent("""
        sc = MeshScenario(participation=0.7, loss_prob=0.2, quorum=0.5,
                          seed=3)
        runs = {K: run_mesh(opt, task, 10, mesh=make_client_mesh(K),
                            scenario=sc) for K in (1, 2, 8)}
        base = runs[1]
        out = {}
        for K in (2, 8):
            mh = runs[K]
            p1 = np.concatenate([np.ravel(x) for x in
                                 jax.tree_util.tree_leaves(base.final_params)])
            pk = np.concatenate([np.ravel(x) for x in
                                 jax.tree_util.tree_leaves(mh.final_params)])
            out[str(K)] = {
                "masks_bitwise": bool(np.array_equal(base.mask, mh.mask)),
                "counts_eq": bool(
                    np.array_equal(base.participated, mh.participated)
                    and np.array_equal(base.attempted, mh.attempted)
                    and np.array_equal(base.delivered, mh.delivered)),
                "met_eq": bool(np.array_equal(base.quorum_met,
                                              mh.quorum_met)),
                "obj_maxrel": float(np.max(np.abs(
                    base.objective - mh.objective)
                    / np.abs(base.objective))),
                "params_maxdiff": float(np.max(np.abs(p1 - pk))),
            }
        print(json.dumps(out))
    """)
    out = run_sub(code)
    for k, rec in out.items():
        assert rec["masks_bitwise"], (k, rec)
        assert rec["counts_eq"] and rec["met_eq"], (k, rec)
        assert rec["obj_maxrel"] < 1e-12, (k, rec)
        assert rec["params_maxdiff"] < 1e-12, (k, rec)


def test_fed_mesh_sync_anchor_on_eight_shards():
    """Anchor (a) survives sharding: the ideal scenario over 8 shards
    keeps censor masks bit-equal to the single-program simulator, with
    objective/params drift bounded by the 8-way fold reorder."""
    code = FED_COMMON + textwrap.dedent("""
        hist = simulator.run(opt, task, 10)
        mh = run_mesh(opt, task, 10, mesh=make_client_mesh(8))
        print(json.dumps({
            "masks_bitwise": bool(np.array_equal(
                np.asarray(hist.mask).astype(np.int8), mh.mask)),
            "comm_eq": bool(np.array_equal(np.asarray(hist.comm_cum),
                                           mh.comm_cum)),
            "obj_maxrel": float(np.max(np.abs(
                np.asarray(hist.objective) - mh.objective)
                / np.abs(np.asarray(hist.objective)))),
        }))
    """)
    out = run_sub(code)
    assert out["masks_bitwise"] and out["comm_eq"], out
    assert out["obj_maxrel"] < 1e-13, out


def test_fed_mesh_donation_safe_across_shards():
    """donate=True at K=2 is bit-identical to donate=False — including
    the prev_params re-injection after the server's quorum select."""
    code = FED_COMMON + textwrap.dedent("""
        sc = MeshScenario(participation=0.8, loss_prob=0.3, quorum=0.6,
                          seed=5)
        mesh = make_client_mesh(2)
        a = run_mesh(opt, task, 12, mesh=mesh, scenario=sc)
        b = run_mesh(opt, task, 12, mesh=mesh, scenario=sc, donate=True)
        print(json.dumps({
            "obj_eq": bool(np.array_equal(a.objective, b.objective)),
            "mask_eq": bool(np.array_equal(a.mask, b.mask)),
            "met_eq": bool(np.array_equal(a.quorum_met, b.quorum_met)),
        }))
    """)
    out = run_sub(code)
    assert all(out.values()), out


def test_fed_mesh_indivisible_clients_raise():
    """M must divide the shard count — loud ValueError, not a silent
    ragged split."""
    code = FED_COMMON + textwrap.dedent("""
        try:
            run_mesh(opt, task, 2, mesh=make_client_mesh(3))
            print(json.dumps({"raised": False, "msg": ""}))
        except ValueError as e:
            print(json.dumps({"raised": True, "msg": str(e)[:120]}))
    """)
    out = run_sub(code)
    assert out["raised"] and "divis" in out["msg"], out


def test_fed_sweep_mesh_partition_is_bitwise():
    """Scenario-grid partitioning over the mesh is a pure partition:
    results are bit-identical to the unpartitioned sweep at K in
    {1, 2, 8}."""
    code = FED_COMMON + textwrap.dedent("""
        from repro.sweep.fed_sweep import run_fed_sweep, FedScenarioGrid
        grid = FedScenarioGrid(loss_prob=(0.0, 0.2),
                               participation=(1.0, 0.6),
                               quorum=(1.0, 0.5), seed=(0,))
        base = run_fed_sweep(opt, task, grid, 6)
        out = {}
        for K in (1, 2, 8):
            r = run_fed_sweep(opt, task, grid, 6, mesh=make_client_mesh(K))
            out[str(K)] = bool(
                np.array_equal(base.objective, r.objective)
                and np.array_equal(base.transmit_mask, r.transmit_mask)
                and np.array_equal(base.delivered_mask, r.delivered_mask)
                and np.array_equal(base.energy_cum, r.energy_cum))
        print(json.dumps(out))
    """)
    out = run_sub(code)
    assert all(out.values()), out


def test_hlo_report_ranks_client_fold_collective():
    """The quorum fold is the mesh runtime's ONE cross-shard collective;
    obs.hlo_report must surface its all-reduce as the top collective row."""
    code = textwrap.dedent("""
        import jax, json
        import jax.numpy as jnp
        from repro.core.distributed import make_client_fold
        from repro.launch.mesh import make_client_mesh
        from repro.launch.sharding import stack_shards
        from repro.obs import hlo_report

        mesh = make_client_mesh(8)
        fold = make_client_fold(mesh)
        pieces = [jax.device_put(jnp.ones((1, 64), jnp.float32), d)
                  for d in mesh.devices.flat]
        stacked = stack_shards([{"g": p} for p in pieces], mesh)
        text = hlo_report.compiled_text(jax.jit(fold), stacked)
        rep = hlo_report.report(text, top=5)
        kinds = [r["kind"] for r in rep["collectives"]]
        print(json.dumps({"kinds": kinds,
                          "total": rep["totals"]["collectives"]}))
    """)
    out = run_sub(code)
    assert "all-reduce" in out["kinds"], out

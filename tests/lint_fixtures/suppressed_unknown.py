"""Suppression naming an unknown rule: a meta finding must fire."""


def compute(x):
    y = x + 1  # repro-lint: disable=no-such-rule -- typo'd rule name
    return y

"""mask-multiply-select must stay silent: the blessed forms."""
import jax.numpy as jnp


def pack(pending, scores, k_threshold):
    keep = (scores >= k_threshold).astype(jnp.float32)
    # fine: where-select keeps the sign of suppressed entries
    return jnp.where(keep != 0, pending, jnp.zeros_like(pending))


def advance(bank, mask, delta):
    # fine: additive blend (the eq.-5 bank advance), not a select
    return bank + mask * delta


def blend(mask, a, b):
    # fine: complementary blend — documented bit-alignment contract
    return mask * a + (1 - mask) * b


def cohort_and(participate, transmit):
    # fine: both operands are indicator masks — a boolean AND
    return participate * transmit


def scale(x, gain):
    # fine: plain math, nothing mask-like on either side
    return x * gain

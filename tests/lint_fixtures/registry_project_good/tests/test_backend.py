"""Fixture golden table: every transport kind has a fingerprint row."""

GOLDEN = {"dense": "deadbeef", "int8": "cafef00d"}

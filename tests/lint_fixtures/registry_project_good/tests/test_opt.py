"""Fixture spec pins: every censor/server kind appears by literal name."""

SPECS = [{"censor": "never"}, {"censor": "eq8"}, {"server": "gd"}]

"""Fixture pin file: parametrizes over every registered transport kind."""

KINDS = ["dense", "int8"]

"""Fixture registry: every registered kind is pinned in its pin files."""

CENSOR_KINDS: dict[str, type] = {
    "never": object,
    "eq8": object,
}
TRANSPORT_KINDS = {
    "dense": object,
    "int8": object,
}
SERVER_KINDS = {
    "gd": object,
}

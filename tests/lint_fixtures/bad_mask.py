"""mask-multiply-select must fire: bare multiply-selects (the PR 6 bug)."""
import jax.numpy as jnp


def pack(pending, scores, k_threshold):
    keep = (scores >= k_threshold).astype(jnp.float32)
    payload = keep * pending          # BAD: -0.0 entries lose their sign
    return payload


def route(delta, transmit):
    return delta * transmit           # BAD: same select, operands swapped

"""vmap-in-draw-exact must stay silent: compliant forms + unmarked code."""
import jax
import jax.numpy as jnp

from repro.lint import draw_exact


@draw_exact
def batched_step(one_point, points, bank):
    out = jax.lax.map(one_point, points)       # fine: bit-exact batching
    rows = [one_point(bank[i]) for i in range(3)]   # fine: explicit loop
    return out, rows


def unmarked_helper(one_point, points):
    # fine: no draw-exact contract here; vmap is allowed
    return jax.vmap(one_point)(points)


@draw_exact
def uses_unrelated_take(queue):
    # fine: a bare .take() on a non-jax object is not the gather family
    return queue.take()

"""interpret-not-routed must fire: hardwired interpreter mode (PR 4)."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double_pallas(x, interpret: bool = True):   # BAD: literal bool default
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,                    # BAD: unrouted passthrough
    )(x)

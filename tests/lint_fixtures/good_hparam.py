"""baked-traced-hparam must stay silent: the compliant forms."""
import functools

import jax

from repro.kernels import hb_update, ops


def dispatch(params, prev, agg, alpha, beta):
    # fine: only the backend switch is static; hparams stay traced operands
    step = jax.jit(ops.tree_hb_update_jit, static_argnames=("use_pallas",))
    return step(params, prev, agg, alpha, beta, use_pallas=True)


def build(nk):
    # fine: partial binds a shape-static tile count, not a sweepable hparam
    return functools.partial(hb_update, nk=nk)


def helper(alpha):
    # fine: binding alpha onto a non-kernel helper is not the bug class
    return functools.partial(print, alpha=alpha)

"""Fixture registry: the 'phantom' transport kind is not pinned anywhere."""

CENSOR_KINDS: dict[str, type] = {
    "never": object,
    "eq8": object,
}
TRANSPORT_KINDS = {
    "dense": object,
    "phantom": object,      # registered but absent from every pin file
}
SERVER_KINDS = {
    "gd": object,
}

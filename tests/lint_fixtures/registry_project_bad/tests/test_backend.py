"""Fixture golden table: fingerprints keyed by kind — 'phantom' missing."""

GOLDEN = {"dense": "deadbeef"}

"""Fixture pin file: parametrizes over 'dense' only — 'phantom' missing."""

KINDS = ["dense"]

"""Fixture spec pins for censor/server kinds."""

SPECS = [{"censor": "never"}, {"censor": "eq8"}, {"server": "gd"}]

"""disable-file: every finding of the named rule in this file is covered."""
# repro-lint: disable-file=mask-multiply-select -- fixture: file-wide waiver


def select(keep, pending):
    return keep * pending


def route(delta, transmit):
    return delta * transmit

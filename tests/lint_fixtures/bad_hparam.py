"""baked-traced-hparam must fire: both freezing forms of the PR 4 bug."""
import functools

import jax

from repro.kernels import hb_update


def dispatch(params, prev, agg, alpha, beta):
    # BAD: hyperparameters declared static — every grid point retraces
    step = jax.jit(hb_update, static_argnames=("alpha", "beta"))
    return step(params, prev, agg, alpha=alpha, beta=beta)


def build(alpha):
    # BAD: partial bakes alpha into the kernel entry point
    return functools.partial(hb_update, alpha=alpha)

"""Suppressions with reasons: every finding here must come back suppressed."""
import numpy as np


def select(keep, pending):
    payload = keep * pending  # repro-lint: disable=mask-multiply-select -- fixture: trailing-comment suppression
    return payload


def draw():
    # repro-lint: disable=unseeded-randomness -- fixture: standalone
    # suppression with a wrapped reason covering the next code line
    return np.random.rand(3)

"""parse-error must fire: this file deliberately does not parse."""


def broken(:
    return 1

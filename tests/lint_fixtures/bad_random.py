"""unseeded-randomness must fire: hidden global RNG state."""
import random

import numpy as np


def make_data(n):
    np.random.seed(0)                       # BAD: global numpy state
    x = np.random.randn(n, 4)               # BAD: legacy global draw
    rng = np.random.default_rng()           # BAD: OS-entropy seed
    jitter = random.random()                # BAD: stdlib global RNG
    return x, rng, jitter

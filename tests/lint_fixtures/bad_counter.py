"""float-byte-counter must fire: float-dtype byte state (the PR 1 bug)."""
import jax.numpy as jnp


class Meter:
    def __init__(self):
        # BAD: byte counter state created as float32 — flatlines past 2^24
        self.uplink_bytes = jnp.zeros((), jnp.float32)

    def record(self, payload_bytes):
        # BAD: accumulating bytes through a float cast
        self.uplink_bytes += payload_bytes.astype(float)


def tally(stats):
    total_bytes = jnp.asarray(0.0, jnp.float64)   # BAD: float byte cell
    for s in stats:
        total_bytes = total_bytes + s
    return total_bytes

"""float-byte-counter must stay silent: split-int32 state, float views."""
import jax.numpy as jnp

MIB = 1 << 20


class Meter:
    def __init__(self):
        # fine: exact split-int32 state (core/accounting.py idiom)
        self.mib = jnp.zeros((), jnp.int32)
        self.rem_bytes = jnp.zeros((), jnp.int32)

    def record(self, payload_bytes):
        rem = self.rem_bytes + jnp.asarray(payload_bytes, jnp.int32)
        self.mib = self.mib + rem // MIB
        self.rem_bytes = rem % MIB

    @property
    def uplink_bytes(self) -> float:
        # fine: a float *view* of exact integer state is a read, not state
        return float(self.mib) * MIB + float(self.rem_bytes)


def loss_ema(prev, new):
    # fine: float assignment whose target is not byte-named
    smoothed_loss = 0.9 * prev + 0.1 * jnp.asarray(new, jnp.float32)
    return smoothed_loss

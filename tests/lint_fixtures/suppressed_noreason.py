"""Reasonless suppression: the finding stays live + a meta finding fires."""


def select(keep, pending):
    payload = keep * pending  # repro-lint: disable=mask-multiply-select
    return payload

"""unseeded-randomness must stay silent: everything is keyed/seeded."""
import jax
import numpy as np


def make_data(n, seed):
    rng = np.random.default_rng(seed)       # fine: explicit seed
    x = rng.normal(size=(n, 4))             # fine: Generator method
    key = jax.random.PRNGKey(seed)          # fine: jax keys are explicit
    noise = jax.random.normal(key, (n,))
    shuffled = rng.permutation(n)           # fine: Generator, not global
    return x, noise, shuffled

"""vmap-in-draw-exact must fire: banned batching in marked scope."""
import jax
import jax.numpy as jnp

from repro.lint import draw_exact


@draw_exact
def batched_step(one_point, points, bank, idx):
    out = jax.vmap(one_point)(points)          # BAD: vmap drifts by ulps
    picked = jnp.take(bank, idx, axis=0)       # BAD: gather-style batching
    return out, picked

"""interpret-not-routed must stay silent: routed through common.py."""
import jax
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double_pallas(x, interpret: bool | None = None):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=resolve_interpret(interpret),   # fine: single source of truth
    )(x)

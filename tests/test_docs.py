"""Documentation health: intra-repo markdown links must resolve, and the
sweep-guide tutorial's code blocks must actually execute (doc-sync — the
tutorial can never rot). Run standalone by the CI docs job:

    PYTHONPATH=src python -m pytest -q tests/test_docs.py
"""
import re
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import pytest

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excludes images ![..](..) nothing special needed, and
# autolinks; external schemes and pure-anchor links are filtered below
_LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")
_CODE_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_EXTERNAL = ("http://", "https://", "mailto:")


def _markdown_files():
    skip_dirs = {".git", ".claude", "__pycache__", ".pytest_cache"}
    for p in sorted(REPO.rglob("*.md")):
        if not any(part in skip_dirs for part in p.parts):
            yield p


def test_markdown_files_exist():
    files = list(_markdown_files())
    names = {p.relative_to(REPO).as_posix() for p in files}
    for required in ("README.md", "docs/architecture.md",
                     "docs/paper_map.md", "docs/sweep_guide.md",
                     "docs/opt_api.md", "docs/kernels.md",
                     "docs/observability.md", "docs/transport_zoo.md",
                     "docs/lint.md", "docs/fed_scaling.md"):
        assert required in names, f"missing {required}"


@pytest.mark.parametrize("md", list(_markdown_files()),
                         ids=lambda p: p.relative_to(REPO).as_posix())
def test_intra_repo_links_resolve(md):
    text = md.read_text()
    broken = []
    for target in _LINK_RE.findall(text):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            broken.append(target)
    assert not broken, f"{md.name}: broken relative links {broken}"


def test_opt_api_code_executes():
    """Doc-sync: run every ```python block of docs/opt_api.md, in order,
    in one shared namespace — the add-your-own-algorithm tutorial (and the
    registry/spec claims around it) can never rot."""
    guide = (REPO / "docs" / "opt_api.md").read_text()
    blocks = _CODE_BLOCK_RE.findall(guide)
    assert len(blocks) >= 5, "tutorial structure changed: update this test"
    ns = {"__name__": "opt_api_doc"}
    # the tutorial registers an algorithm + censor kind; snapshot the
    # global registries so other tests stay order-independent
    from repro import opt
    from repro.opt import registry as opt_registry
    algos_before = dict(opt_registry._ALGORITHMS)
    censors_before = dict(opt.CENSOR_KINDS)
    try:
        for i, block in enumerate(blocks):
            try:
                exec(compile(block, f"opt_api.md[block {i}]", "exec"), ns)
            except Exception as e:     # pragma: no cover - failure reporting
                pytest.fail(f"opt_api.md code block {i} failed: {e!r}")
        # the tutorial's headline claims came out true
        assert "roundrobin" in opt.names()
        assert isinstance(ns["legacy"].build(), opt.ComposedOptimizer)
    finally:
        opt_registry._ALGORITHMS.clear()
        opt_registry._ALGORITHMS.update(algos_before)
        opt.CENSOR_KINDS.clear()
        opt.CENSOR_KINDS.update(censors_before)


def test_kernels_doc_code_executes():
    """Doc-sync: run every ```python block of docs/kernels.md, in order,
    in one shared namespace — the backend-selection, bit-exactness,
    no-retrace, and interpret-rule claims are asserted inside the doc."""
    guide = (REPO / "docs" / "kernels.md").read_text()
    blocks = _CODE_BLOCK_RE.findall(guide)
    assert len(blocks) >= 5, "kernel guide structure changed: update this"
    ns = {"__name__": "kernels_doc"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"kernels.md[block {i}]", "exec"), ns)
        except Exception as e:     # pragma: no cover - failure reporting
            pytest.fail(f"kernels.md code block {i} failed: {e!r}")
    # the doc's headline objects came out right
    assert ns["spec"]["backend"] == "pallas"
    assert ns["res"].num_programs == 1


def test_transport_zoo_doc_code_executes():
    """Doc-sync: run every ```python block of docs/transport_zoo.md, in
    order, in one shared namespace — the spec round-trip, EF telescoping,
    byte-accounting, warm-start, backend bit-identity, and sweep-survival
    contracts are asserted inside the doc itself."""
    guide = (REPO / "docs" / "transport_zoo.md").read_text()
    blocks = _CODE_BLOCK_RE.findall(guide)
    assert len(blocks) >= 6, "transport zoo guide changed: update this"
    ns = {"__name__": "transport_zoo_doc"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"transport_zoo.md[block {i}]", "exec"), ns)
        except Exception as e:     # pragma: no cover - failure reporting
            pytest.fail(f"transport_zoo.md code block {i} failed: {e!r}")
    # the doc's headline objects came out right
    assert ns["spec"]["transport"] == {"kind": "topk", "k": 8}
    assert int(ns["res"].uplink_bytes[1]) < int(ns["res"].uplink_bytes[0])


def test_observability_doc_code_executes():
    """Doc-sync: run every ```python block of docs/observability.md, in
    order, in one shared namespace — the read-only/bit-exactness, stage
    namespacing, zero-extra-compile, JSONL-schema, and BENCH-schema
    claims are asserted inside the doc itself."""
    guide = (REPO / "docs" / "observability.md").read_text()
    blocks = _CODE_BLOCK_RE.findall(guide)
    assert len(blocks) >= 7, "observability guide changed: update this"
    ns = {"__name__": "observability_doc"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"observability.md[block {i}]", "exec"), ns)
        except Exception as e:     # pragma: no cover - failure reporting
            pytest.fail(f"observability.md code block {i} failed: {e!r}")
    # the doc's headline objects came out right
    assert ns["ev"]["event"] == "round"
    assert "chb_step[reference]" in ns["hlo"]


def test_lint_doc_code_executes():
    """Doc-sync: run every ```python block of docs/lint.md, in order, in
    one shared namespace — the rule-catalog behavior, suppression policy
    (reason required, wrapped reasons join), draw-exact marker, and
    findings-artifact schema are asserted inside the doc itself."""
    guide = (REPO / "docs" / "lint.md").read_text()
    blocks = _CODE_BLOCK_RE.findall(guide)
    assert len(blocks) >= 6, "lint guide structure changed: update this"
    ns = {"__name__": "lint_doc"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"lint.md[block {i}]", "exec"), ns)
        except Exception as e:     # pragma: no cover - failure reporting
            pytest.fail(f"lint.md code block {i} failed: {e!r}")
    # the doc's headline objects came out right
    assert ns["artifact"]["counts"]["by_rule"] == {"vmap-in-draw-exact": 1}
    assert ns["fold_rows"].__draw_exact__ is True


def test_fed_scaling_doc_code_executes():
    """Doc-sync: run every ```python block of docs/fed_scaling.md, in
    order, in one shared namespace — the sync-anchor bitwise claim, the
    draw-replay claim, the quorum-gate replay, and the exact-bytes
    accounting are asserted inside the doc itself."""
    guide = (REPO / "docs" / "fed_scaling.md").read_text()
    blocks = _CODE_BLOCK_RE.findall(guide)
    assert len(blocks) >= 5, "fed scaling guide changed: update this test"
    ns = {"__name__": "fed_scaling_doc"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"fed_scaling.md[block {i}]", "exec"), ns)
        except Exception as e:     # pragma: no cover - failure reporting
            pytest.fail(f"fed_scaling.md code block {i} failed: {e!r}")
    # the doc's headline objects came out right
    assert ns["mh"].quorum_met.dtype == bool
    assert ns["payload"] > 0


def test_sweep_guide_code_executes():
    """Doc-sync: run every ```python block of docs/sweep_guide.md, in order,
    in one shared namespace — assertions inside the guide do the checking."""
    guide = (REPO / "docs" / "sweep_guide.md").read_text()
    blocks = _CODE_BLOCK_RE.findall(guide)
    assert len(blocks) >= 5, "tutorial structure changed: update this test"
    ns = {"__name__": "sweep_guide_doc"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"sweep_guide.md[block {i}]", "exec"), ns)
        except Exception as e:     # pragma: no cover - failure reporting
            pytest.fail(f"sweep_guide.md code block {i} failed: {e!r}")
    # the tutorial's headline objects came out the right shape
    assert ns["res"].num_programs == 2
    assert len(ns["frontier"]) == 10
    assert len(ns["fed_rows"]) == 4

"""MoE dispatch modes must agree: dense (oracle) == scan == grouped under
generous capacity; capacity dropping is bounded; property sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models import moe


def make_cfg(e=4, k=2, act="swiglu", d=64, dff=48):
    return ModelConfig(name="m", family="moe", num_layers=2, d_model=d,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=97, layer_pattern="A", num_experts=e,
                       num_experts_per_tok=k, d_ff_expert=dff,
                       scan_period=2, dtype="float32").validate()


@pytest.mark.parametrize("act", ["swiglu", "gelu", "squared_relu"])
@pytest.mark.parametrize("e,k", [(4, 2), (8, 1), (8, 3)])
def test_modes_agree_generous_capacity(act, e, k):
    cfg = make_cfg(e=e, k=k, act=act)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    yd, ad = moe.moe_dense(p, cfg, x)
    ys, as_ = moe.moe_scan(p, cfg, x, capacity_factor=float(e))
    yg, ag = moe.moe_grouped(p, cfg, x, capacity_factor=float(e))
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(as_), float(ad), rtol=1e-6)
    np.testing.assert_allclose(float(ag), float(ad), rtol=1e-6)


def test_grouped_gradients_match_dense():
    cfg = make_cfg()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (2, 16, 64))

    def loss(fn):
        return lambda pp: jnp.sum(fn(pp, cfg, x)[0] ** 2)

    gd = jax.grad(loss(lambda pp, c, xx: moe.moe_dense(pp, c, xx)))(p)
    gg = jax.grad(loss(lambda pp, c, xx: moe.moe_grouped(
        pp, c, xx, capacity_factor=4.0)))(p)
    for kk in gd:
        np.testing.assert_allclose(np.asarray(gd[kk]), np.asarray(gg[kk]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_single_token_routes_across_batch():
    cfg = make_cfg()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (8, 1, 64))
    yd, _ = moe.moe_dense(p, cfg, x)
    ys, _ = moe.moe_scan(p, cfg, x, capacity_factor=4.0)
    yg, _ = moe.moe_grouped(p, cfg, x, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yd),
                               rtol=1e-5, atol=1e-5)
    # batch=1, len=1 edge (long_500k decode regression)
    x1 = x[:1]
    y1, _ = moe.moe_scan(p, cfg, x1)
    assert y1.shape == (1, 1, 64)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), l=st.integers(4, 40))
def test_property_capacity_drop_is_bounded(seed, l):
    """With capacity factor 1.0, dropped tokens reduce the output but the
    kept contributions must exactly match a dense recomputation restricted
    to the kept (token, expert) pairs."""
    cfg = make_cfg()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(seed), (1, l, 64))
    y_scan, _ = moe.moe_scan(p, cfg, x, capacity_factor=1.0)
    y_dense, _ = moe.moe_dense(p, cfg, x)
    # dropping only ever removes expert contributions, so the scan output
    # must never exceed dense in L2 by more than numerical noise
    assert float(jnp.sum(y_scan ** 2)) <= float(jnp.sum(y_dense ** 2)) * 4 + 1e-3
    # and with generous capacity it matches exactly
    y_full, _ = moe.moe_scan(p, cfg, x, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-5)

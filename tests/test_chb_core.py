"""Unit + property tests for the CHB core (Algorithm 1 semantics and theory)."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st

from repro.core import baselines, chb, simulator
from repro.core.censoring import check_feasible, paper_eps1, theoretical_params
from repro.data import paper_tasks


@pytest.fixture(scope="module")
def linreg():
    return paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)


# --------------------------------------------------------------- reference
def reference_algorithm1(cfg, task, num_iters):
    """Literal, unvectorized Algorithm 1 for cross-checking the fast path."""
    theta = np.asarray(task.init_params, dtype=np.float64)
    theta_prev = theta.copy()
    M = cfg.num_workers
    ghat = [np.zeros_like(theta) for _ in range(M)]
    objs, comms, total = [], [], 0
    data = jax.tree_util.tree_map(np.asarray, task.worker_data)
    for _ in range(num_iters):
        objs.append(sum(float(task.loss_fn(jnp.asarray(theta),
                                           jax.tree_util.tree_map(lambda x, i=i: x[i], data)))
                        for i in range(M)))
        step_sq = float(np.sum((theta - theta_prev) ** 2))
        nabla = np.zeros_like(theta)
        for m in range(M):
            g = np.asarray(task.grad_fn(
                jnp.asarray(theta),
                jax.tree_util.tree_map(lambda x, m=m: x[m], data)))
            delta = g - ghat[m]
            if float(np.sum(delta ** 2)) > cfg.eps1 * step_sq:
                ghat[m] = g  # transmit
                total += 1
        nabla = sum(ghat)
        new_theta = theta - cfg.alpha * nabla + cfg.beta * (theta - theta_prev)
        theta_prev, theta = theta, new_theta
        comms.append(total)
    return np.array(objs), np.array(comms)


def test_matches_literal_algorithm1(linreg):
    cfg = baselines.chb(linreg.alpha_paper, 5)
    hist = simulator.run(cfg, linreg.task, 50)
    ref_obj, ref_comms = reference_algorithm1(cfg, linreg.task, 50)
    np.testing.assert_allclose(np.asarray(hist.objective), ref_obj,
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_array_equal(np.asarray(hist.comm_cum, int), ref_comms)


def test_chb_eps0_equals_hb(linreg):
    """eps1=0 must reduce CHB to classical HB exactly (paper Sec. II)."""
    a = linreg.alpha_paper
    h_chb = simulator.run(chb.FedOptConfig(alpha=a, beta=0.4, eps1=0.0,
                                           num_workers=5), linreg.task, 100)
    h_hb = simulator.run(baselines.hb(a, 5), linreg.task, 100)
    np.testing.assert_allclose(np.asarray(h_chb.objective),
                               np.asarray(h_hb.objective), rtol=0, atol=0)
    assert int(h_hb.comm_cum[-1]) == 5 * 100  # HB transmits every iteration


def test_hb_beta0_equals_gd(linreg):
    a = linreg.alpha_paper
    h1 = simulator.run(baselines.hb(a, 5, beta=0.0), linreg.task, 80)
    h2 = simulator.run(baselines.gd(a, 5), linreg.task, 80)
    np.testing.assert_allclose(np.asarray(h1.objective),
                               np.asarray(h2.objective), rtol=0, atol=0)


def test_lemma2_comm_bound():
    """Workers with L_m^2 <= eps1 transmit at most k/2 + 1 times (Lemma 2),
    checked over the active optimization phase."""
    # n_per=10, d=50 -> ill-conditioned (small mu), long active phase
    b = paper_tasks.make_linear_regression(m=9, n_per=10, d=50, seed=0)
    cfg = baselines.chb(b.alpha_paper, 9)
    hist = simulator.run(cfg, b.task, 200)
    # Lemma 2 presumes the optimization is active; once the f64 floor is hit
    # ||dtheta|| ~ 0 and rounding noise dominates the censor test (the paper's
    # Fig. 1 likewise shows the first 24 iterations only). Restrict to the
    # pre-floor window.
    fstar = simulator.estimate_fstar(b.task, b.alpha_paper, 30000)
    err = np.asarray(hist.objective) - float(fstar)
    active = err > 1e-9 * err[0]
    k = int(active.sum())
    assert k >= 40, "need a meaningful active phase"
    counts = np.asarray(hist.mask)[:k].sum(axis=0)
    eligible = b.L_m ** 2 <= cfg.eps1
    assert eligible.any(), "setup must include Lemma-2-eligible workers"
    for m in np.nonzero(eligible)[0]:
        assert counts[m] <= k / 2 + 1, (m, counts[m])


def test_half_communications_saved_when_all_eligible():
    """If L_m^2 <= eps1 for all m, at least half of all comms are censored."""
    b = paper_tasks.make_linear_regression(m=6, n_per=30, d=20,
                                           worker_L=[2.0] * 6, seed=3)
    eps1 = 5.0  # > max L_m^2 = 4
    cfg = chb.FedOptConfig(alpha=b.alpha_paper, beta=0.4, eps1=eps1,
                           num_workers=6)
    k = 150
    hist = simulator.run(cfg, b.task, k)
    total = int(hist.comm_cum[-1])
    assert total <= 6 * (k / 2 + 1)


def test_theorem1_linear_convergence():
    """With the Appendix-C parameter corner, the Lyapunov-implied bound
    f(theta^k) - f* <= (1-c)^k L(theta^0) holds."""
    b = paper_tasks.make_linear_regression(m=4, n_per=40, d=10,
                                           worker_L=[3.0] * 4, seed=1)
    # strong convexity constant of the quadratic objective
    X = np.asarray(b.task.worker_data[0])
    H = sum(X[i].T @ X[i] for i in range(4))
    mu = float(np.linalg.eigvalsh(H)[0])
    assert mu > 0
    p = theoretical_params(L=b.L, mu=mu, num_workers=4, delta=0.5)
    cfg = chb.FedOptConfig(alpha=p.alpha, beta=p.beta, eps1=p.eps1,
                           num_workers=4)
    hist = simulator.run(cfg, b.task, 400)
    fstar = simulator.estimate_fstar(b.task, b.alpha_paper, 30000)
    err = np.asarray(hist.objective) - float(fstar)
    L0 = err[0]  # theta^0 == theta^{-1} so Lyapunov == objective error
    ks = np.arange(400)
    bound = (1.0 - p.rate) ** ks * L0
    active = err > 1e-10  # above numerical floor
    assert np.all(err[active] <= bound[active] * (1.0 + 1e-6))


def test_monotone_lyapunov_descent():
    """Lemma 1: L(theta^{k+1}) <= L(theta^k); with theta^0 = theta^{-1}
    eta1-term telescopes, we check the objective-error part stays bounded
    by a monotone envelope."""
    b = paper_tasks.make_linear_regression(m=4, n_per=40, d=10,
                                           worker_L=[2.0] * 4, seed=2)
    X = np.asarray(b.task.worker_data[0])
    H = sum(X[i].T @ X[i] for i in range(4))
    mu = float(np.linalg.eigvalsh(H)[0])
    p = theoretical_params(L=b.L, mu=mu, num_workers=4, delta=0.5)
    cfg = chb.FedOptConfig(alpha=p.alpha, beta=p.beta, eps1=p.eps1,
                           num_workers=4)
    hist = simulator.run(cfg, b.task, 300)
    obj = np.asarray(hist.objective)
    # Lyapunov includes eta1||dtheta||^2 >= 0, so objective may wiggle but the
    # Lyapunov upper envelope of the objective must be non-increasing:
    env = np.maximum.accumulate(obj[::-1])[::-1]  # tail max
    assert env[0] == obj[0]  # first iterate is the worst


def test_feasibility_helpers():
    p = theoretical_params(L=10.0, mu=1.0, num_workers=8, delta=0.5)
    assert check_feasible(p.alpha, p.beta, p.eps1, L=10.0, num_workers=8)
    assert not check_feasible(1.0, 0.0, 0.0, L=10.0, num_workers=8)  # alpha>1/L
    assert paper_eps1(0.1, 10) == pytest.approx(0.1 / (0.01 * 100))


def test_accounting_consistency(linreg):
    cfg = baselines.chb(linreg.alpha_paper, 5)
    hist = simulator.run(cfg, linreg.task, 64)
    assert int(hist.comm_cum[-1]) == int(np.asarray(hist.mask).sum())
    st_ = hist.final_state
    assert int(st_.comm.iterations) == 64
    assert int(st_.comm.downlink_count) == 64
    np.testing.assert_array_equal(np.asarray(st_.comm.uplink_count),
                                  np.asarray(hist.mask).sum(axis=0))


def test_quantized_chb_converges(linreg):
    """int8 + error feedback: converges to ~quantization-limited accuracy
    with 4x fewer uplink bytes per transmission."""
    a = linreg.alpha_paper
    cfg_q = chb.FedOptConfig(alpha=a, beta=0.4,
                             eps1=paper_eps1(a, 5), num_workers=5,
                             quantize="int8")
    cfg_d = baselines.chb(a, 5)
    hq = simulator.run(cfg_q, linreg.task, 500)
    hd = simulator.run(cfg_d, linreg.task, 500)
    fstar = simulator.estimate_fstar(linreg.task, a, 20000)
    err_q = float(hq.objective[-1] - fstar)
    assert err_q < 1e-3 * float(hq.objective[0] - fstar)
    # bytes per transmission: 8 bytes/elem (f64) vs 1 byte/elem + scale
    bytes_q = float(hq.final_state.comm.uplink_bytes)
    n_tx_q = float(hq.final_state.comm.total_uplinks)
    bytes_d = float(hd.final_state.comm.uplink_bytes)
    n_tx_d = float(hd.final_state.comm.total_uplinks)
    assert bytes_q / n_tx_q < 0.25 * bytes_d / n_tx_d


# ------------------------------------------------------------- properties
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       m=st.integers(2, 6),
       beta=st.floats(0.0, 0.6),
       eps_scale=st.floats(0.0, 0.5))
def test_property_descent_on_quadratics(seed, m, beta, eps_scale):
    """For random quadratic tasks and paper-style constants, CHB must make
    progress: final objective error << initial, and comm count <= M*K."""
    b = paper_tasks.make_linear_regression(
        m=m, n_per=20, d=8, seed=seed,
        worker_L=[1.5 + (i % 3) for i in range(m)])
    a = b.alpha_paper
    eps1 = eps_scale / (a ** 2 * m ** 2)
    cfg = chb.FedOptConfig(alpha=a, beta=beta, eps1=eps1, num_workers=m)
    hist = simulator.run(cfg, b.task, 400)
    fstar = simulator.estimate_fstar(b.task, a, 20000)
    err0 = float(hist.objective[0] - fstar)
    errK = float(hist.objective[-1] - fstar)
    assert errK <= 1e-4 * err0 + 1e-12
    assert int(hist.comm_cum[-1]) <= m * 400
    assert int(hist.comm_cum[-1]) >= m  # first iteration transmits everywhere


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_censoring_never_blocks_first_iteration(seed):
    b = paper_tasks.make_linear_regression(m=3, n_per=10, d=5, seed=seed)
    cfg = baselines.chb(b.alpha_paper, 3)
    hist = simulator.run(cfg, b.task, 3)
    assert np.asarray(hist.mask)[0].sum() == 3  # theta^1==theta^0 -> all transmit


def test_adaptive_censoring_mode():
    """Beyond-paper EMA-relative censoring (FedOptConfig.adaptive):
    runs, censors, and converges for conservative thresholds — and we
    document its failure mode (geometric convergence starves the EMA test;
    see EXPERIMENTS.md P4c)."""
    b = paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)
    cfg = chb.FedOptConfig(alpha=b.alpha_paper, beta=0.4, num_workers=5,
                           adaptive=0.25)
    hist = simulator.run(cfg, b.task, 600)
    fstar = simulator.estimate_fstar(b.task, b.alpha_paper, 20000)
    err = float(hist.objective[-1] - fstar)
    assert err < 1e-6 * float(hist.objective[0] - fstar)
    assert int(hist.comm_cum[-1]) < 5 * 600  # some censoring happened
    # aggressive adaptive thresholds stall on deterministic problems —
    # the documented failure mode (transmits keep being censored because
    # each delta is smaller than its own EMA)
    cfg_bad = chb.FedOptConfig(alpha=b.alpha_paper, beta=0.4, num_workers=5,
                               adaptive=1.0)
    hist_bad = simulator.run(cfg_bad, b.task, 600)
    assert float(hist_bad.objective[-1] - fstar) > err  # strictly worse


def test_per_tensor_censoring():
    """Beyond-paper per-tensor granularity: identical to global censoring
    when theta is a single tensor; on a multi-tensor pytree it ships fewer
    bytes at equal-or-better progress (EXPERIMENTS.md P4d)."""
    import dataclasses
    b = paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)
    c1 = baselines.chb(b.alpha_paper, 5)
    c2 = dataclasses.replace(c1, granularity="per_tensor")
    h1 = simulator.run(c1, b.task, 150)
    h2 = simulator.run(c2, b.task, 150)
    np.testing.assert_allclose(np.asarray(h1.objective),
                               np.asarray(h2.objective), rtol=1e-10)

    bn = paper_tasks.make_neural_network(m=5, n_per=100, d=10)
    cg = baselines.chb(0.02, 5)
    cp = dataclasses.replace(cg, granularity="per_tensor")
    hg = simulator.run(cg, bn.task, 300)
    hp = simulator.run(cp, bn.task, 300)
    # robust invariants (byte ordering is horizon-dependent; see
    # EXPERIMENTS.md P4d): both censor, both make progress, and the
    # per-tensor bytes stay within 2x of global
    assert float(hp.final_state.comm.uplink_bytes) < \
        2 * float(hg.final_state.comm.uplink_bytes)
    assert float(hp.agg_grad_sqnorm[-1]) < float(hp.agg_grad_sqnorm[0])
    dense = 5 * 300 * 4  # workers * iters * tensors
    assert float(np.asarray(hp.mask).sum()) < dense

"""Edge cases of core/simulator.py accuracy helpers and the precision-safe
byte accounting in core/accounting.py."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, simulator
from repro.core.accounting import MIB, CommStats
from repro.data import paper_tasks


def _history(objective, comm_cum):
    """Minimal History with only the fields the helpers read."""
    k = len(objective)
    return simulator.History(
        objective=jnp.asarray(objective, jnp.float64),
        comm_cum=jnp.asarray(comm_cum, jnp.int64),
        mask=jnp.zeros((k, 1)),
        agg_grad_sqnorm=jnp.zeros((k,)),
        final_params=None,
        final_state=None,
    )


def test_iterations_to_accuracy_first_hit():
    # err = obj - fstar = [5, 3, 0.5, 0.05, 0.2]: first < 0.1 is index 3
    h = _history([5.0, 3.0, 0.5, 0.05, 0.2], [1, 3, 5, 6, 9])
    assert simulator.iterations_to_accuracy(h, fstar=0.0, tol=0.1) == 3
    assert simulator.comms_to_accuracy(h, fstar=0.0, tol=0.1) == 6
    # the non-monotone tail must not shift the first-hit index
    assert simulator.iterations_to_accuracy(h, fstar=0.0, tol=0.3) == 3


def test_iterations_to_accuracy_hit_at_zero():
    h = _history([0.01, 0.5, 0.001], [0, 2, 4])
    assert simulator.iterations_to_accuracy(h, fstar=0.0, tol=0.1) == 0
    assert simulator.comms_to_accuracy(h, fstar=0.0, tol=0.1) == 0


def test_tolerance_never_reached_returns_minus_one():
    h = _history([5.0, 4.0, 3.0], [1, 2, 3])
    assert simulator.iterations_to_accuracy(h, fstar=0.0, tol=1e-9) == -1
    assert simulator.comms_to_accuracy(h, fstar=0.0, tol=1e-9) == -1


def test_strict_inequality_at_threshold():
    """The helpers use err < tol (strict), mirroring the paper's targets."""
    h = _history([1.0, 0.1, 0.0999], [1, 2, 3])
    assert simulator.iterations_to_accuracy(h, fstar=0.0, tol=0.1) == 2


def test_helpers_on_real_run():
    b = paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)
    cfg = baselines.chb(b.alpha_paper, 5)
    hist = simulator.run(cfg, b.task, 400)
    fstar = float(simulator.estimate_fstar(b.task, b.alpha_paper, 20000))
    k = simulator.iterations_to_accuracy(hist, fstar, 1e-6)
    assert k > 0
    assert float(hist.objective[k]) - fstar < 1e-6
    assert float(hist.objective[k - 1]) - fstar >= 1e-6
    assert simulator.comms_to_accuracy(hist, fstar, 1e-6) == \
        int(hist.comm_cum[k])


# ------------------------------------------------- precision-safe byte counts
def test_comm_stats_bytes_exact_past_f32_cliff():
    """Accumulating small payloads far past 2^24 bytes must stay exact —
    the old float32 cell silently stopped registering increments there."""
    s = CommStats.init(1)
    payload = 65_537                       # odd size: exercises the carry
    n = 400
    for _ in range(n):
        s = s.update(jnp.asarray([1.0]), payload_bytes=payload)
    assert s.uplink_bytes_exact() == n * payload
    assert n * payload > (1 << 24)         # the regime the fix targets
    assert int(s.uplink_rem) < MIB


def test_comm_stats_update_counts():
    s = CommStats.init(4)
    for _ in range(10):
        s = s.update(jnp.asarray([1.0, 0.0, 1.0, 0.0]), payload_bytes=100)
    assert int(s.total_uplinks) == 20
    assert s.uplink_bytes_exact() == 2000
    assert float(s.uplink_bytes) == pytest.approx(2000.0)
    np.testing.assert_array_equal(np.asarray(s.uplink_count), [10, 0, 10, 0])


def test_comm_stats_inside_scan_carry():
    """The split counters must be dtype-stable through lax.scan."""
    s0 = CommStats.init(2)

    def body(s, _):
        return s.update(jnp.asarray([1.0, 1.0]), payload_bytes=3 * MIB + 7), None

    s, _ = jax.lax.scan(body, s0, None, length=50)
    assert s.uplink_bytes_exact() == 50 * 2 * (3 * MIB + 7)

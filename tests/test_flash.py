"""Flash attention vs naive oracle: values + gradients, GQA/causal/window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention, reference_attention


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
def test_flash_matches_reference(h, kh, causal, window):
    key = jax.random.PRNGKey(0)
    b, lq, s, d = 2, 128, 128, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, lq, d), jnp.float32)
    k = jax.random.normal(kk, (b, kh, s, d), jnp.float32)
    v = jax.random.normal(kv, (b, kh, s, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=32, kv_block=32)
    ref = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_gradients_match():
    key = jax.random.PRNGKey(1)
    b, h, kh, l, d = 1, 4, 2, 64, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, l, d))
    k = jax.random.normal(kk, (b, kh, l, d))
    v = jax.random.normal(kv, (b, kh, l, d))

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, q_block=16,
                                       kv_block=16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(reference_attention(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_flash_q_offset_decode_chunk():
    """q_offset makes the causal mask absolute (used by chunked prefill)."""
    key = jax.random.PRNGKey(2)
    b, h, l, d = 1, 2, 64, 16
    q = jax.random.normal(key, (b, h, l, d))
    k = jax.random.normal(key, (b, h, l, d))
    v = jax.random.normal(key, (b, h, l, d))
    full = reference_attention(q, k, v, causal=True)
    lower = flash_attention(q[:, :, 32:], k, v, causal=True, q_offset=32,
                            q_block=16, kv_block=16)
    np.testing.assert_allclose(lower, full[:, :, 32:], rtol=2e-5, atol=2e-5)

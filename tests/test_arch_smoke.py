"""Per-architecture smoke tests: a REDUCED variant of each assigned config
(2 layers, d_model<=512, <=4 experts) runs one forward/train step and one
decode step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import kvcache, model


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch, rng):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = model.init_params(rng, cfg)
    b, l = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, l), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend:
        batch["enc_embeddings"] = 0.3 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.num_frontend_tokens,
                                    cfg.d_frontend), cfg.jnp_dtype)

    loss, metrics = jax.jit(
        lambda p, bt: model.train_loss(p, cfg, bt, remat="none"))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # one gradient step must be finite as well
    g = jax.jit(jax.grad(
        lambda p: model.train_loss(p, cfg, batch, remat="full")[0]))(params)
    sq = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
             for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(sq) and sq > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step_smoke(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = model.init_params(rng, cfg)
    b, cache_len = 2, 96
    cache = kvcache.init_cache(cfg, b, cache_len)
    tok = jax.random.randint(jax.random.PRNGKey(3), (b, 1), 0, cfg.vocab_size)
    pos = jnp.asarray(17)
    logits, new_cache = jax.jit(
        lambda p, c, t: model.serve_step(p, cfg, c, t, pos))(params, cache, tok)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    # cache structure preserved
    assert (jax.tree_util.tree_structure(new_cache)
            == jax.tree_util.tree_structure(cache))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_then_decode_consistency(arch, rng):
    """Decode after prefill == one-shot forward on the extended sequence."""
    cfg = ARCHS[arch].reduced()
    params = model.init_params(rng, cfg)
    b, l = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, l), 0,
                              cfg.vocab_size)
    kwargs = {}
    if cfg.frontend:
        kwargs["enc_embeddings"] = 0.3 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.num_frontend_tokens,
                                    cfg.d_frontend), cfg.jnp_dtype)
    prefix = cfg.num_frontend_tokens if cfg.frontend == "audio" else 0
    _, cache = model.prefill(params, cfg, toks, cache_len=prefix + l + 4,
                             moe_mode="dense", **kwargs)
    nt = jax.random.randint(jax.random.PRNGKey(5), (b, 1), 0, cfg.vocab_size)
    logits, _ = model.serve_step(params, cfg, cache, nt,
                                 jnp.asarray(prefix + l), moe_mode="dense")
    ext = jnp.concatenate([toks, nt], axis=1)
    x, _ = model.forward(params, cfg, ext, remat="none", moe_mode="dense",
                         **kwargs)
    ref = x[:, -1, :] @ model._lm_head(params, cfg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_all_archs_registered():
    assert len(ASSIGNED) == 10
    fams = {ARCHS[a].family for a in ASSIGNED}
    assert fams == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_exact_assigned_specs():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936, 128, 8),
        "gemma3-12b": (48, 3840, 16, 8, 262144, 0, 0),
        "musicgen-medium": (48, 1536, 24, 24, 2048, 0, 0),
        "mixtral-8x22b": (56, 6144, 48, 8, 32768, 8, 2),
        "mamba2-780m": (48, 1536, 1, 1, 50280, 0, 0),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 128256, 0, 0),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 65536, 16, 2),
        "qwen3-4b": (36, 2560, 32, 8, 151936, 0, 0),
        "phi3-medium-14b": (40, 5120, 40, 10, 100352, 0, 0),
        "nemotron-4-15b": (32, 6144, 48, 8, 256000, 0, 0),
    }
    for a, (nl, dm, h, kv, v, e, k) in spec.items():
        c = ARCHS[a]
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.vocab_size, c.num_experts, c.num_experts_per_tok) == \
            (nl, dm, h, kv, v, e, k), a


def test_param_counts_match_nameplates():
    from repro.models.model import active_param_count, param_count
    expect = {  # (total B, active B, rel tol)
        "qwen3-moe-235b-a22b": (235, 22, 0.05),
        "mixtral-8x22b": (141, 39, 0.05),
        "jamba-1.5-large-398b": (398, 94, 0.05),
        "llama-3.2-vision-90b": (90, 90, 0.06),
        "mamba2-780m": (0.78, 0.78, 0.05),
    }
    for a, (tot, act, tol) in expect.items():
        cfg = ARCHS[a]
        pc = param_count(cfg) / 1e9
        ac = active_param_count(cfg) / 1e9
        assert abs(pc - tot) / tot < tol, (a, pc)
        assert abs(ac - act) / act < tol, (a, ac)

"""Event-driven edge runtime (repro.fed): sync-mode equivalence anchor,
stale-bank semantics under loss/stragglers, and energy/latency accounting."""
import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import fed
from repro.core import baselines, simulator
from repro.core.quantize import payload_bytes_dense
from repro.data import paper_tasks


@pytest.fixture(scope="module")
def linreg():
    return paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)


# ------------------------------------------------- sync-mode correctness anchor
@pytest.mark.parametrize("algo", ["gd", "hb", "lag", "chb"])
def test_sync_mode_reproduces_simulator(linreg, algo):
    """Zero latency + lossless + full participation + full quorum must be
    numerically identical to core/simulator.run — objective AND cumulative
    uplink trajectories."""
    cfg = baselines.ALGORITHMS[algo](linreg.alpha_paper, 5)
    ref = simulator.run(cfg, linreg.task, 60)
    hist = fed.run_edge(cfg, linreg.task, fed.sync_config(5), 60)
    np.testing.assert_allclose(hist.objective, np.asarray(ref.objective),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_array_equal(hist.comm_cum, np.asarray(ref.comm_cum))
    np.testing.assert_array_equal(hist.mask,
                                  np.asarray(ref.mask).astype(np.int8))


def test_sync_mode_reproduces_simulator_int8(linreg):
    """The quantized (per-worker-scale) path is part of the anchor too."""
    cfg = dataclasses.replace(baselines.chb(linreg.alpha_paper, 5),
                              quantize="int8")
    ref = simulator.run(cfg, linreg.task, 60)
    hist = fed.run_edge(cfg, linreg.task, fed.sync_config(5), 60)
    np.testing.assert_allclose(hist.objective, np.asarray(ref.objective),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_array_equal(hist.comm_cum, np.asarray(ref.comm_cum))


def test_sync_mode_nn_task():
    """Anchor holds on the paper's nonconvex pytree-parameter task."""
    b = paper_tasks.make_neural_network(m=4, n_per=40, d=8, hidden=6)
    cfg = baselines.chb(0.02, 4)
    ref = simulator.run(cfg, b.task, 25)
    hist = fed.run_edge(cfg, b.task, fed.sync_config(4), 25)
    np.testing.assert_allclose(hist.objective, np.asarray(ref.objective),
                               rtol=1e-8)
    np.testing.assert_array_equal(hist.comm_cum, np.asarray(ref.comm_cum))


# ----------------------------------------------------------- channel semantics
def test_dropped_uplinks_leave_bank_untouched(linreg):
    """With ~certain loss, no delta ever folds: the stale bank stays zero,
    GD makes no progress, yet air time and energy are still charged."""
    edge = fed.EdgeConfig(
        population=fed.uniform_population(5),
        channel=fed.ChannelConfig(kind="bernoulli", loss_prob=0.999999,
                                  uplink_rate_bps=1e6),
        seed=0)
    cfg = baselines.gd(linreg.alpha_paper, 5)
    hist = fed.run_edge(cfg, linreg.task, edge, 8)
    bank_norm = sum(float(jnp.abs(x).sum())
                    for x in jax.tree_util.tree_leaves(hist.final_bank))
    assert bank_norm == 0.0
    assert np.allclose(hist.objective, hist.objective[0])
    assert hist.mask.sum() == 0
    d = hist.stats.as_dict()
    assert d["dropped"] == d["uplinks"] > 0
    assert d["energy_j"] > 0 and d["tx_s"] > 0


def test_moderate_loss_still_converges(linreg):
    """Bernoulli loss slows but does not break CHB (bank stays consistent)."""
    edge = fed.EdgeConfig(population=fed.uniform_population(5),
                          channel=fed.ChannelConfig.lossy(0.3),
                          seed=1)
    cfg = baselines.chb(linreg.alpha_paper, 5)
    hist = fed.run_edge(cfg, linreg.task, edge, 200)
    fstar = float(simulator.estimate_fstar(linreg.task, linreg.alpha_paper,
                                           20000))
    assert hist.objective[-1] - fstar < 1e-6 * (hist.objective[0] - fstar)
    d = hist.stats.as_dict()
    assert d["dropped"] > 0 and d["delivered"] > 0


def test_channel_models():
    rng = np.random.default_rng(0)
    ch = fed.ChannelConfig(uplink_rate_bps=1e6, overhead_s=0.01)
    tx = ch.uplink(125_000, rng)      # 1 Mbit at 1 Mbps
    assert tx.delivered and tx.time_s == pytest.approx(1.01)
    assert ch.downlink_time(0) == pytest.approx(0.01)
    lossy = fed.ChannelConfig.lossy(0.5)
    outcomes = [lossy.uplink(100, rng).delivered for _ in range(400)]
    assert 0.3 < np.mean(outcomes) < 0.7
    fading = fed.ChannelConfig.fading(uplink_rate_bps=1e6, fading_floor=0.1)
    rates = [fading.uplink(1000, rng).rate_bps for _ in range(200)]
    assert min(rates) >= 0.1 * 1e6 and np.std(rates) > 0
    with pytest.raises(ValueError):
        fed.ChannelConfig(kind="quantum")


# -------------------------------------------------- stragglers / participation
def test_straggler_quorum_folds_stale_arrivals(linreg):
    """quorum<1 advances past stragglers; their late uplinks still fold
    (eq. (5) bank semantics) and are counted as stale folds."""
    pop = fed.straggler_population(5, compute_mean_s=1.0, straggler_frac=0.2,
                                   straggler_slowdown=25.0, jitter="fixed",
                                   seed=0)
    edge = fed.EdgeConfig(population=pop, channel=fed.ChannelConfig(),
                          quorum=0.8, seed=2)
    cfg = baselines.chb(linreg.alpha_paper, 5)
    hist = fed.run_edge(cfg, linreg.task, edge, 120)
    assert hist.stats.as_dict()["stale_folds"] > 0
    # the slow client still contributed uplinks eventually
    slow = int(np.argmax([p.compute_mean_s for p in pop.profiles]))
    assert hist.stats.uplink_count[slow] > 0
    # quorum=0.8 must finish the same rounds in less wall-clock than waiting
    # for the 25x straggler every round
    full = fed.run_edge(cfg, linreg.task,
                        dataclasses.replace(edge, quorum=1.0), 120)
    assert hist.wall_clock[-1] < full.wall_clock[-1]


def test_partial_participation_caps_cohort(linreg):
    edge = fed.EdgeConfig(
        population=fed.uniform_population(5, participation=0.4),
        channel=fed.ChannelConfig.ideal(), seed=3)
    cfg = baselines.chb(0.5 * linreg.alpha_paper, 5)
    hist = fed.run_edge(cfg, linreg.task, edge, 300)
    per_round = hist.mask.sum(axis=1)
    assert per_round.max() <= 2          # ceil(0.4 * 5)
    fstar = float(simulator.estimate_fstar(linreg.task, linreg.alpha_paper,
                                           20000))
    assert hist.objective[-1] - fstar < 1e-4 * (hist.objective[0] - fstar)


def test_intermittent_availability_makes_progress(linreg):
    edge = fed.EdgeConfig(
        population=fed.intermittent_population(5, avail_p=0.5,
                                               compute_mean_s=0.5),
        seed=4)
    cfg = baselines.chb(linreg.alpha_paper, 5)
    hist = fed.run_edge(cfg, linreg.task, edge, 120)
    assert hist.objective[-1] < hist.objective[0]
    assert hist.stats.total_uplinks < 5 * 120   # not everyone every round


# ------------------------------------------------------------------ accounting
def test_energy_accounting_consistency(linreg):
    em = fed.EnergyModel(uplink_j_per_byte=1e-6, uplink_j_per_tx=1e-3,
                         downlink_j_per_byte=0.0)
    edge = fed.EdgeConfig(
        population=fed.uniform_population(5, compute_mean_s=2.0,
                                          compute_w=3.0),
        channel=fed.ChannelConfig(uplink_rate_bps=1e6),
        energy=em, seed=5)
    cfg = baselines.chb(linreg.alpha_paper, 5)
    hist = fed.run_edge(cfg, linreg.task, edge, 50)
    d = hist.stats.as_dict()
    expect = (d["uplink_bytes"] * 1e-6 + d["uplinks"] * 1e-3
              + d["compute_s"] * 3.0)
    assert d["energy_j"] == pytest.approx(expect, rel=1e-9)
    # exact byte count: every transmission carries the full dense payload
    assert d["uplink_bytes"] == d["uplinks"] * \
        payload_bytes_dense(linreg.task.init_params)
    # wall clock covers at least one compute phase per round
    assert hist.wall_clock[-1] >= 50 * 2.0


def test_edge_metrics_to_accuracy(linreg):
    cfg = baselines.chb(linreg.alpha_paper, 5)
    hist = fed.run_edge(cfg, linreg.task, fed.sync_config(5), 200)
    fstar = float(simulator.estimate_fstar(linreg.task, linreg.alpha_paper,
                                           20000))
    met = fed.edge_metrics_to_accuracy(hist, fstar, 1e-6)
    assert met["rounds"] > 0
    assert met["uplinks"] == int(hist.comm_cum[met["rounds"]])
    unreachable = fed.edge_metrics_to_accuracy(hist, fstar, -1.0)
    assert unreachable["rounds"] == -1 and unreachable["uplinks"] == -1


# -------------------------------------------------------------- config guards
def test_rejects_unsupported_modes(linreg):
    edge = fed.sync_config(5)
    bad_gran = dataclasses.replace(baselines.chb(0.1, 5),
                                   granularity="per_tensor")
    with pytest.raises(NotImplementedError):
        fed.run_edge(bad_gran, linreg.task, edge, 2)
    bad_workers = baselines.chb(0.1, 7)
    with pytest.raises(ValueError):
        fed.run_edge(bad_workers, linreg.task, edge, 2)
    with pytest.raises(ValueError):
        fed.EdgeConfig(population=fed.uniform_population(5), quorum=0.0)
    with pytest.raises(ValueError):
        fed.uniform_population(5, participation=1.5)

"""The ``backend`` axis: the Pallas kernels as the opt execution engine.

Pins the tentpole contract of the backend redesign:

  * ``opt.make(name, backend="pallas")`` runs end-to-end through
    ``simulator.run``, ``sweep.run_sweep`` and the ``repro.fed`` event
    runtime, **bit-identical** to the reference backend at f32 and f64
    (in interpret mode on this container) — pinned both by direct
    history comparison and by golden hex fingerprints;
  * specs round-trip the backend through JSON;
  * sweeping (alpha, beta, eps1) over a pallas composition compiles ONE
    program and traces each kernel dispatch exactly once (the
    static-hparam retrace bug this PR fixes made every point recompile);
  * compositions the kernels cannot fuse (custom stages) are rejected at
    construction instead of silently falling back.
"""
import json

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import fed, opt, sweep
from repro.core import simulator
from repro.data import paper_tasks
from repro.kernels import ops as kernel_ops

M = 5
ITERS = 60


@pytest.fixture(scope="module")
def linreg():
    return paper_tasks.make_linear_regression(m=M, n_per=30, d=20, seed=0)


def _cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def _as_f32(task):
    return task._replace(init_params=_cast_tree(task.init_params,
                                                jnp.float32),
                         worker_data=_cast_tree(task.worker_data,
                                                jnp.float32))


@pytest.fixture(scope="module")
def task32(linreg):
    return _as_f32(linreg.task)


def _fingerprint(h):
    obj = np.asarray(h.objective)
    fsq = float(sum(np.sum(np.square(np.asarray(x, np.float64)))
                    for x in jax.tree_util.tree_leaves(h.final_params)))
    return (float(obj[-1]).hex(), float(obj.sum()).hex(),
            int(np.asarray(h.comm_cum)[-1]),
            int(np.asarray(h.mask).sum()),
            float(np.asarray(h.agg_grad_sqnorm)[-1]).hex(), fsq.hex())


def _assert_histories_equal(h1, h2):
    for f in ("objective", "mask", "comm_cum", "agg_grad_sqnorm"):
        np.testing.assert_array_equal(np.asarray(getattr(h1, f)),
                                      np.asarray(getattr(h2, f)), err_msg=f)
    for a, b in zip(jax.tree_util.tree_leaves(h1.final_params),
                    jax.tree_util.tree_leaves(h2.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(h1.final_state.ghat),
                    jax.tree_util.tree_leaves(h2.final_state.ghat)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# Golden hex fingerprints of the f32 chb run (60 iters, m=5/n=30/d=20
# linreg task at alpha_paper), recorded from the REFERENCE backend — the
# pallas backend must reproduce them bit-for-bit.
GOLDEN_CHB_F32 = ("0x1.107a260000000p+6", "0x1.0024fc0000000p+12",
                  262, 262, "0x1.dc40000000000p-42",
                  "0x1.a94328858133cp+1")

# Per-transport golden pins for the same run, at conformance-scale
# hyperparameters (k=8 actually sparsifies the d=20 task; lowrank ships
# vector leaves dense, so its trajectory — deliberately — equals the
# dense pin). A transport registered without an entry here FAILS
# ``test_golden_fingerprints_all_transports`` loudly instead of going
# uncovered.
GOLDEN_TRANSPORT_KW = {"topk": {"k": 8}, "lowrank": {"rank": 2}}
GOLDEN_TRANSPORT_F32 = {
    "dense": GOLDEN_CHB_F32,
    "int8": ("0x1.107a260000000p+6", "0x1.00251e0000000p+12", 259, 259,
             "0x1.7e80000000000p-41", "0x1.a94328064f2b5p+1"),
    "topk": ("0x1.107a280000000p+6", "0x1.0075ec0000000p+12", 295, 295,
             "0x1.baecd80000000p-13", "0x1.a943cf7d37977p+1"),
    "lowrank": GOLDEN_CHB_F32,
}


# ------------------------------------------------------- simulator parity
@pytest.mark.parametrize("name,kw", [
    ("gd", {}), ("hb", {}), ("lag", {}), ("chb", {}),
    ("csgd", {"tau0": 5.0}),
    ("chb", {"quantize": "int8"}),
    ("chb", {"granularity": "per_tensor"}),
])
def test_simulator_bitwise_f32(linreg, task32, name, kw):
    o_ref = opt.make(name, linreg.alpha_paper, M, **kw)
    o_pal = opt.make(name, linreg.alpha_paper, M, backend="pallas", **kw)
    _assert_histories_equal(simulator.run(o_ref, task32, ITERS),
                            simulator.run(o_pal, task32, ITERS))


@pytest.mark.parametrize("kw", [{}, {"quantize": "int8"}])
def test_simulator_bitwise_f64(linreg, kw):
    o_ref = opt.make("chb", linreg.alpha_paper, M, **kw)
    o_pal = opt.make("chb", linreg.alpha_paper, M, backend="pallas", **kw)
    _assert_histories_equal(simulator.run(o_ref, linreg.task, ITERS),
                            simulator.run(o_pal, linreg.task, ITERS))


def test_golden_fingerprints_both_backends(linreg, task32):
    """Both backends reproduce the recorded golden hex trajectory.

    The pallas leg runs the one-sweep fused step (its default route), so
    this golden also pins the megakernel against the reference bits."""
    for backend in opt.BACKENDS:
        o = opt.make("chb", linreg.alpha_paper, M, backend=backend)
        got = _fingerprint(simulator.run(o, task32, ITERS))
        assert got == GOLDEN_CHB_F32, (backend, got)


@pytest.mark.parametrize("kind", ["dense", "int8"])
def test_golden_fingerprints_staged_pallas(linreg, task32, kind):
    """``force_staged()`` pins the pre-fusion kernel chain to the SAME
    goldens: the fused and staged pallas routes may never drift apart."""
    from repro.kernels import fused_step
    t = opt.make_transport(kind)
    o = opt.make("chb", linreg.alpha_paper, M, transport=t,
                 backend="pallas")
    with fused_step.force_staged():
        got = _fingerprint(simulator.run(o, task32, ITERS))
    assert got == GOLDEN_TRANSPORT_F32[kind], (kind, got)


@pytest.mark.parametrize("kind", sorted(opt.TRANSPORT_KINDS))
def test_golden_fingerprints_all_transports(linreg, task32, kind):
    """Every registered transport has a golden pin, reproduced bit-for-bit
    by BOTH backends. A new registry entry without a pin fails the first
    assert — record one instead of shipping an uncovered transport."""
    assert kind in GOLDEN_TRANSPORT_F32, (
        f"transport {kind!r} is registered but has no golden fingerprint; "
        "add a GOLDEN_TRANSPORT_F32 entry (and GOLDEN_TRANSPORT_KW "
        "hyperparameters if the defaults are a no-op on the d=20 task)")
    t = opt.make_transport(kind, **GOLDEN_TRANSPORT_KW.get(kind, {}))
    for backend in opt.BACKENDS:
        o = opt.make("chb", linreg.alpha_paper, M, transport=t,
                     backend=backend)
        got = _fingerprint(simulator.run(o, task32, ITERS))
        assert got == GOLDEN_TRANSPORT_F32[kind], (kind, backend, got)


def test_pytree_task_bitwise(linreg):
    bn = paper_tasks.make_neural_network(m=4, n_per=40, d=8, hidden=6)
    t32 = _as_f32(bn.task)
    _assert_histories_equal(
        simulator.run(opt.make("chb", 0.02, 4), t32, 25),
        simulator.run(opt.make("chb", 0.02, 4, backend="pallas"), t32, 25))


# ------------------------------------------------------------ spec axis
def test_spec_roundtrips_backend(linreg):
    o = opt.make("chb", 0.05, M, backend="pallas")
    spec = opt.to_spec(o)
    assert spec["backend"] == "pallas"
    assert opt.from_spec(spec) == o
    # JSON wire round-trip
    assert opt.from_spec(json.loads(json.dumps(spec))) == o
    # pre-backend specs (no key) rebuild on the reference backend
    legacy = {k: v for k, v in spec.items() if k != "backend"}
    assert opt.from_spec(legacy).backend == "reference"


def test_with_hparams_preserves_backend():
    o = opt.make("chb", 0.05, M, backend="pallas")
    o2 = o.with_hparams(alpha=0.1, beta=0.3, eps1=2.0)
    assert o2.backend == "pallas"


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        opt.make("chb", 0.05, M, backend="mosaic")


def test_custom_stages_rejected_on_pallas():
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class MyServer:
        alpha: float

        def apply(self, params, prev_params, agg):
            return params

    with pytest.raises(TypeError, match="custom server"):
        opt.ComposedOptimizer(
            censor=opt.NeverCensor(), transport=opt.DenseTransport(),
            server=MyServer(0.1), num_workers=M, backend="pallas")


# --------------------------------------------------------- sweep engine
def test_sweep_pallas_bitwise_one_program(linreg, task32):
    grid = sweep.ConfigGrid(
        alpha=[0.5 * linreg.alpha_paper, linreg.alpha_paper],
        beta=[0.0, 0.4], eps1=[0.5, 2.0])
    base_p = opt.make("chb", linreg.alpha_paper, M, backend="pallas")
    base_r = opt.make("chb", linreg.alpha_paper, M)
    kernel_ops.reset_trace_counts()
    res_p = sweep.run_sweep(grid, task32, num_iters=40, base_cfg=base_p)
    # one compiled program for the whole 8-point grid, each kernel
    # dispatch traced exactly once (the retrace-bug regression)
    assert res_p.num_programs == 1
    assert kernel_ops.trace_counts == {"tree_delta_sqnorms": 1,
                                       "tree_fused_dense_step": 1}
    res_r = sweep.run_sweep(grid, task32, num_iters=40, base_cfg=base_r)
    for i in range(len(res_p)):
        hp, hr = res_p.history(i), res_r.history(i)
        for f in ("objective", "mask", "comm_cum", "agg_grad_sqnorm"):
            np.testing.assert_array_equal(np.asarray(getattr(hp, f)),
                                          np.asarray(getattr(hr, f)))
        assert res_p.specs[i]["backend"] == "pallas"
        assert res_r.specs[i]["backend"] == "reference"
    # sweep rows == per-point pallas simulator.run (the PR-2 exactness
    # contract, now holding for the kernel backend too; asserted on the
    # f64 task — at f32 it holds only to the ulp for BOTH backends)
    res64 = sweep.run_sweep(grid, linreg.task, num_iters=40,
                            base_cfg=base_p)
    pt = res64.points[3]
    o = base_p.with_hparams(alpha=pt.alpha, beta=pt.beta, eps1=pt.eps1)
    h = simulator.run(o, linreg.task, 40)
    np.testing.assert_array_equal(np.asarray(h.objective),
                                  np.asarray(res64.history(3).objective))


# ----------------------------------------------------------- fed runtime
def test_fed_pallas_bitwise(linreg):
    """Event runtime, sync anchor: pallas == reference, bit-for-bit."""
    edge = fed.sync_config(M)
    for kw in ({}, {"quantize": "int8"}):
        h_ref = fed.run_edge(opt.make("chb", linreg.alpha_paper, M, **kw),
                             linreg.task, edge, 30)
        h_pal = fed.run_edge(
            opt.make("chb", linreg.alpha_paper, M, backend="pallas", **kw),
            linreg.task, edge, 30)
        for f in ("objective", "mask", "comm_cum", "agg_grad_sqnorm"):
            np.testing.assert_array_equal(getattr(h_ref, f),
                                          getattr(h_pal, f), err_msg=f)
        for a, b in zip(jax.tree_util.tree_leaves(h_ref.final_params),
                        jax.tree_util.tree_leaves(h_pal.final_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fed_pallas_sync_anchor(linreg):
    """Pallas fed == pallas simulator on the sync anchor: draws, masks
    and uplinks exact; objectives to the anchor tolerance (gradient
    evaluation is per-row there vs vmapped in the simulator)."""
    o = opt.make("csgd", linreg.alpha_paper, M, tau0=5.0,
                 backend="pallas")
    hs = simulator.run(o, linreg.task, 25)
    he = fed.run_edge(o, linreg.task, fed.sync_config(M), 25)
    np.testing.assert_array_equal(np.asarray(hs.mask), he.mask)
    np.testing.assert_array_equal(np.asarray(hs.comm_cum), he.comm_cum)
    np.testing.assert_allclose(np.asarray(hs.objective), he.objective,
                               rtol=1e-9)


# -------------------------------------------------- multi-tile numerics
def test_multitile_masks_aligned_trajectories_close():
    """Beyond the golden scale (multi-tile leaves, >256*128 elements per
    worker): censor masks and uplink counts stay aligned between the
    backends, trajectories stay close but may drift by compounded
    fusion/reduction ulps — the documented contract limit
    (docs/kernels.md), pinned here so a real kernel bug (which would
    break masks or blow past ulp scale) cannot hide behind it."""
    m, d = 4, 70_000
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (m, 30, d), jnp.float32) * 0.05
    y = jax.random.normal(jax.random.fold_in(key, 1), (m, 30), jnp.float32)
    task = simulator.FedTask(
        init_params=jnp.zeros((d,), jnp.float32),
        grad_fn=lambda p, dat: (dat[0].T @ (dat[0] @ p - dat[1]))
        / dat[0].shape[0],
        loss_fn=lambda p, dat: 0.5 * jnp.mean((dat[0] @ p - dat[1]) ** 2),
        worker_data=(A, y), name="multitile")
    h_ref = simulator.run(opt.make("chb", 0.05, m, eps1=0.3), task, 25)
    h_pal = simulator.run(opt.make("chb", 0.05, m, eps1=0.3,
                                   backend="pallas"), task, 25)
    np.testing.assert_array_equal(np.asarray(h_ref.mask),
                                  np.asarray(h_pal.mask))
    np.testing.assert_array_equal(np.asarray(h_ref.comm_cum),
                                  np.asarray(h_pal.comm_cum))
    np.testing.assert_allclose(np.asarray(h_ref.objective),
                               np.asarray(h_pal.objective),
                               rtol=1e-3, atol=1e-9)


# ----------------------------------------------------- distributed hook
def test_distributed_accepts_pallas_composition(linreg):
    """The scan strategy consumes the composition's hyperparameter views;
    a pallas composition passes realizability and trains."""
    from repro.core import distributed
    o = opt.make("chb", 0.05, 4, backend="pallas")
    params = {"w": jnp.zeros((8,), jnp.float32)}
    data = (jnp.ones((4, 3, 8), jnp.float32),
            jnp.ones((4, 3), jnp.float32))

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    state = distributed.init_scan_state(o, params)
    step = jax.jit(distributed.make_scan_step(o, loss_fn))
    params2, state2, metrics = step(params, state, data)
    assert np.isfinite(float(metrics["loss"]))
    assert int(np.asarray(state2.step)) == 1

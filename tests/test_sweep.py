"""repro.sweep: grid enumeration, sweep-vs-loop bit-exactness for the whole
algorithm family (incl. int8 and multi-seed grids), the fed scenario sweep's
sync anchor, export round-trips, and the benchmark driver's --only guard."""
import json
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import sweep
from repro.core import baselines, chb, simulator
from repro.core.censoring import paper_eps1
from repro.data import paper_tasks


@pytest.fixture(scope="module")
def linreg():
    return paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)


def _task_factory(seed, m):
    return paper_tasks.make_linear_regression(
        m=m, n_per=30, d=20, seed=seed).task


def _assert_history_equal(hist, ref):
    """Bitwise trajectory equality: objective, comms, masks, final params."""
    np.testing.assert_array_equal(np.asarray(hist.objective),
                                  np.asarray(ref.objective))
    np.testing.assert_array_equal(np.asarray(hist.comm_cum),
                                  np.asarray(ref.comm_cum))
    np.testing.assert_array_equal(np.asarray(hist.mask),
                                  np.asarray(ref.mask))
    np.testing.assert_array_equal(np.asarray(hist.agg_grad_sqnorm),
                                  np.asarray(ref.agg_grad_sqnorm))
    for a, b in zip(jax.tree_util.tree_leaves(hist.final_params),
                    jax.tree_util.tree_leaves(ref.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------- grid
def test_grid_cartesian_product():
    g = sweep.ConfigGrid(alpha=(0.1, 0.2), beta=(0.0, 0.4),
                         eps1=(0.0, 1.0), seed=(0, 1))
    pts = g.points()
    assert len(pts) == g.num_points == 16
    # row-major in declared field order: alpha slowest, seed fastest here
    assert pts[0] == sweep.GridPoint(0.1, 0.0, 0.0, 0, None, None)
    assert pts[1].seed == 1 and pts[1].alpha == 0.1
    assert pts[-1] == sweep.GridPoint(0.2, 0.4, 1.0, 1, None, None)
    assert pts[0].algo_name == "gd" and pts[-1].algo_name == "chb"


def test_grid_eps1_scale_resolution():
    g = sweep.ConfigGrid(alpha=(0.1,), eps1_scale=(0.5,))
    (p,) = g.points(default_num_workers=4)
    assert p.eps1 == pytest.approx(paper_eps1(0.1, 4, 0.5))
    with pytest.raises(ValueError):
        g.points()      # no M anywhere -> cannot resolve the scale
    with pytest.raises(ValueError):
        sweep.ConfigGrid(alpha=(0.1,), eps1=(1.0,), eps1_scale=(0.5,))
    with pytest.raises(ValueError):
        sweep.ConfigGrid(alpha=(0.1,), quantize=("int4",))


# ------------------------------------------- sweep-vs-loop bit-exactness
def test_sweep_matches_per_point_run_exactly(linreg):
    """A >=8-point batched sweep covering GD/HB/LAG/CHB at two step sizes
    must reproduce each per-point simulator.run trajectory bit-exactly."""
    a = linreg.alpha_paper
    points = []
    for s in (1.0, 0.5):
        for algo in ("gd", "hb", "lag", "chb"):
            cfg = baselines.ALGORITHMS[algo](a * s, 5)
            points.append(sweep.GridPoint(alpha=cfg.alpha, beta=cfg.beta,
                                          eps1=cfg.eps1))
    assert len(points) >= 8
    res = sweep.run_sweep(points, task=linreg.task, num_iters=120)
    assert res.num_programs == 1        # one compiled program for all eight
    for p, hist in zip(points, res.histories):
        cfg = chb.FedOptConfig(alpha=p.alpha, beta=p.beta, eps1=p.eps1,
                               num_workers=5)
        _assert_history_equal(hist, simulator.run(cfg, linreg.task, 120))


def test_sweep_int8_quantized_path_exact(linreg):
    """Mixed dense/int8 grids partition into two programs; the quantized
    error-feedback path must stay bit-exact too."""
    a = linreg.alpha_paper
    eps = paper_eps1(a, 5)
    points = [
        sweep.GridPoint(alpha=a, beta=0.4, eps1=eps),
        sweep.GridPoint(alpha=a, beta=0.4, eps1=eps, quantize="int8"),
        sweep.GridPoint(alpha=a, beta=0.0, eps1=0.0, quantize="int8"),
    ]
    res = sweep.run_sweep(points, task=linreg.task, num_iters=100)
    assert res.num_programs == 2
    for p, hist in zip(points, res.histories):
        cfg = chb.FedOptConfig(alpha=p.alpha, beta=p.beta, eps1=p.eps1,
                               num_workers=5, quantize=p.quantize)
        _assert_history_equal(hist, simulator.run(cfg, linreg.task, 100))
    # quantized transmissions ship ~8x fewer bytes (f64 -> int8 + scale)
    assert res.uplink_bytes[1] < 0.25 * res.uplink_bytes[0]


def test_sweep_seed_axis_exact():
    """Seed (task) axes partition per seed and stay bit-exact per point."""
    b = paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)
    a = b.alpha_paper
    grid = sweep.ConfigGrid(alpha=(a,), beta=(0.4,), eps1_scale=(0.1, 1.0),
                            seed=(0, 1), num_workers=(5,))
    res = sweep.run_sweep(grid, task_factory=_task_factory, num_iters=60)
    assert len(res) == 4 and res.num_programs == 2
    for p, hist in zip(res.points, res.histories):
        cfg = chb.FedOptConfig(alpha=p.alpha, beta=p.beta, eps1=p.eps1,
                               num_workers=5)
        ref = simulator.run(cfg, _task_factory(p.seed, 5), 60)
        _assert_history_equal(hist, ref)


def test_sweep_seed_axis_requires_factory(linreg):
    grid = sweep.ConfigGrid(alpha=(linreg.alpha_paper,), seed=(0, 1))
    with pytest.raises(ValueError, match="task_factory"):
        sweep.run_sweep(grid, task=linreg.task, num_iters=5)
    # a single non-default seed with a shared task would silently mislabel
    # every result row — must be an error, not a shrug
    pts = [sweep.GridPoint(alpha=linreg.alpha_paper, seed=3)]
    with pytest.raises(ValueError, match="task_factory"):
        sweep.run_sweep(pts, task=linreg.task, num_iters=5)


def test_sweep_per_tensor_granularity_exact(linreg):
    """Per-tensor censoring sweeps too: eps1 becomes a static partition
    axis (its byte accounting divmods host-side), and every point stays
    bit-exact vs the per-point simulator run."""
    from repro import opt
    a = linreg.alpha_paper
    base = opt.make("chb", a, 5, granularity="per_tensor")
    eps = paper_eps1(a, 5)
    points = [sweep.GridPoint(alpha=a, beta=0.4, eps1=eps),
              sweep.GridPoint(alpha=a, beta=0.4, eps1=2 * eps),
              sweep.GridPoint(alpha=a * 0.5, beta=0.4, eps1=eps)]
    res = sweep.run_sweep(points, task=linreg.task, num_iters=80,
                          base_cfg=base)
    assert res.num_programs == 2      # one per distinct static eps1
    for p, hist in zip(points, res.histories):
        ref = simulator.run(
            opt.make("chb", p.alpha, 5, beta=p.beta, eps1=p.eps1,
                     granularity="per_tensor"), linreg.task, 80)
        _assert_history_equal(hist, ref)
    # per-tensor masks really differ from global censoring on this grid
    ref_global = simulator.run(opt.make("chb", a, 5, eps1=eps),
                               linreg.task, 80)
    assert (np.asarray(res.histories[0].mask)
            != np.asarray(ref_global.mask)).any()


def test_sweep_float32_task_exact_under_x64():
    """Bit-exactness must hold for f32 tasks too: traced alpha/beta arrive
    as strong f64 scalars under x64 and used to promote (and double-round)
    the f32 eq.-(4) update, flipping censor decisions vs simulator.run."""
    b = paper_tasks.make_linear_regression(m=4, n_per=20, d=10, seed=0)
    to32 = lambda t: jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), t)
    task32 = b.task._replace(init_params=to32(b.task.init_params),
                             worker_data=to32(b.task.worker_data))
    cfg = baselines.chb(b.alpha_paper, 4)
    res = sweep.run_sweep(
        [sweep.GridPoint(alpha=cfg.alpha, beta=cfg.beta, eps1=cfg.eps1)],
        task=task32, num_iters=200)
    _assert_history_equal(res.history(0), simulator.run(cfg, task32, 200))


def test_transmit_mask_traced_eps_matches_static_at_f32_boundary():
    """The eq.-(8) decision must be f32 for static AND traced eps1.

    dsq = f32(0.3) sits exactly on the f32 censor boundary for eps1=0.1,
    ssq=3: in f32 arithmetic eps1*ssq == dsq (censored), in f64 it is
    strictly smaller (transmit). A traced f64 eps1 used to flip this
    decision, breaking the sweep engine's bit-exactness contract."""
    from repro.core.censoring import transmit_mask
    dsq = jnp.float32(0.3)
    ssq = jnp.float32(3.0)
    static = transmit_mask(dsq, ssq, 0.1)
    traced = jax.jit(lambda e: transmit_mask(dsq, ssq, e))(
        jnp.asarray(0.1, jnp.float64))
    np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))
    assert float(static) == 0.0     # f32 semantics: censored


def test_sweep_nn_pytree_task():
    """Pytree (dict) parameters work through the engine unchanged."""
    b = paper_tasks.make_neural_network(m=4, n_per=40, d=8, hidden=6)
    cfg = baselines.chb(0.02, 4)
    pts = [sweep.GridPoint(alpha=cfg.alpha, beta=cfg.beta, eps1=cfg.eps1),
           sweep.GridPoint(alpha=cfg.alpha / 2, beta=0.0, eps1=0.0)]
    res = sweep.run_sweep(pts, task=b.task, num_iters=25)
    for p, hist in zip(pts, res.histories):
        c = chb.FedOptConfig(alpha=p.alpha, beta=p.beta, eps1=p.eps1,
                             num_workers=4)
        _assert_history_equal(hist, simulator.run(c, b.task, 25))


def test_sweep_vectorized_mode_close(linreg):
    """vectorize=True batches the matmuls: same trajectories to float
    tolerance (bit-exactness is only contracted for the default mode)."""
    a = linreg.alpha_paper
    cfg = baselines.chb(a, 5)
    pts = [sweep.GridPoint(alpha=cfg.alpha, beta=cfg.beta, eps1=cfg.eps1),
           sweep.GridPoint(alpha=cfg.alpha, beta=0.0, eps1=0.0)]
    res = sweep.run_sweep(pts, task=linreg.task, num_iters=80,
                          vectorize=True)
    for p, hist in zip(pts, res.histories):
        c = chb.FedOptConfig(alpha=p.alpha, beta=p.beta, eps1=p.eps1,
                             num_workers=5)
        ref = simulator.run(c, linreg.task, 80)
        np.testing.assert_allclose(np.asarray(hist.objective),
                                   np.asarray(ref.objective),
                                   rtol=1e-6, atol=1e-8)


def test_traced_structural_fields_raise(linreg):
    """Structural config fields must stay static: a traced adaptive is a
    loud error, not silent miscompilation."""
    cfg = chb.FedOptConfig(alpha=0.1, num_workers=5, adaptive=0.5)

    def bad(adaptive):
        c = chb.FedOptConfig(alpha=0.1, num_workers=5, adaptive=adaptive)
        return simulator.trajectory(c, linreg.task, 2).objective

    with pytest.raises(NotImplementedError, match="adaptive"):
        jax.jit(bad)(jnp.asarray(0.5))
    # static adaptive still works through the (non-sweep) path
    hist = simulator.run(cfg, linreg.task, 10)
    assert int(hist.final_state.comm.iterations) == 10


# ----------------------------------------------------- frontier + export
def test_frontier_and_export_roundtrip(linreg, tmp_path):
    a = linreg.alpha_paper
    cfgs = [baselines.ALGORITHMS[n](a, 5) for n in ("gd", "chb")]
    pts = [sweep.GridPoint(alpha=c.alpha, beta=c.beta, eps1=c.eps1)
           for c in cfgs]
    res = sweep.run_sweep(pts, task=linreg.task, num_iters=400)
    fstar = float(simulator.estimate_fstar(linreg.task, a, 8000))
    rows = res.frontier(fstar, 1e-6)
    assert [r["algo"] for r in rows] == ["gd", "chb"]
    assert all(r["iters_to_tol"] > 0 for r in rows)
    assert rows[1]["total_comms"] < rows[0]["total_comms"]  # CHB censors

    jpath, cpath = tmp_path / "s.json", tmp_path / "s.csv"
    res.to_json(str(jpath), fstar=fstar, tol=1e-6)
    doc = json.loads(jpath.read_text())
    assert doc["num_points"] == 2 and len(doc["objective"]) == 2
    assert doc["frontier"][1]["algo"] == "chb"
    res.to_csv(fstar, 1e-6, str(cpath))
    lines = cpath.read_text().splitlines()
    assert lines[0].startswith("index,algo,") and len(lines) == 3


# ------------------------------------------------------------- fed sweep
def test_fed_sweep_ideal_point_matches_run(linreg):
    """loss 0 + participation 1 + quorum 1 == core/simulator.run exactly
    (the same anchor contract as the event-driven fed runtime)."""
    cfg = baselines.chb(linreg.alpha_paper, 5)
    grid = sweep.FedScenarioGrid(loss_prob=(0.0, 0.4))
    res = sweep.run_fed_sweep(cfg, linreg.task, grid, num_rounds=80)
    ref = simulator.run(cfg, linreg.task, 80)
    i = res.points.index(sweep.FedScenarioPoint(0.0, 1.0, 1.0, 0))
    np.testing.assert_array_equal(res.objective[i],
                                  np.asarray(ref.objective))
    np.testing.assert_array_equal(res.comm_cum[i], np.asarray(ref.comm_cum))
    np.testing.assert_array_equal(
        res.transmit_mask[i], np.asarray(ref.mask).astype(np.int8))
    assert bool(res.quorum_met[i].all())


def test_fed_sweep_scenario_effects(linreg):
    cfg = baselines.chb(linreg.alpha_paper, 5)
    grid = sweep.FedScenarioGrid(loss_prob=(0.0, 0.4),
                                 participation=(1.0, 0.5))
    res = sweep.run_fed_sweep(cfg, linreg.task, grid, num_rounds=120)
    p = list(res.points)
    ideal = p.index(sweep.FedScenarioPoint(0.0, 1.0, 1.0, 0))
    lossy = p.index(sweep.FedScenarioPoint(0.4, 1.0, 1.0, 0))
    partial = p.index(sweep.FedScenarioPoint(0.0, 0.5, 1.0, 0))
    # drops burn uplinks without delivering
    assert res.delivered_cum[lossy, -1] < res.comm_cum[lossy, -1]
    assert res.delivered_cum[ideal, -1] == res.comm_cum[ideal, -1]
    # partial participation attempts fewer uplinks than full
    assert res.comm_cum[partial, -1] < res.comm_cum[ideal, -1]
    # accounting is monotone and consistent
    assert (np.diff(res.energy_cum, axis=1) >= 0).all()
    assert (res.bytes_cum[:, -1] > 0).all()
    fstar = float(simulator.estimate_fstar(linreg.task,
                                           linreg.alpha_paper, 8000))
    rows = res.frontier(fstar, 1e-6)
    assert rows[ideal]["rounds"] > 0 and rows[ideal]["energy_j"] > 0


def test_fed_sweep_rejects_unsupported_modes(linreg):
    import dataclasses
    cfg = dataclasses.replace(baselines.chb(linreg.alpha_paper, 5),
                              quantize="int8")
    with pytest.raises(NotImplementedError):
        sweep.run_fed_sweep(cfg, linreg.task, sweep.FedScenarioGrid(), 5)


# ------------------------------------------------------ benchmark driver
def test_bench_run_only_unknown_name_exits_nonzero():
    """A typo'd --only must fail fast listing valid names, not print an
    empty CSV with exit 0."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "no_such_bench"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode != 0
    assert "no_such_bench" in proc.stderr
    assert "fig11_epsilon" in proc.stderr     # the valid names are listed

"""repro.opt: the composable optimizer protocol.

Pins (1) bit-exact golden trajectories of every legacy composition against
fingerprints recorded from the pre-redesign ``chb.step`` (the hex values
below were produced by the monolithic implementation at commit 10c3388),
(2) registry round-trips and error behavior, (3) the deprecation shims,
(4) csgd — a pure composition — end-to-end through simulator, fed runtime,
and sweep, and (5) censor-mask properties (hypothesis).
"""
import json
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import fed, opt, sweep
from repro.core import baselines, chb, simulator
from repro.data import paper_tasks


@pytest.fixture(scope="module")
def linreg():
    return paper_tasks.make_linear_regression(m=5, n_per=30, d=20, seed=0)


def _fingerprint(o, task, num_iters):
    h = simulator.run(o, task, num_iters)
    obj = np.asarray(h.objective)
    fsq = float(sum(np.sum(np.square(np.asarray(x)))
                    for x in jax.tree_util.tree_leaves(h.final_params)))
    return (float(obj[-1]).hex(), float(obj.sum()).hex(),
            int(np.asarray(h.comm_cum)[-1]),
            int(np.asarray(h.mask).sum()),
            float(np.asarray(h.agg_grad_sqnorm)[-1]).hex(), fsq.hex())


# Recorded from the pre-redesign monolithic chb.step (80 iters on the
# m=5/n=30/d=20/seed=0 linreg task at alpha_paper; nn: 25 iters, alpha=.02).
PRE_REDESIGN = {
    "gd": ("0x1.107a2630170dep+6", "0x1.5565de3d49cdep+12", 400, 400,
           "0x1.89217c0000000p-47", "0x1.a9432872d3e1dp+1"),
    "hb": ("0x1.107a2630170dep+6", "0x1.554a72a2ae846p+12", 400, 400,
           "0x1.bf00000000000p-99", "0x1.a9432904593dep+1"),
    "lag": ("0x1.107a2630170dfp+6", "0x1.55624996ff56bp+12", 318, 318,
            "0x1.b7ba9e0000000p-49", "0x1.a94328ba0160bp+1"),
    "chb": ("0x1.107a2630170dfp+6", "0x1.554b25e02a552p+12", 322, 322,
            "0x1.4975000000000p-90", "0x1.a9432904593e7p+1"),
    "chb_int8": ("0x1.107a2630170dfp+6", "0x1.554b482e14e77p+12", 322, 322,
                 "0x1.74d9900000000p-90", "0x1.a9432904593e6p+1"),
    "chb_per_tensor": ("0x1.107a2630170dfp+6", "0x1.554b25e02a552p+12",
                       339, 339, "0x1.2fe2a80000000p-89",
                       "0x1.a9432904593e2p+1"),
    "adaptive": ("0x1.107d098b8dcacp+6", "0x1.564a627d34fcep+12", 83, 83,
                 "0x1.4ab7740000000p-5", "0x1.aa4b7667b4258p+1"),
    "nn_chb": ("0x1.403883a4462c4p+2", "0x1.94b4c291e8686p+8", 40, 40,
               "0x1.61d8d00000000p+2", "0x1.1a697c350cf04p+5"),
}

ALPHA_PAPER_HEX = "0x1.406a1a2d8bd52p-4"


def test_task_alpha_unchanged(linreg):
    """The goldens assume this task; if alpha moves, they mean nothing."""
    assert float(linreg.alpha_paper).hex() == ALPHA_PAPER_HEX


# ------------------------------------------------- golden bit-exactness
@pytest.mark.parametrize("name", ["gd", "hb", "lag", "chb"])
def test_registry_matches_pre_redesign_step(linreg, name):
    got = _fingerprint(opt.make(name, linreg.alpha_paper, 5),
                       linreg.task, 80)
    assert got == PRE_REDESIGN[name]


def test_int8_composition_matches_pre_redesign(linreg):
    o = opt.make("chb", linreg.alpha_paper, 5, quantize="int8")
    assert _fingerprint(o, linreg.task, 80) == PRE_REDESIGN["chb_int8"]


def test_per_tensor_composition_matches_pre_redesign(linreg):
    o = opt.make("chb", linreg.alpha_paper, 5, granularity="per_tensor")
    assert _fingerprint(o, linreg.task, 80) == \
        PRE_REDESIGN["chb_per_tensor"]


def test_adaptive_composition_matches_pre_redesign(linreg):
    o = opt.ComposedOptimizer(
        censor=opt.AdaptiveCensor(0.25), transport=opt.DenseTransport(),
        server=opt.HeavyBall(linreg.alpha_paper, 0.4), num_workers=5)
    assert _fingerprint(o, linreg.task, 80) == PRE_REDESIGN["adaptive"]


def test_pytree_task_matches_pre_redesign():
    bn = paper_tasks.make_neural_network(m=4, n_per=40, d=8, hidden=6)
    assert _fingerprint(opt.make("chb", 0.02, 4), bn.task, 25) == \
        PRE_REDESIGN["nn_chb"]


# ------------------------------------------------------ deprecation shims
def test_fedoptconfig_construction_warns():
    with pytest.warns(DeprecationWarning, match="repro.opt"):
        chb.FedOptConfig(alpha=0.1, num_workers=3)


@pytest.mark.parametrize("name", ["gd", "hb", "lag", "chb"])
def test_baselines_warn_and_match_registry_bitwise(linreg, name):
    """The legacy constructors warn once and build the SAME composition
    the registry does — trajectories bit-for-bit identical."""
    with pytest.warns(DeprecationWarning):
        cfg = baselines.ALGORITHMS[name](linreg.alpha_paper, 5)
    built = cfg.build()
    reg = opt.make(name, linreg.alpha_paper, 5)
    # the facade may express "no censoring"/"no momentum" through the same
    # stages or degenerate ones; the trajectories must be bit-identical
    h_facade = simulator.run(cfg, linreg.task, 60)
    h_built = simulator.run(built, linreg.task, 60)
    h_reg = simulator.run(reg, linreg.task, 60)
    for a, b in ((h_facade, h_reg), (h_built, h_reg)):
        np.testing.assert_array_equal(np.asarray(a.objective),
                                      np.asarray(b.objective))
        np.testing.assert_array_equal(np.asarray(a.mask),
                                      np.asarray(b.mask))
        np.testing.assert_array_equal(np.asarray(a.comm_cum),
                                      np.asarray(b.comm_cum))
        for x, y in zip(jax.tree_util.tree_leaves(a.final_params),
                        jax.tree_util.tree_leaves(b.final_params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_legacy_step_entrypoint_still_works(linreg):
    """chb.init/chb.step keep their legacy signatures and return order."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg = baselines.chb(linreg.alpha_paper, 5)
    params = linreg.task.init_params
    state = chb.init(cfg, params)
    grads = jax.vmap(linreg.task.grad_fn, in_axes=(None, 0))(
        params, linreg.task.worker_data)
    new_params, new_state, info = chb.step(cfg, state, params, grads)
    assert isinstance(info, opt.StepStats)
    assert isinstance(new_state, opt.OptState)
    assert info.mask.shape == (5,)
    assert int(new_state.comm.iterations) == 1
    assert jax.tree_util.tree_structure(new_params) == \
        jax.tree_util.tree_structure(params)


# -------------------------------------------------- registry round-trips
def test_spec_roundtrip_identity_all_registered():
    for name in opt.names():
        o = opt.make(name, 0.05, 4)
        spec = opt.to_spec(o)
        assert opt.from_spec(spec) == o, name
        # and through an actual JSON wire format
        assert opt.from_spec(json.loads(json.dumps(spec))) == o, name


def test_spec_roundtrip_nondefault_fields():
    o = opt.make("csgd", 0.03, 7, tau0=12.5, decay=0.9, seed=3,
                 quantize="int8")
    assert opt.from_spec(json.loads(json.dumps(opt.to_spec(o)))) == o


def test_every_registered_kind_round_trips_through_spec():
    """Every censor/server kind in the registries survives a spec
    round-trip — and is pinned here by literal kind name, which is what
    the registry-kind-unpinned lint rule checks for (repro.lint)."""
    base = opt.to_spec(opt.make("gd", 0.05, 3))
    assert base["censor"]["kind"] == "never"
    assert base["server"]["kind"] == "gd"
    censor_specs = {
        "never": {"kind": "never"},
        "eq8": {"kind": "eq8", "eps1": 0.2},
        "adaptive": {"kind": "adaptive", "adaptive": 1.5, "decay": 0.9},
        "stochastic": {"kind": "stochastic", "tau0": 10.0, "decay": 0.8,
                       "seed": 0},
    }
    server_specs = {
        "gd": {"kind": "gd", "alpha": 0.05},
        "hb": {"kind": "hb", "alpha": 0.05, "beta": 0.4},
    }
    assert set(censor_specs) == set(opt.registry.CENSOR_KINDS)
    assert set(server_specs) == set(opt.registry.SERVER_KINDS)
    for ckind, cspec in censor_specs.items():
        for skind, sspec in server_specs.items():
            spec = dict(base, censor=cspec, server=sspec)
            rebuilt = opt.from_spec(json.loads(json.dumps(spec)))
            round_trip = opt.to_spec(rebuilt)
            assert round_trip["censor"]["kind"] == ckind
            assert round_trip["server"]["kind"] == skind
            assert opt.from_spec(round_trip) == rebuilt


def test_unknown_algorithm_lists_valid_names():
    with pytest.raises(ValueError) as ei:
        opt.make("no_such_algo", 0.1, 3)
    msg = str(ei.value)
    for name in opt.names():
        assert name in msg
    with pytest.raises(ValueError):
        opt.make_for_point("also_missing", 0.1, 3)


def test_unknown_spec_kind_raises():
    spec = opt.to_spec(opt.make("gd", 0.1, 3))
    spec["censor"] = {"kind": "martian"}
    with pytest.raises(ValueError, match="martian"):
        opt.from_spec(spec)


def test_make_for_point_filters_by_signature():
    """gd's builder takes no beta/eps1/seed; the sweep engine's uniform
    keyword set must not crash it."""
    o = opt.make_for_point("gd", 0.1, 3, beta=0.7, eps1=0.2, quantize=None,
                           seed=4)
    assert isinstance(o.censor, opt.NeverCensor)
    assert o.beta == 0.0


def test_with_hparams_semantics():
    base = opt.make("chb", 0.1, 4)
    o = base.with_hparams(alpha=0.2, beta=0.0, eps1=0.5)
    assert (o.alpha, o.beta, o.eps1) == (0.2, 0.0, 0.5)
    # NeverCensor upgrades to Eq8 when an eps1 axis is swept
    o2 = opt.make("hb", 0.1, 4).with_hparams(eps1=0.5)
    assert isinstance(o2.censor, opt.Eq8Censor)
    # adaptive censors ignore the eps axis (legacy config precedence)
    ad = opt.ComposedOptimizer(
        censor=opt.AdaptiveCensor(0.3), transport=opt.DenseTransport(),
        server=opt.HeavyBall(0.1), num_workers=4)
    assert isinstance(ad.with_hparams(eps1=0.5).censor, opt.AdaptiveCensor)
    # stochastic (and custom) censors own their thresholds: kept as
    # composed, never silently swapped for Eq8 (the spec must stay honest)
    sc = opt.make("csgd", 0.1, 4, tau0=7.0)
    swept = sc.with_hparams(alpha=0.2, beta=0.0, eps1=0.5)
    assert isinstance(swept.censor, opt.StochasticCensor)
    assert swept.censor.tau0 == 7.0
    # a GD server is promoted to HeavyBall when a beta axis is swept
    # (bit-identical at beta=0), so lag/gd bases sweep like legacy configs
    gd_based = opt.make("lag", 0.1, 4)
    hb_swept = gd_based.with_hparams(beta=0.4)
    assert isinstance(hb_swept.server, opt.HeavyBall)
    assert hb_swept.beta == 0.4 and hb_swept.alpha == 0.1


def test_run_sweep_accepts_gd_server_base(linreg):
    """A lag/gd ComposedOptimizer base must sweep (regression: the GD
    server used to raise on the engine's always-present beta axis) and
    stay bit-exact vs per-point runs."""
    from repro.core.censoring import paper_eps1
    a = linreg.alpha_paper
    base = opt.make("lag", a, 5)
    pts = [sweep.GridPoint(alpha=a, beta=0.0, eps1=paper_eps1(a, 5)),
           sweep.GridPoint(alpha=a, beta=0.0, eps1=0.0)]
    res = sweep.run_sweep(pts, task=linreg.task, num_iters=60,
                          base_cfg=base)
    for p, hist in zip(pts, res.histories):
        ref = simulator.run(opt.ComposedOptimizer(
            censor=opt.Eq8Censor(p.eps1), transport=opt.DenseTransport(),
            server=opt.HeavyBall(p.alpha, p.beta), num_workers=5),
            linreg.task, 60)
        np.testing.assert_array_equal(np.asarray(hist.objective),
                                      np.asarray(ref.objective))
        np.testing.assert_array_equal(np.asarray(hist.mask),
                                      np.asarray(ref.mask))


def test_run_sweep_keeps_stochastic_base_censor(linreg):
    """base_cfg with a StochasticCensor sweeps alpha without the censor
    being silently replaced — and the recorded spec says so."""
    a = linreg.alpha_paper
    base = opt.make("csgd", a, 5, tau0=1e3, decay=0.99)
    pts = [sweep.GridPoint(alpha=a), sweep.GridPoint(alpha=a * 0.5)]
    res = sweep.run_sweep(pts, task=linreg.task, num_iters=40,
                          base_cfg=base)
    for spec in res.specs:
        assert spec["censor"]["kind"] == "stochastic"
        assert spec["censor"]["tau0"] == 1e3
    ref = simulator.run(base, linreg.task, 40)
    np.testing.assert_array_equal(np.asarray(res.histories[0].mask),
                                  np.asarray(ref.mask))
    # ...but a VARYING eps axis over such a base would be silently
    # ignored trajectory-wise — run_sweep must refuse it loudly
    bad = [sweep.GridPoint(alpha=a, eps1=0.1),
           sweep.GridPoint(alpha=a, eps1=0.2)]
    with pytest.raises(ValueError, match="eps1 hook"):
        sweep.run_sweep(bad, task=linreg.task, num_iters=5, base_cfg=base)


def test_hyperparameter_views(linreg):
    o = opt.make("chb", 0.05, 9, quantize="int8")
    assert o.alpha == 0.05 and o.beta == 0.4 and o.eps1 > 0
    assert o.quantize == "int8" and o.adaptive == 0.0
    assert o.name == "chb"
    assert opt.make("gd", 0.05, 9).name == "gd"
    assert opt.make("hb", 0.05, 9).name == "hb"
    assert opt.make("lag", 0.05, 9).name == "lag"


# --------------------------------------------------------- csgd end-to-end
def _csgd(alpha, m, tau0=50.0, decay=0.98, seed=0):
    return opt.make("csgd", alpha, m, tau0=tau0, decay=decay, seed=seed)


def test_csgd_simulator_censors_and_progresses(linreg):
    o = _csgd(linreg.alpha_paper, 5, tau0=1e3, decay=0.98)
    hist = simulator.run(o, linreg.task, 600)
    total = int(np.asarray(hist.comm_cum)[-1])
    assert 0 < total < 5 * 600            # censors, but the bank stays live
    assert float(hist.objective[-1]) < float(hist.objective[0])
    fstar = float(simulator.estimate_fstar(linreg.task,
                                           linreg.alpha_paper, 20000))
    # GD-rate convergence under stochastic censoring: solidly past 1% of
    # the initial error (momentum-free, so slower than chb's tail)
    assert float(hist.objective[-1]) - fstar < \
        1e-2 * (float(hist.objective[0]) - fstar)


def test_csgd_fed_sync_anchor_matches_simulator(linreg):
    """Synchronous edge schedule == simulator draw-for-draw: the per-client
    key folding must reproduce the batched censor decisions exactly."""
    o = _csgd(linreg.alpha_paper, 5, tau0=1e3, decay=0.99)
    ref = simulator.run(o, linreg.task, 60)
    hist = fed.run_edge(o, linreg.task, fed.sync_config(5), 60)
    np.testing.assert_array_equal(hist.mask,
                                  np.asarray(ref.mask).astype(np.int8))
    np.testing.assert_array_equal(hist.comm_cum, np.asarray(ref.comm_cum))
    np.testing.assert_allclose(hist.objective, np.asarray(ref.objective),
                               rtol=1e-9, atol=1e-12)


def test_named_point_defaults_use_builder_defaults(linreg):
    """GridPoint(algo="chb") with beta/eps1 left at the grid's 0.0
    defaults must run the REAL registered chb (paper beta=0.4, Sec.-IV
    eps1) — not an uncensored gd mislabeled chb (regression)."""
    a = linreg.alpha_paper
    pts = [sweep.GridPoint(alpha=a, algo="chb"),
           sweep.GridPoint(alpha=a, beta=0.2, algo="chb")]
    res = sweep.run_sweep(pts, task=linreg.task, num_iters=60)
    assert res.num_programs == 2      # set vs unset beta axis differ
    spec0 = res.specs[0]
    assert spec0["censor"]["kind"] == "eq8" and \
        spec0["censor"]["eps1"] > 0           # paper default applied
    assert spec0["server"] == {"kind": "hb", "alpha": float(a), "beta": 0.4}
    ref = simulator.run(opt.make("chb", a, 5), linreg.task, 60)
    np.testing.assert_array_equal(np.asarray(res.histories[0].objective),
                                  np.asarray(ref.objective))
    np.testing.assert_array_equal(np.asarray(res.histories[0].mask),
                                  np.asarray(ref.mask))
    # the explicitly-set beta point really used beta=0.2
    assert res.specs[1]["server"]["beta"] == 0.2


def test_csgd_sweep_partition_bit_exact(linreg):
    """GridPoint(algo="csgd") compiles as its own partition and reproduces
    the per-point simulator run bit-exactly (tau0 swept via the eps axis)."""
    a = linreg.alpha_paper
    chb_o = opt.make("chb", a, 5)
    pts = [sweep.GridPoint(alpha=chb_o.alpha, beta=chb_o.beta,
                           eps1=chb_o.eps1),
           sweep.GridPoint(alpha=a, eps1=1e3, algo="csgd"),
           sweep.GridPoint(alpha=a, eps1=50.0, algo="csgd")]
    res = sweep.run_sweep(pts, task=linreg.task, num_iters=80)
    assert res.num_programs == 2          # continuum + csgd partition
    assert [p.algo_name for p in res.points] == ["chb", "csgd", "csgd"]
    for p, hist in zip(pts[1:], res.histories[1:]):
        ref = simulator.run(
            opt.make("csgd", p.alpha, 5, tau0=p.eps1), linreg.task, 80)
        np.testing.assert_array_equal(np.asarray(hist.objective),
                                      np.asarray(ref.objective))
        np.testing.assert_array_equal(np.asarray(hist.mask),
                                      np.asarray(ref.mask))
        np.testing.assert_array_equal(np.asarray(hist.comm_cum),
                                      np.asarray(ref.comm_cum))


def test_csgd_fed_scenario_sweep_ideal_anchor(linreg):
    """csgd also runs through the synchronous fed-scenario sweep; the
    ideal point reproduces simulator.run exactly."""
    o = _csgd(linreg.alpha_paper, 5, tau0=1e3, decay=0.99)
    grid = sweep.FedScenarioGrid(loss_prob=(0.0, 0.3))
    res = sweep.run_fed_sweep(o, linreg.task, grid, num_rounds=60)
    ref = simulator.run(o, linreg.task, 60)
    i = res.points.index(sweep.FedScenarioPoint(0.0, 1.0, 1.0, 0))
    np.testing.assert_array_equal(res.objective[i],
                                  np.asarray(ref.objective))
    np.testing.assert_array_equal(
        res.transmit_mask[i], np.asarray(ref.mask).astype(np.int8))


# ------------------------------------------- artifact reproducibility
def test_sweep_artifact_specs_rebuild_exact_runs(linreg, tmp_path):
    """--json artifacts carry full registry specs: a run is reproducible
    from the artifact alone, without the code that made it."""
    a = linreg.alpha_paper
    chb_o = opt.make("chb", a, 5)
    pts = [sweep.GridPoint(alpha=chb_o.alpha, beta=chb_o.beta,
                           eps1=chb_o.eps1),
           sweep.GridPoint(alpha=a, eps1=200.0, algo="csgd")]
    res = sweep.run_sweep(pts, task=linreg.task, num_iters=50)
    path = tmp_path / "artifact.json"
    res.to_json(str(path))
    doc = json.loads(path.read_text())
    assert len(doc["specs"]) == 2
    for i, spec in enumerate(doc["specs"]):
        rebuilt = opt.from_spec(spec)
        rerun = simulator.run(rebuilt, linreg.task, 50)
        np.testing.assert_array_equal(np.asarray(rerun.objective),
                                      np.asarray(doc["objective"][i]))
        np.testing.assert_array_equal(np.asarray(rerun.comm_cum),
                                      np.asarray(doc["comm_cum"][i]))
    # the csgd spec names its composition, not just "csgd"
    assert doc["specs"][1]["censor"]["kind"] == "stochastic"
    assert doc["specs"][1]["server"]["kind"] == "gd"


# --------------------------------------------------- protocol boundaries
def test_minimal_protocol_optimizer_runs_in_simulator(linreg):
    """A bare init/step implementation runs through the simulator; the
    stage hosts (fed, fed-sweep) reject it with a clear TypeError instead
    of a raw attribute crash."""
    class Wrapped:
        def __init__(self, inner):
            self.inner = inner
            self.num_workers = inner.num_workers

        def init(self, params):
            return self.inner.init(params)

        def step(self, state, params, grads):
            return self.inner.step(state, params, grads)

    inner = opt.make("chb", linreg.alpha_paper, 5)
    wrapped = Wrapped(inner)
    hist = simulator.run(wrapped, linreg.task, 40)
    ref = simulator.run(inner, linreg.task, 40)
    np.testing.assert_array_equal(np.asarray(hist.objective),
                                  np.asarray(ref.objective))
    with pytest.raises(TypeError, match="ComposedOptimizer"):
        fed.run_edge(wrapped, linreg.task, fed.sync_config(5), 5)
    with pytest.raises(TypeError, match="ComposedOptimizer"):
        sweep.run_fed_sweep(wrapped, linreg.task,
                            sweep.FedScenarioGrid(), 5)


def test_distributed_strategies_reject_unrealizable_censors():
    """The scan/pod training strategies only realize eq-8/uncensored
    policies; a stochastic censor must be refused loudly, not silently
    run uncensored through the flat eps1 view."""
    from repro.core import distributed
    o = opt.make("csgd", 0.05, 4, tau0=10.0)
    with pytest.raises(NotImplementedError, match="StochasticCensor"):
        distributed.make_scan_step(o, lambda p, b: 0.0)
    # eq-8 compositions still build fine
    distributed.make_scan_step(opt.make("chb", 0.05, 4), lambda p, b: 0.0)


def test_sweep_runs_custom_stage_without_spec(linreg):
    """A composition using a censor class outside the spec vocabulary is
    still sweepable — its spec is recorded as None instead of aborting."""
    import dataclasses as dc

    @dc.dataclass(frozen=True)
    class EveryOther:
        supports_event_runtime = True

        def init(self, num_workers):
            return jnp.zeros((), jnp.int32)

        def decide(self, k, delta_sq, step_sq):
            on = (k % 2 == 0).astype(jnp.float32)
            return jnp.full(delta_sq.shape, 1.0) * on, k + 1

        def client_decide(self, round_index, worker, delta_sq, step_sq):
            return (round_index % 2) == 0

    base = opt.ComposedOptimizer(
        censor=EveryOther(), transport=opt.DenseTransport(),
        server=opt.HeavyBall(linreg.alpha_paper, 0.4), num_workers=5)
    res = sweep.run_sweep([sweep.GridPoint(alpha=linreg.alpha_paper)],
                          task=linreg.task, num_iters=20, base_cfg=base)
    assert res.specs == (None,)
    assert int(res.comm_cum[0, -1]) == 5 * 10     # every other round
    o = opt.ComposedOptimizer(
        censor=opt.AdaptiveCensor(0.3), transport=opt.DenseTransport(),
        server=opt.HeavyBall(linreg.alpha_paper, 0.4), num_workers=5)
    with pytest.raises(NotImplementedError, match="[Aa]daptive"):
        fed.run_edge(o, linreg.task, fed.sync_config(5), 5)


def test_unknown_quantize_mode_raises():
    with pytest.raises(ValueError, match="int8"):
        opt.make("chb", 0.1, 4, quantize="int4")


# ------------------------------------------------------ mask properties
def test_censor_mask_monotone_in_eps1_concrete():
    dsq = jnp.asarray([0.5, 1.0, 2.0, 8.0], jnp.float32)
    ssq = jnp.asarray(4.0, jnp.float32)
    prev = None
    for eps1 in (0.0, 0.1, 0.25, 0.5, 2.0, 10.0):
        mask, _ = opt.Eq8Censor(eps1).decide((), dsq, ssq)
        m = np.asarray(mask)
        if prev is not None:
            assert (m <= prev).all(), eps1
        prev = m


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(dsq=st.lists(st.floats(0.0, 1e6), min_size=1, max_size=8),
           ssq=st.floats(0.0, 1e6),
           e1=st.floats(0.0, 1e3), e2=st.floats(0.0, 1e3))
    def test_property_censor_mask_monotone_in_eps1(dsq, ssq, e1, e2):
        """Raising eps1 can only censor MORE workers (eq. 8 is a one-sided
        threshold), for static and traced thresholds alike."""
        lo, hi = sorted((e1, e2))
        d = jnp.asarray(dsq, jnp.float32)
        s = jnp.asarray(ssq, jnp.float32)
        m_lo, _ = opt.Eq8Censor(lo).decide((), d, s)
        m_hi, _ = opt.Eq8Censor(hi).decide((), d, s)
        assert (np.asarray(m_hi) <= np.asarray(m_lo)).all()
        # traced threshold decides identically (sweep bit-exactness)
        m_tr = jax.jit(lambda e: opt.Eq8Censor(e).decide((), d, s)[0])(
            jnp.float64(hi))
        np.testing.assert_array_equal(np.asarray(m_tr), np.asarray(m_hi))

    @settings(max_examples=25, deadline=None)
    @given(k=st.integers(0, 500), seed=st.integers(0, 100))
    def test_property_stochastic_censor_tau_decays(k, seed):
        """The CSGD threshold sequence decays geometrically, so any fixed
        delta's transmit probability is non-decreasing in k."""
        pol = opt.StochasticCensor(tau0=100.0, decay=0.97, seed=seed)
        t0 = float(pol._tau(jnp.asarray(k)))
        t1 = float(pol._tau(jnp.asarray(k + 1)))
        assert t1 <= t0

"""Table I: ijcnn1-scale (49990 x 22, 9 workers) — linear, lasso, logistic
regression + neural network. Synthetic stand-in with matched dimensions
(offline container; see DESIGN.md §7)."""
from .common import compare_algorithms, csv_row, print_table
from repro.data import paper_tasks


def main() -> str:
    rows = []
    for kind, tol, iters in [("linear", 1e-7, 2000), ("lasso", 1e-5, 2000),
                             ("logistic", 1e-5, 3000)]:
        b = paper_tasks.make_standin("ijcnn1", kind)
        res = compare_algorithms(b, num_iters=iters, tol=tol)
        print_table(f"Table I: ijcnn1 {kind} (tol {tol})", res)
        chb, hb = res["chb"], res["hb"]
        if chb["comms_to_tol"] > 0 and hb["comms_to_tol"] > 0:
            assert chb["comms_to_tol"] <= hb["comms_to_tol"]
            rows.append(f"{kind}={hb['comms_to_tol']/chb['comms_to_tol']:.1f}x")
    # neural network: fixed 500 iterations, metric = ||grad||^2
    b = paper_tasks.make_neural_network(m=9, d=22)
    res = compare_algorithms(b, num_iters=500, tol=0.0,
                             alpha=0.02, eps1_scale=None or 0.1)
    print("\n== Table I: neural network (500 iters) ==")
    for a in ("chb", "hb", "lag", "gd"):
        r = res[a]
        print(f"{a:4s} comms={r['total_comms']:6d} "
              f"norm_sq_grad={r['final_gradsq']:.4e}")
    chb, hb = res["chb"], res["hb"]
    assert chb["total_comms"] < hb["total_comms"]
    # competitive progress: same order of magnitude gradient norm as HB
    assert chb["final_gradsq"] < 10 * hb["final_gradsq"]
    rows.append(f"nn_comm_frac={chb['total_comms']/hb['total_comms']:.2f}")
    return csv_row("table1_ijcnn", res, ";".join(rows))


if __name__ == "__main__":
    print(main())

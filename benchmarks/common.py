"""Shared helpers for the paper-reproduction benchmarks.

All paper experiments run in float64 (the censoring test degenerates at the
f32 numerical floor — see EXPERIMENTS.md) and report:
  * communications / iterations to a target objective error (Tables I, II)
  * objective-error trajectories vs comms and vs iterations (Figs. 2-12)

Since PR 2 the algorithm comparisons run through ``repro.sweep``: the four
gd/hb/lag/chb baselines are four grid points of one compiled device program
(bit-identical to per-point ``simulator.run`` — tests/test_sweep.py), so a
table that used to pay four compilations pays one.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import sweep
from repro.core import baselines, simulator
from repro.core.simulator import (FedTask, comms_to_accuracy, estimate_fstar,
                                  iterations_to_accuracy, run)

ALGOS = ["chb", "hb", "lag", "gd"]


def algo_points(alpha: float, m: int, beta: float = 0.4,
                eps1_scale: float = 0.1) -> dict[str, sweep.GridPoint]:
    """The four baselines as sweep grid points (one compiled program)."""
    out = {}
    for name in ALGOS:
        kw = {}
        if name in ("hb", "chb"):
            kw["beta"] = beta
        if name in ("lag", "chb"):
            kw["eps1_scale"] = eps1_scale
        cfg = baselines.ALGORITHMS[name](alpha, m, **kw)
        out[name] = sweep.GridPoint(alpha=cfg.alpha, beta=cfg.beta,
                                    eps1=cfg.eps1)
    return out


def compare_algorithms(bundle, num_iters: int, tol: float,
                       alpha: float | None = None, beta: float = 0.4,
                       eps1_scale: float = 0.1, fstar_iters: int = 40000):
    """Run all four algorithms as one sweep; return {algo: dict} with stats."""
    alpha = alpha if alpha is not None else bundle.alpha_paper
    m = bundle.L_m.shape[0]
    fstar = float(estimate_fstar(bundle.task, alpha, fstar_iters))
    points = algo_points(alpha, m, beta=beta, eps1_scale=eps1_scale)
    res = sweep.run_sweep(tuple(points.values()), task=bundle.task,
                          num_iters=num_iters)
    us = res.elapsed_s / (len(points) * num_iters) * 1e6
    out = {"fstar": fstar}
    for i, name in enumerate(points):
        hist = res.history(i)
        out[name] = {
            "iters_to_tol": iterations_to_accuracy(hist, fstar, tol),
            "comms_to_tol": comms_to_accuracy(hist, fstar, tol),
            "total_comms": int(np.asarray(hist.comm_cum)[-1]),
            "final_err": float(np.asarray(hist.objective)[-1] - fstar),
            "final_gradsq": float(np.asarray(hist.agg_grad_sqnorm)[-1]),
            "us_per_iter": us,
            "objective": np.asarray(hist.objective) - fstar,
            "comm_cum": np.asarray(hist.comm_cum),
            "mask": np.asarray(hist.mask),
        }
    return out


def print_table(title: str, results: dict, metric_keys=("comms_to_tol",
                                                        "iters_to_tol")):
    print(f"\n== {title} ==")
    hdr = "algo".ljust(6) + "".join(k.rjust(16) for k in metric_keys)
    print(hdr)
    for a in ALGOS:
        row = a.ljust(6) + "".join(
            str(results[a][k]).rjust(16) for k in metric_keys)
        print(row)


def csv_row(name: str, results: dict, derived: str) -> str:
    us = results["chb"]["us_per_iter"]
    return f"{name},{us:.1f},{derived}"

"""Shared helpers for the paper-reproduction benchmarks.

All paper experiments run in float64 (the censoring test degenerates at the
f32 numerical floor — see EXPERIMENTS.md) and report:
  * communications / iterations to a target objective error (Tables I, II)
  * objective-error trajectories vs comms and vs iterations (Figs. 2-12)

Since PR 2 the algorithm comparisons run through ``repro.sweep``: the four
gd/hb/lag/chb baselines are four grid points of one compiled device program
(bit-identical to per-point ``simulator.run`` — tests/test_sweep.py).
Since PR 3 they are built through the ``repro.opt`` registry, the fifth
curve is ``csgd`` (stochastic censoring, arXiv:1909.03631 — a pure
composition of existing stages), and every result row carries the full
registry spec so ``--json`` artifacts are reproducible from the artifact
alone (``opt.from_spec(row["spec"])`` rebuilds the exact optimizer).
"""
from __future__ import annotations

import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import opt, sweep
from repro.core.censoring import delta_sqnorms
from repro.core.simulator import (FedTask, comms_to_accuracy, estimate_fstar,
                                  iterations_to_accuracy)

ALGOS = ["chb", "hb", "lag", "gd", "csgd"]

# compressed-uplink variants: chb over each non-dense registry transport,
# at task-scaled hyperparameters (see ``task_transport``)
TRANSPORT_CURVES = ["chb_int8", "chb_topk", "chb_lowrank"]
CURVES = ALGOS + TRANSPORT_CURVES


def task_params_count(task: FedTask) -> int:
    return int(sum(x.size for x in
                   jax.tree_util.tree_leaves(task.init_params)))


def task_transport(kind: str, task: FedTask):
    """A task-scaled transport instance for the comparison curves.

    top-k keeps ~40% of each worker's update (at least one entry) — on
    the paper's ill-conditioned quadratics (L_m up to 1.3^16) EF top-k
    at the paper step size diverges below ~36% density, so 40% is the
    stable setting that still cuts uplink bytes (12-byte index+value
    pairs vs 8 bytes/entry dense at f64). Low-rank uses the PowerSGD
    rank-2 default. The instances go onto the sweep ``base_cfg`` and
    survive the quantize axis intact (the engine reuses a base transport
    whose ``mode`` matches the point's kind).
    """
    if kind == "topk":
        return opt.make_transport(
            "topk", k=max(1, (2 * task_params_count(task)) // 5))
    if kind == "lowrank":
        return opt.make_transport("lowrank", rank=2)
    return opt.make_transport(kind)


def csgd_tau0(task: FedTask) -> float:
    """A task-scaled initial threshold for the CSGD decaying sequence.

    CSGD censors ``||delta||^2`` against an absolute threshold, so unlike
    the paper's eq. (8) (which self-scales through ``||dtheta||^2``) it
    needs to know the problem's gradient scale. The median worker's
    squared gradient norm at theta^0 puts the initial transmit probability
    ``min(1, ||delta||^2/tau_0)`` around 1 for the high-curvature half of
    the cohort.
    """
    g0 = jax.vmap(task.grad_fn, in_axes=(None, 0))(task.init_params,
                                                   task.worker_data)
    return float(np.median(np.asarray(delta_sqnorms(g0))))


def algo_points(alpha: float, m: int, beta: float = 0.4,
                eps1_scale: float = 0.1,
                tau0: float | None = None) -> dict[str, sweep.GridPoint]:
    """The five benchmark algorithms as registry-built sweep grid points.

    gd/hb/lag/chb share one compiled program (the eq.-8/heavy-ball
    continuum); csgd compiles as its own partition and is only included
    when a task-scaled ``tau0`` is given (see ``csgd_tau0``).
    """
    out = {}
    for name in ALGOS:
        if name == "csgd":
            if tau0 is None:
                continue
            out[name] = sweep.GridPoint(alpha=alpha, eps1=tau0, algo="csgd")
            continue
        kw = {}
        if name in ("hb", "chb"):
            kw["beta"] = beta
        if name in ("lag", "chb"):
            kw["eps1_scale"] = eps1_scale
        o = opt.make(name, alpha, m, **kw)
        out[name] = sweep.GridPoint(alpha=o.alpha, beta=o.beta, eps1=o.eps1)
    return out


def _curve(res, i, fstar, tol, us):
    hist = res.history(i)
    return {
        "iters_to_tol": iterations_to_accuracy(hist, fstar, tol),
        "comms_to_tol": comms_to_accuracy(hist, fstar, tol),
        "total_comms": int(np.asarray(hist.comm_cum)[-1]),
        "final_err": float(np.asarray(hist.objective)[-1] - fstar),
        "final_gradsq": float(np.asarray(hist.agg_grad_sqnorm)[-1]),
        "uplink_bytes": int(res.uplink_bytes[i]),
        "us_per_iter": us,
        "spec": res.specs[i],
        "objective": np.asarray(hist.objective) - fstar,
        "comm_cum": np.asarray(hist.comm_cum),
        "mask": np.asarray(hist.mask),
    }


def compare_algorithms(bundle, num_iters: int, tol: float,
                       alpha: float | None = None, beta: float = 0.4,
                       eps1_scale: float = 0.1, fstar_iters: int = 40000,
                       transports: tuple = ()):
    """Run all five algorithms as one sweep; return {algo: dict} with stats.

    Each algorithm's dict includes its full registry ``spec``
    (``opt.from_spec``-able) and its exact ``uplink_bytes``, so exported
    artifacts identify the exact composition, not just a name.

    ``transports`` adds compressed-chb curves (one per non-dense kind,
    keyed ``chb_<kind>``) at task-scaled hyperparameters — each kind runs
    as its own single-point sweep partition with the scaled transport on
    the ``base_cfg``.
    """
    alpha = alpha if alpha is not None else bundle.alpha_paper
    m = bundle.L_m.shape[0]
    fstar = float(estimate_fstar(bundle.task, alpha, fstar_iters))
    points = algo_points(alpha, m, beta=beta, eps1_scale=eps1_scale,
                         tau0=csgd_tau0(bundle.task))
    res = sweep.run_sweep(tuple(points.values()), task=bundle.task,
                          num_iters=num_iters)
    us = res.elapsed_s / (len(points) * num_iters) * 1e6
    out = {"fstar": fstar}
    for i, name in enumerate(points):
        out[name] = _curve(res, i, fstar, tol, us)
    chb = opt.make("chb", alpha, m, beta=beta, eps1_scale=eps1_scale)
    for kind in transports:
        base = dataclasses.replace(chb,
                                   transport=task_transport(kind,
                                                            bundle.task))
        pt = sweep.GridPoint(alpha=chb.alpha, beta=chb.beta, eps1=chb.eps1,
                             quantize=kind)
        tres = sweep.run_sweep((pt,), task=bundle.task,
                               num_iters=num_iters, base_cfg=base)
        tus = tres.elapsed_s / num_iters * 1e6
        out[f"chb_{kind}"] = _curve(tres, 0, fstar, tol, tus)
    return out


def print_table(title: str, results: dict, metric_keys=("comms_to_tol",
                                                        "iters_to_tol")):
    print(f"\n== {title} ==")
    width = max(len(a) for a in CURVES) + 1
    hdr = "algo".ljust(width) + "".join(k.rjust(16) for k in metric_keys)
    print(hdr)
    for a in CURVES:
        if a not in results:
            continue
        row = a.ljust(width) + "".join(
            str(results[a][k]).rjust(16) for k in metric_keys)
        print(row)


def specs_payload(results: dict) -> dict:
    """The {curve: registry spec} section for --json artifacts."""
    return {a: results[a]["spec"] for a in CURVES if a in results}


def csv_row(name: str, results: dict, derived: str) -> str:
    us = results["chb"]["us_per_iter"]
    return f"{name},{us:.1f},{derived}"

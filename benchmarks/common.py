"""Shared helpers for the paper-reproduction benchmarks.

All paper experiments run in float64 (the censoring test degenerates at the
f32 numerical floor — see EXPERIMENTS.md) and report:
  * communications / iterations to a target objective error (Tables I, II)
  * objective-error trajectories vs comms and vs iterations (Figs. 2-12)

Since PR 2 the algorithm comparisons run through ``repro.sweep``: the four
gd/hb/lag/chb baselines are four grid points of one compiled device program
(bit-identical to per-point ``simulator.run`` — tests/test_sweep.py).
Since PR 3 they are built through the ``repro.opt`` registry, the fifth
curve is ``csgd`` (stochastic censoring, arXiv:1909.03631 — a pure
composition of existing stages), and every result row carries the full
registry spec so ``--json`` artifacts are reproducible from the artifact
alone (``opt.from_spec(row["spec"])`` rebuilds the exact optimizer).
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import opt, sweep
from repro.core.censoring import delta_sqnorms
from repro.core.simulator import (FedTask, comms_to_accuracy, estimate_fstar,
                                  iterations_to_accuracy)

ALGOS = ["chb", "hb", "lag", "gd", "csgd"]


def csgd_tau0(task: FedTask) -> float:
    """A task-scaled initial threshold for the CSGD decaying sequence.

    CSGD censors ``||delta||^2`` against an absolute threshold, so unlike
    the paper's eq. (8) (which self-scales through ``||dtheta||^2``) it
    needs to know the problem's gradient scale. The median worker's
    squared gradient norm at theta^0 puts the initial transmit probability
    ``min(1, ||delta||^2/tau_0)`` around 1 for the high-curvature half of
    the cohort.
    """
    g0 = jax.vmap(task.grad_fn, in_axes=(None, 0))(task.init_params,
                                                   task.worker_data)
    return float(np.median(np.asarray(delta_sqnorms(g0))))


def algo_points(alpha: float, m: int, beta: float = 0.4,
                eps1_scale: float = 0.1,
                tau0: float | None = None) -> dict[str, sweep.GridPoint]:
    """The five benchmark algorithms as registry-built sweep grid points.

    gd/hb/lag/chb share one compiled program (the eq.-8/heavy-ball
    continuum); csgd compiles as its own partition and is only included
    when a task-scaled ``tau0`` is given (see ``csgd_tau0``).
    """
    out = {}
    for name in ALGOS:
        if name == "csgd":
            if tau0 is None:
                continue
            out[name] = sweep.GridPoint(alpha=alpha, eps1=tau0, algo="csgd")
            continue
        kw = {}
        if name in ("hb", "chb"):
            kw["beta"] = beta
        if name in ("lag", "chb"):
            kw["eps1_scale"] = eps1_scale
        o = opt.make(name, alpha, m, **kw)
        out[name] = sweep.GridPoint(alpha=o.alpha, beta=o.beta, eps1=o.eps1)
    return out


def compare_algorithms(bundle, num_iters: int, tol: float,
                       alpha: float | None = None, beta: float = 0.4,
                       eps1_scale: float = 0.1, fstar_iters: int = 40000):
    """Run all five algorithms as one sweep; return {algo: dict} with stats.

    Each algorithm's dict includes its full registry ``spec``
    (``opt.from_spec``-able), so exported artifacts identify the exact
    composition, not just a name.
    """
    alpha = alpha if alpha is not None else bundle.alpha_paper
    m = bundle.L_m.shape[0]
    fstar = float(estimate_fstar(bundle.task, alpha, fstar_iters))
    points = algo_points(alpha, m, beta=beta, eps1_scale=eps1_scale,
                         tau0=csgd_tau0(bundle.task))
    res = sweep.run_sweep(tuple(points.values()), task=bundle.task,
                          num_iters=num_iters)
    us = res.elapsed_s / (len(points) * num_iters) * 1e6
    out = {"fstar": fstar}
    for i, name in enumerate(points):
        hist = res.history(i)
        out[name] = {
            "iters_to_tol": iterations_to_accuracy(hist, fstar, tol),
            "comms_to_tol": comms_to_accuracy(hist, fstar, tol),
            "total_comms": int(np.asarray(hist.comm_cum)[-1]),
            "final_err": float(np.asarray(hist.objective)[-1] - fstar),
            "final_gradsq": float(np.asarray(hist.agg_grad_sqnorm)[-1]),
            "us_per_iter": us,
            "spec": res.specs[i],
            "objective": np.asarray(hist.objective) - fstar,
            "comm_cum": np.asarray(hist.comm_cum),
            "mask": np.asarray(hist.mask),
        }
    return out


def print_table(title: str, results: dict, metric_keys=("comms_to_tol",
                                                        "iters_to_tol")):
    print(f"\n== {title} ==")
    hdr = "algo".ljust(6) + "".join(k.rjust(16) for k in metric_keys)
    print(hdr)
    for a in ALGOS:
        if a not in results:
            continue
        row = a.ljust(6) + "".join(
            str(results[a][k]).rjust(16) for k in metric_keys)
        print(row)


def specs_payload(results: dict) -> dict:
    """The {algo: registry spec} section for --json artifacts."""
    return {a: results[a]["spec"] for a in ALGOS if a in results}


def csv_row(name: str, results: dict, derived: str) -> str:
    us = results["chb"]["us_per_iter"]
    return f"{name},{us:.1f},{derived}"

"""Shared helpers for the paper-reproduction benchmarks.

All paper experiments run in float64 (the censoring test degenerates at the
f32 numerical floor — see EXPERIMENTS.md) and report:
  * communications / iterations to a target objective error (Tables I, II)
  * objective-error trajectories vs comms and vs iterations (Figs. 2-12)
"""
from __future__ import annotations

import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import baselines, simulator
from repro.core.simulator import (FedTask, comms_to_accuracy, estimate_fstar,
                                  iterations_to_accuracy, run)

ALGOS = ["chb", "hb", "lag", "gd"]


def compare_algorithms(bundle, num_iters: int, tol: float,
                       alpha: float | None = None, beta: float = 0.4,
                       eps1_scale: float = 0.1, fstar_iters: int = 40000):
    """Run all four algorithms; return {algo: dict} with comm/iter stats."""
    alpha = alpha if alpha is not None else bundle.alpha_paper
    m = bundle.L_m.shape[0]
    fstar = float(estimate_fstar(bundle.task, alpha, fstar_iters))
    out = {"fstar": fstar}
    for name in ALGOS:
        kw = {}
        if name in ("hb", "chb"):
            kw["beta"] = beta
        if name in ("lag", "chb"):
            kw["eps1_scale"] = eps1_scale
        cfg = baselines.ALGORITHMS[name](alpha, m, **kw)
        t0 = time.time()
        hist = run(cfg, bundle.task, num_iters)
        dt = time.time() - t0
        rec = {
            "iters_to_tol": iterations_to_accuracy(hist, fstar, tol),
            "comms_to_tol": comms_to_accuracy(hist, fstar, tol),
            "total_comms": int(hist.comm_cum[-1]),
            "final_err": float(hist.objective[-1] - fstar),
            "final_gradsq": float(hist.agg_grad_sqnorm[-1]),
            "us_per_iter": dt / num_iters * 1e6,
            "objective": np.asarray(hist.objective) - fstar,
            "comm_cum": np.asarray(hist.comm_cum),
            "mask": np.asarray(hist.mask),
        }
        out[name] = rec
    return out


def print_table(title: str, results: dict, metric_keys=("comms_to_tol",
                                                        "iters_to_tol")):
    print(f"\n== {title} ==")
    hdr = "algo".ljust(6) + "".join(k.rjust(16) for k in metric_keys)
    print(hdr)
    for a in ALGOS:
        row = a.ljust(6) + "".join(
            str(results[a][k]).rjust(16) for k in metric_keys)
        print(row)


def csv_row(name: str, results: dict, derived: str) -> str:
    us = results["chb"]["us_per_iter"]
    return f"{name},{us:.1f},{derived}"

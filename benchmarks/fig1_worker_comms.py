"""Fig. 1: per-worker communication counts in the first 24 iterations,
linear regression with increasing smoothness L_m = (1.3^(m-1))^2."""
import numpy as np

from .common import compare_algorithms, csv_row
from repro.core import baselines, simulator
from repro.data import paper_tasks


def main() -> str:
    b = paper_tasks.make_linear_regression()   # paper Fig. 1 setting
    cfg = baselines.chb(b.alpha_paper, 9)
    hist = simulator.run(cfg, b.task, 24)
    counts = np.asarray(hist.mask).sum(axis=0).astype(int)
    hb_counts = np.full(9, 24)
    print("\n== Fig. 1: per-worker comms, first 24 iterations ==")
    print("worker:  " + " ".join(f"{i+1:4d}" for i in range(9)))
    print("CHB:     " + " ".join(f"{c:4d}" for c in counts))
    print("HB:      " + " ".join(f"{c:4d}" for c in hb_counts))
    # paper claim: workers with small L_m transmit less frequently
    assert counts[0] <= counts[-1]
    monotone_frac = np.mean(np.diff(counts) >= 0)
    saved = 1 - counts.sum() / hb_counts.sum()
    return (f"fig1_worker_comms,0,chb_saved={saved:.2f};"
            f"monotone_frac={monotone_frac:.2f}")


if __name__ == "__main__":
    print(main())

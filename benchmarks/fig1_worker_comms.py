"""Fig. 1: per-worker communication counts in the first 24 iterations,
linear regression with increasing smoothness L_m = (1.3^(m-1))^2.

Three rows since the ``repro.opt`` redesign: CHB (the paper's), HB's
transmit-always baseline, and — composed purely through the registry —
CSGD's stochastically censored GD as a contrast: its decaying absolute
threshold censors against gradient magnitude alone, while CHB's eq.-(8)
test adapts to each worker's smoothness (the paper's Fig.-1 claim).
"""
import numpy as np

from .common import csgd_tau0
from repro import opt
from repro.core import simulator
from repro.data import paper_tasks


def main():
    b = paper_tasks.make_linear_regression()   # paper Fig. 1 setting
    chb = opt.make("chb", b.alpha_paper, 9)
    hist = simulator.run(chb, b.task, 24)
    counts = np.asarray(hist.mask).sum(axis=0).astype(int)
    hb_counts = np.full(9, 24)

    tau0 = csgd_tau0(b.task)
    csgd = opt.make("csgd", b.alpha_paper, 9, tau0=tau0)
    csgd_hist = simulator.run(csgd, b.task, 24)
    csgd_counts = np.asarray(csgd_hist.mask).sum(axis=0).astype(int)

    print("\n== Fig. 1: per-worker comms, first 24 iterations ==")
    print("worker:  " + " ".join(f"{i+1:4d}" for i in range(9)))
    print("CHB:     " + " ".join(f"{c:4d}" for c in counts))
    print("HB:      " + " ".join(f"{c:4d}" for c in hb_counts))
    print("CSGD:    " + " ".join(f"{c:4d}" for c in csgd_counts))
    # paper claim: workers with small L_m transmit less frequently
    assert counts[0] <= counts[-1]
    monotone_frac = np.mean(np.diff(counts) >= 0)
    saved = 1 - counts.sum() / hb_counts.sum()
    csgd_saved = 1 - csgd_counts.sum() / hb_counts.sum()
    row = (f"fig1_worker_comms,0,chb_saved={saved:.2f};"
           f"monotone_frac={monotone_frac:.2f};csgd_saved={csgd_saved:.2f}")
    payload = {
        "counts": {"chb": counts.tolist(), "hb": hb_counts.tolist(),
                   "csgd": csgd_counts.tolist()},
        # full registry specs: the artifact alone rebuilds each optimizer
        "specs": {"chb": opt.to_spec(chb), "csgd": opt.to_spec(csgd)},
    }
    return row, payload


if __name__ == "__main__":
    print(main()[0])

"""Benchmark driver: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints a ``name,us_per_call,derived`` CSV line per benchmark at the end.
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (fig1_worker_comms, fig2_linreg, fig3_logreg,
                   fig10_stepsize, fig11_epsilon, fig12_descent,
                   fig_edge_scenarios, roofline, serving, table1_ijcnn,
                   table2_small, table3_mnist)
    benches = [
        ("fig1_worker_comms", fig1_worker_comms.main),
        ("fig_edge_scenarios", fig_edge_scenarios.main),
        ("fig2_linreg", fig2_linreg.main),
        ("fig3_logreg", fig3_logreg.main),
        ("table1_ijcnn", table1_ijcnn.main),
        ("table2_small", table2_small.main),
        ("table3_mnist", table3_mnist.main),
        ("fig10_stepsize", fig10_stepsize.main),
        ("fig11_epsilon", fig11_epsilon.main),
        ("fig12_descent", fig12_descent.main),
        ("serving", serving.main),
        ("roofline", roofline.main),
    ]
    rows, failed = [], []
    for name, fn in benches:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            rows.append(fn())
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

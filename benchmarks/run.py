"""Benchmark driver: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json PATH]

Prints a ``name,us_per_call,derived`` CSV line per benchmark at the end.
``--json PATH`` additionally writes a machine-readable artifact (rows plus
whatever structured payload each benchmark returns — trajectories,
frontiers, speedups, and the full ``repro.opt`` registry spec of every
algorithm, so a result is reproducible from the artifact alone via
``opt.from_spec``) so future PRs can commit ``BENCH_*.json`` files.

Benchmark modules are imported lazily (module name == benchmark name), so
``--only`` validation costs nothing and a typo'd name fails fast with the
list of valid names instead of silently printing an empty CSV.
"""
import argparse
import importlib
import json
import sys
import time
import traceback

BENCH_NAMES = (
    "fig1_worker_comms",
    "fig_edge_scenarios",
    "fig2_linreg",
    "fig3_logreg",
    "table1_ijcnn",
    "table2_small",
    "table3_mnist",
    "fig10_stepsize",
    "fig11_epsilon",
    "fig12_descent",
    "serving",
    "roofline",
    "kernel_roofline",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write structured results (rows + per-benchmark "
                         "payloads) to PATH")
    args = ap.parse_args()

    if args.only is not None and args.only not in BENCH_NAMES:
        print(f"error: unknown benchmark {args.only!r}; valid names:",
              file=sys.stderr)
        for n in BENCH_NAMES:
            print(f"  {n}", file=sys.stderr)
        raise SystemExit(2)

    # every paper benchmark runs in f64 (see common.py); the old driver got
    # this from eagerly importing common — keep it explicit under lazy import
    import jax
    jax.config.update("jax_enable_x64", True)

    names = [args.only] if args.only else list(BENCH_NAMES)
    rows, payloads, failed = [], {}, []
    for name in names:
        t0 = time.time()
        try:
            fn = importlib.import_module(f"benchmarks.{name}").main
            out = fn()
            if isinstance(out, tuple):
                row, payload = out
            else:
                row, payload = out, {}
            dt = time.time() - t0
            rows.append(row)
            payloads[name] = {"row": row, "seconds": dt, **payload}
            print(f"[{name}] done in {dt:.1f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)
    if args.json:
        from repro import opt
        doc = {"benchmarks": payloads, "failed": failed,
               "registry": list(opt.names())}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Benchmark driver: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json PATH]

Prints a ``name,us_per_call,derived`` CSV line per benchmark at the end.
``--json PATH`` additionally writes a schema-versioned artifact (see
``repro.obs.bench`` for the envelope: ``schema_version``, ``env``,
``registry``, per-benchmark payloads with rows, per-point ``repro.opt``
registry specs, and backend axes) — the checked-in ``BENCH_*.json`` files
at the repo root are these artifacts, validated by
``python -m repro.obs.bench --validate`` and diffed by
``tools/bench_diff.py``.

Every per-benchmark payload uniformly carries ``backend`` (the
``repro.opt`` backend axis it exercised, defaulting to "reference") and
``specs`` (per-point registry specs where the benchmark has optimizer
points), so a result row is reproducible from the artifact alone via
``opt.from_spec``.

Benchmark modules are imported lazily (module name == benchmark name), so
``--only`` validation costs nothing and a typo'd name fails fast with the
list of valid names instead of silently printing an empty CSV. Setting
``REPRO_BENCH_FAST=1`` asks benchmarks that support it (kernel_roofline,
transport_zoo, fed_mesh) to run tiny CI-smoke shapes.
"""
import argparse
import importlib
import os
import sys
import time
import traceback

BENCH_NAMES = (
    "fig1_worker_comms",
    "fig_edge_scenarios",
    "fig2_linreg",
    "fig3_logreg",
    "table1_ijcnn",
    "table2_small",
    "table3_mnist",
    "fig10_stepsize",
    "fig11_epsilon",
    "fig12_descent",
    "transport_zoo",
    "serving",
    "roofline",
    "kernel_roofline",
    "fed_mesh",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write structured results (rows + per-benchmark "
                         "payloads) to PATH")
    args = ap.parse_args()

    if args.only is not None and args.only not in BENCH_NAMES:
        print(f"error: unknown benchmark {args.only!r}; valid names:",
              file=sys.stderr)
        for n in BENCH_NAMES:
            print(f"  {n}", file=sys.stderr)
        raise SystemExit(2)

    # every paper benchmark runs in f64 (see common.py); the old driver got
    # this from eagerly importing common — keep it explicit under lazy import
    import jax
    jax.config.update("jax_enable_x64", True)

    names = [args.only] if args.only else list(BENCH_NAMES)
    rows, payloads, failed = [], {}, []
    for name in names:
        t0 = time.time()
        try:
            fn = importlib.import_module(f"benchmarks.{name}").main
            out = fn()
            if isinstance(out, tuple):
                row, payload = out
            else:
                row, payload = out, {}
            dt = time.time() - t0
            rows.append(row)
            entry = {"row": row, "seconds": dt, **payload}
            # uniform artifact contract: every payload names its backend
            # axis and carries per-point specs (empty when the benchmark
            # has no optimizer points)
            entry.setdefault("backend", "reference")
            entry.setdefault("specs", [])
            payloads[name] = entry
            print(f"[{name}] done in {dt:.1f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)
    if args.json:
        from repro import opt
        from repro.obs import bench
        stem = os.path.basename(args.json)
        if stem.startswith("BENCH_"):
            stem = stem[len("BENCH_"):]
        stem = stem[:-5] if stem.endswith(".json") else stem
        doc = bench.make_artifact(
            stem or "bench", payloads, failed=failed,
            registry=list(opt.names()))
        bench.write_artifact(doc, args.json)
        print(f"wrote {args.json}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Roofline analysis (deliverable g): post-process the dry-run sweep JSONs
into the three-term table. See EXPERIMENTS.md §Roofline.

  compute    = FLOPs_device / peak          (197 TFLOP/s bf16 per chip)
  memory     = HBM_bytes_device / bw        (819 GB/s)
  collective = coll_bytes_device / link_bw  (~50 GB/s/link ICI)

FLOPs / bytes are the loop-aware per-device totals from
repro.launch.hlo_analysis (XLA's cost_analysis counts while bodies once —
see that module's docstring).
"""
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

MODEL_PARAMS = {}


def _model_flops(arch: str, shape: str) -> float:
    """6*N(active)*tokens for train, 2*N*tokens for inference."""
    from repro.configs import ARCHS
    from repro.launch.specs import INPUT_SHAPES
    from repro.models.model import active_param_count
    cfg = ARCHS[arch]
    if arch not in MODEL_PARAMS:
        MODEL_PARAMS[arch] = active_param_count(cfg)
    n = MODEL_PARAMS[arch]
    info = INPUT_SHAPES[shape]
    if info["kind"] == "train":
        toks = info["global_batch"] * info["seq_len"]
        return 6.0 * n * toks
    if info["kind"] == "prefill":
        toks = info["global_batch"] * info["seq_len"]
        return 2.0 * n * toks
    return 2.0 * n * info["global_batch"]          # decode: 1 token/seq


def load_records(result_dir: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(f) as fh:
            data = json.load(fh)
        recs.extend(data if isinstance(data, list) else [data])
    return recs


def roofline_table(result_dir: str, chips: int = 256) -> list[dict]:
    rows = []
    for r in load_records(result_dir):
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "ok": False, "error": r.get("error", "?")})
            continue
        comp = r["flops"] / PEAK_FLOPS           # per-device seconds
        mem = r["hbm_bytes"] / HBM_BW
        coll = r["collective_bytes"] / LINK_BW
        dom = max(("compute", comp), ("memory", mem),
                  ("collective", coll), key=lambda kv: kv[1])
        mf = _model_flops(r["arch"], r["shape"])
        useful = mf / (r["flops"] * chips) if r["flops"] else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "ok": True,
            "compute_s": comp, "memory_s": mem, "collective_s": coll,
            "bottleneck": dom[0],
            "model_flops": mf, "hlo_flops_total": r["flops"] * chips,
            "useful_ratio": useful,
            "temp_gib": r["memory"]["temp_bytes"] / 2**30,
            "arg_gib": r["memory"]["argument_bytes"] / 2**30,
        })
    return rows


def main() -> tuple[str, dict]:
    base = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results")
    n_ok = 0
    total = 0
    all_rows = {}
    for mesh in ("pod1", "pod2"):
        d = os.path.join(base, f"dryrun_{mesh}")
        if not os.path.isdir(d):
            continue
        rows = roofline_table(d)
        all_rows[mesh] = rows
        print(f"\n== Roofline ({mesh}) ==")
        print(f"{'arch':24s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
              f"{'coll_s':>9s} {'bound':>10s} {'useful':>7s}")
        for r in rows:
            total += 1
            if not r["ok"]:
                print(f"{r['arch']:24s} {r['shape']:12s} FAILED: "
                      f"{r['error'][:50]}")
                continue
            n_ok += 1
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
                  f"{r['collective_s']:9.4f} {r['bottleneck']:>10s} "
                  f"{r['useful_ratio']:7.3f}")
    hillclimb_table(base)
    payload = {"backend": "reference", "specs": [],
               "peaks": {"flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                         "link_bw": LINK_BW},
               "tables": all_rows}
    return f"roofline,0,cases_ok={n_ok}/{total}", payload


def hillclimb_table(base: str) -> None:
    """§Perf comparison: hillclimb variants vs their single-pod baselines."""
    d = os.path.join(base, "hillclimb")
    if not os.path.isdir(d):
        return
    print("\n== §Perf hillclimb variants (vs single-pod baselines) ==")
    print(f"{'variant':42s} {'comp_s':>8s} {'mem_s':>8s} {'coll_s':>9s} "
          f"{'max-term':>9s}")
    shown = set()
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        recs = json.load(open(f))
        r = recs[0] if isinstance(recs, list) else recs
        if not r.get("ok"):
            print(f"{os.path.basename(f)[:-5]:42s} FAILED")
            continue
        key = (r["arch"], r["shape"])
        if key not in shown:
            shown.add(key)
            bpath = os.path.join(base, "dryrun_pod1",
                                 f"{r['arch']}_{r['shape']}.json")
            if os.path.exists(bpath):
                b = json.load(open(bpath))[0]
                bc, bm, bl = (b["flops"] / PEAK_FLOPS,
                              b["hbm_bytes"] / HBM_BW,
                              b["collective_bytes"] / LINK_BW)
                print(f"{(r['arch'][:24] + ' BASELINE'):42s} {bc:8.2f} "
                      f"{bm:8.2f} {bl:9.2f} {max(bc, bm, bl):9.2f}")
        c, m, l = (r["flops"] / PEAK_FLOPS, r["hbm_bytes"] / HBM_BW,
                   r["collective_bytes"] / LINK_BW)
        name = os.path.basename(f)[:-5]
        print(f"{name[:42]:42s} {c:8.2f} {m:8.2f} {l:9.2f} "
              f"{max(c, m, l):9.2f}")


if __name__ == "__main__":
    print(main()[0])

"""Table III: MNIST-scale (60000 x 196 stand-in), fixed iteration budget —
report objective error at the budget + total comms."""
from .common import compare_algorithms, csv_row
from repro.data import paper_tasks


def main() -> str:
    rows = []
    res = None
    for kind, iters in [("linear", 1500), ("logistic", 1500)]:
        b = paper_tasks.make_standin("mnist", kind)
        res = compare_algorithms(b, num_iters=iters, tol=0.0)
        print(f"\n== Table III: mnist {kind} ({iters} iters, fixed) ==")
        for a in ("chb", "hb", "lag", "gd"):
            r = res[a]
            print(f"{a:4s} comms={r['total_comms']:7d} "
                  f"final_err={r['final_err']:.4e}")
        chb, hb, gd = res["chb"], res["hb"], res["gd"]
        assert chb["total_comms"] < hb["total_comms"]
        # paper: at a fixed budget CHB keeps error at least in HB's range,
        # far below GD
        assert chb["final_err"] <= 10 * hb["final_err"] + 1e-12
        rows.append(f"{kind}_comm_frac="
                    f"{chb['total_comms']/hb['total_comms']:.3f}")
    return csv_row("table3_mnist", res, ";".join(rows))


if __name__ == "__main__":
    print(main())

"""Fig. 2: objective error vs comms and iterations — linear regression,
synthetic, increasing L_m (the paper's headline synthetic comparison)."""
from .common import compare_algorithms, csv_row, print_table, specs_payload
from repro.data import paper_tasks


def main():
    b = paper_tasks.make_linear_regression()
    res = compare_algorithms(b, num_iters=3000, tol=1e-7)
    print_table("Fig. 2: linreg synthetic (tol 1e-7)", res)
    chb, hb = res["chb"], res["hb"]
    lag = res["lag"]
    # paper claims: CHB fewest comms; iterations ~ HB; beats LAG on both
    assert chb["comms_to_tol"] < hb["comms_to_tol"]
    assert chb["comms_to_tol"] < lag["comms_to_tol"]
    assert chb["iters_to_tol"] <= lag["iters_to_tol"]
    ratio = hb["comms_to_tol"] / chb["comms_to_tol"]
    row = csv_row("fig2_linreg", res,
                  f"chb_comms={chb['comms_to_tol']};hb_comms="
                  f"{hb['comms_to_tol']};saving_x={ratio:.2f}")
    return row, {"specs": specs_payload(res),
                 "comms_to_tol": {a: res[a]["comms_to_tol"]
                                  for a in specs_payload(res)}}


if __name__ == "__main__":
    print(main()[0])

"""Serving micro-benchmark: prefill + per-token decode wall-clock on the
REDUCED config of each family representative (CPU; real numbers come from
the TPU dry-run terms — this validates the serving path end-to-end and
gives the `us_per_call` figures for deliverable d)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.data.lm_data import MarkovLM
from repro.models import model

REPS = ["qwen3-4b", "mixtral-8x22b", "mamba2-780m", "jamba-1.5-large-398b"]


def bench_arch(arch: str, batch=4, prompt=64, gen=8):
    cfg = get(arch).reduced()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    lm = MarkovLM(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(lm.sample(rng, batch, prompt)[:, :-1])
    kwargs = {}
    if cfg.frontend:
        kwargs["enc_embeddings"] = jnp.asarray(
            0.3 * rng.standard_normal((batch, cfg.num_frontend_tokens,
                                       cfg.d_frontend)), cfg.jnp_dtype)
    prefix = cfg.num_frontend_tokens if cfg.frontend == "audio" else 0
    cache_len = prefix + prompt + gen + 1

    pre = jax.jit(lambda p, t: model.prefill(p, cfg, t, cache_len=cache_len,
                                             **kwargs))
    step = jax.jit(lambda p, c, t, pos: model.serve_step(p, cfg, c, t, pos))
    logits, cache = pre(params, prompts)            # compile
    jax.block_until_ready(logits)
    t0 = time.time()
    logits, cache = pre(params, prompts)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = step(params, cache, tok, jnp.asarray(prefix + prompt))
    jax.block_until_ready(logits2)                  # compile
    t0 = time.time()
    for i in range(gen):
        logits2, cache = step(params, cache, tok,
                              jnp.asarray(prefix + prompt + i))
    jax.block_until_ready(logits2)
    decode_us = (time.time() - t0) / gen * 1e6
    return prefill_s, decode_us


def main() -> str:
    print("\n== Serving path (reduced configs, CPU wall-clock) ==")
    parts = []
    decode_us_first = 0.0
    for arch in REPS:
        pre_s, dec_us = bench_arch(arch)
        if not decode_us_first:
            decode_us_first = dec_us
        print(f"{arch:24s} prefill={pre_s*1e3:8.1f}ms "
              f"decode={dec_us/1e3:8.1f}ms/tok")
        parts.append(f"{arch.split('-')[0]}={dec_us/1e3:.0f}ms")
    return f"serving,{decode_us_first:.0f},{';'.join(parts)}"


if __name__ == "__main__":
    print(main())

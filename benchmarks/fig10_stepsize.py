"""Fig. 10: step-size impact on communications (MNIST-scale linear
regression): smaller alpha can SAVE communications for censored methods."""
import numpy as np

from .common import compare_algorithms, csv_row
from repro.core import baselines, simulator
from repro.data import paper_tasks


def main() -> str:
    b = paper_tasks.make_standin("mnist", "linear")
    fstar = float(simulator.estimate_fstar(b.task, b.alpha_paper, 30000))
    print("\n== Fig. 10: step size vs comms (CHB), target err = 1e-2 rel ==")
    rows = []
    errs0 = None
    for scale in [1.0, 0.5, 0.25]:
        alpha = b.alpha_paper * scale
        cfg = baselines.chb(alpha, 9)
        hist = simulator.run(cfg, b.task, 4000)
        err = np.asarray(hist.objective) - fstar
        if errs0 is None:
            errs0 = err[0]
        target = 1e-2 * errs0
        k = simulator.iterations_to_accuracy(hist, fstar, target)
        c = simulator.comms_to_accuracy(hist, fstar, target)
        print(f"alpha={alpha:.3e} iters_to_target={k:5d} comms={c}")
        rows.append((scale, k, c))
    # paper: smaller step size -> more iterations but can cost FEWER comms
    assert rows[2][1] > rows[0][1]
    derived = ";".join(f"a{r[0]}:comms={r[2]}" for r in rows)
    return f"fig10_stepsize,0,{derived}"


if __name__ == "__main__":
    print(main())

"""Fig. 10: step-size impact on communications (MNIST-scale linear
regression): smaller alpha can SAVE communications for censored methods.

The three CHB step sizes run as one compiled sweep (eps1 follows the
paper's eps1 = 0.1/(alpha^2 M^2) rule, so it varies with alpha)."""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import opt, sweep
from repro.core import simulator
from repro.data import paper_tasks

SCALES = (1.0, 0.5, 0.25)


def main() -> tuple[str, dict]:
    b = paper_tasks.make_standin("mnist", "linear")
    fstar = float(simulator.estimate_fstar(b.task, b.alpha_paper, 30000))
    print("\n== Fig. 10: step size vs comms (CHB), target err = 1e-2 rel ==")
    points = []
    for scale in SCALES:
        o = opt.make("chb", b.alpha_paper * scale, 9)
        points.append(sweep.GridPoint(alpha=o.alpha, beta=o.beta,
                                      eps1=o.eps1))
    res = sweep.run_sweep(points, task=b.task, num_iters=4000)
    errs0 = float(np.asarray(res.history(0).objective)[0]) - fstar
    target = 1e-2 * errs0
    rows = []
    for scale, hist in zip(SCALES, res.histories):
        k = simulator.iterations_to_accuracy(hist, fstar, target)
        c = simulator.comms_to_accuracy(hist, fstar, target)
        print(f"alpha={scale * b.alpha_paper:.3e} iters_to_target={k:5d} "
              f"comms={c}")
        rows.append((scale, k, c))
    # paper: smaller step size -> more iterations but can cost FEWER comms
    assert rows[2][1] > rows[0][1]
    derived = ";".join(f"a{r[0]}:comms={r[2]}" for r in rows)
    payload = {"fstar": fstar, "target_err": target,
               "rows": [{"alpha_scale": r[0], "iters_to_target": r[1],
                         "comms_to_target": r[2]} for r in rows]}
    return f"fig10_stepsize,0,{derived}", payload


if __name__ == "__main__":
    print(main()[0])

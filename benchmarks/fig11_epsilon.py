"""Fig. 11: eps1 sweep — the communication/iteration trade-off knob.

Run on the Fig.-2 linear-regression setting (heterogeneous L_m), where the
paper's monotone trade-off is cleanly visible: larger eps1 -> fewer comms,
more iterations. (On our ill-conditioned logistic stand-in the trade-off
inverts — heavier censoring lengthens the large-||dtheta|| transient so the
total comms at tolerance RISES with eps1; recorded in EXPERIMENTS.md §Repro
as a deviation of the stand-in, not of the algorithm.)
"""
from repro.core import chb as chb_mod, simulator
from repro.core.censoring import paper_eps1
from repro.data import paper_tasks


def main() -> str:
    b = paper_tasks.make_linear_regression()   # Fig. 2 setting
    alpha = b.alpha_paper
    fstar = float(simulator.estimate_fstar(b.task, alpha, 40000))
    print("\n== Fig. 11: eps1 sweep (linreg synthetic, tol 1e-7) ==")
    rows = []
    for scale in [0.01, 0.1, 1.0]:
        cfg = chb_mod.FedOptConfig(alpha=alpha, beta=0.4,
                                   eps1=paper_eps1(alpha, 9, scale),
                                   num_workers=9)
        hist = simulator.run(cfg, b.task, 3000)
        k = simulator.iterations_to_accuracy(hist, fstar, 1e-7)
        c = simulator.comms_to_accuracy(hist, fstar, 1e-7)
        print(f"eps1_scale={scale:5.2f} iters={k:6d} comms={c}")
        rows.append((scale, k, c))
    comms = [r[2] for r in rows]
    iters = [r[1] for r in rows]
    # the paper's trade-off: comms monotone down, iterations monotone up
    assert comms == sorted(comms, reverse=True), comms
    assert iters == sorted(iters), iters
    derived = ";".join(f"e{r[0]}:c={r[2]},k={r[1]}" for r in rows)
    return f"fig11_epsilon,0,{derived}"


if __name__ == "__main__":
    print(main())

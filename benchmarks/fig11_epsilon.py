"""Fig. 11: eps1 sweep — the communication/iteration trade-off knob.

Run on the Fig.-2 linear-regression setting (heterogeneous L_m), where the
paper's monotone trade-off is cleanly visible: larger eps1 -> fewer comms,
more iterations. (On our ill-conditioned logistic stand-in the trade-off
inverts — heavier censoring lengthens the large-||dtheta|| transient so the
total comms at tolerance RISES with eps1; recorded in EXPERIMENTS.md §Repro
as a deviation of the stand-in, not of the algorithm.)

Since PR 2 this is also the sweep engine's headline: a dense 33-scale x
2-seed eps-grid (66 runs) executes as two compiled device programs, and we
time it against the old per-point ``simulator.run`` loop on the identical
grid. The engine must win by >=5x wall-clock (dispatch/compile overhead was
the bottleneck, not FLOPs).
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import opt, sweep
from repro.core import simulator
from repro.data import paper_tasks

SCALES = tuple(float(s) for s in np.logspace(-2.0, 0.0, 33))
SEEDS = (0, 1)
NUM_ITERS = 3000
M = 9
TOL = 1e-7


def _task_factory(seed: int, m: int):
    return paper_tasks.make_linear_regression(m=m, seed=seed).task


def main() -> tuple[str, dict]:
    b = paper_tasks.make_linear_regression()   # Fig. 2 setting, seed 0
    alpha = b.alpha_paper
    fstar = {s: float(simulator.estimate_fstar(_task_factory(s, M), alpha,
                                               40000)) for s in SEEDS}
    grid = sweep.ConfigGrid(alpha=(alpha,), beta=(0.4,), eps1_scale=SCALES,
                            seed=SEEDS, num_workers=(M,))
    res = sweep.run_sweep(grid, task_factory=_task_factory,
                          num_iters=NUM_ITERS)

    # the pre-sweep-engine baseline: one fresh trace+jit per grid point
    # (tasks prebuilt — we time the dispatch overhead, not data generation)
    tasks = {s: _task_factory(s, M) for s in SEEDS}
    t0 = time.perf_counter()
    for p in res.points:
        o = opt.ComposedOptimizer(
            censor=opt.Eq8Censor(p.eps1), transport=opt.DenseTransport(),
            server=opt.HeavyBall(p.alpha, p.beta), num_workers=M)
        hist = simulator.run(o, tasks[p.seed], NUM_ITERS)
        hist.objective.block_until_ready()
    t_loop = time.perf_counter() - t0
    speedup = t_loop / res.elapsed_s

    rows = res.frontier(fstar, TOL)
    print(f"\n== Fig. 11: eps1 sweep (linreg synthetic, tol {TOL:g}) ==")
    print(f"{len(res.points)} grid points in {res.num_programs} compiled "
          f"programs: sweep {res.elapsed_s:.2f}s vs per-point loop "
          f"{t_loop:.2f}s -> {speedup:.1f}x")
    by_scale = {}
    # grid order: eps axis is outer, seed axis inner (row-major field order)
    for i, s in enumerate(SCALES):
        r = rows[i * len(SEEDS)]           # seed 0 row for this scale
        by_scale[s] = (r["iters_to_tol"], r["comms_to_tol"])
        print(f"eps1_scale={s:7.4f} iters={r['iters_to_tol']:6d} "
              f"comms={r['comms_to_tol']}")

    # the paper's trade-off on the canonical scales (0.01, 0.1, 1.0)
    canon = [SCALES[0], SCALES[16], SCALES[32]]
    assert abs(canon[1] - 0.1) < 1e-12, canon
    iters = [by_scale[s][0] for s in canon]
    comms = [by_scale[s][1] for s in canon]
    assert comms == sorted(comms, reverse=True), comms
    assert iters == sorted(iters), iters
    # dense-grid trend + the engine's reason to exist
    assert by_scale[SCALES[0]][1] > by_scale[SCALES[-1]][1]
    assert speedup >= 5.0, f"sweep engine speedup {speedup:.1f}x < 5x"

    derived = (f"speedup={speedup:.1f}x;"
               + ";".join(f"e{s:.2f}:c={by_scale[s][1]},k={by_scale[s][0]}"
                          for s in canon))
    payload = {
        "speedup_vs_loop": speedup,
        "elapsed_sweep_s": res.elapsed_s,
        "elapsed_loop_s": t_loop,
        "num_points": len(res.points),
        "num_programs": res.num_programs,
        "tol": TOL,
        "fstar": fstar,
        "frontier": rows,
        "backend": "reference",
        "specs": list(res.specs),
    }
    return f"fig11_epsilon,0,{derived}", payload


if __name__ == "__main__":
    print(main()[0])

"""Fig. 12: averaged per-communication descent VERSUS OBJECTIVE ERROR
(the paper's x-axis): descent/comm = (f(theta^0) - f(theta^k)) / comms at the
first iteration reaching each target error. CHB extracts more descent per
uplink than censored GD, and the per-comm descent decays as the error
target tightens (both paper observations).

CHB and LAG are two points of one compiled sweep program.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import opt, sweep
from repro.core import simulator
from repro.data import paper_tasks


def main() -> tuple[str, dict]:
    b = paper_tasks.make_linear_regression()   # heterogeneous-L_m setting
    alpha = b.alpha_paper
    fstar = float(simulator.estimate_fstar(b.task, alpha, 40000))
    f0 = float(simulator.global_loss(b.task, b.task.init_params))
    err0 = f0 - fstar
    levels = [1e-2 * err0, 1e-4 * err0, 1e-7 * err0]
    print("\n== Fig. 12: descent per communication vs objective error ==")
    names = ("chb", "lag")
    points = []
    for name in names:
        o = opt.make(name, alpha, 9)
        points.append(sweep.GridPoint(alpha=o.alpha, beta=o.beta,
                                      eps1=o.eps1))
    res = sweep.run_sweep(points, task=b.task, num_iters=3000)
    table = {}
    for name, hist in zip(names, res.histories):
        row = []
        for lv in levels:
            c = simulator.comms_to_accuracy(hist, fstar, lv)
            k = simulator.iterations_to_accuracy(hist, fstar, lv)
            d = (f0 - float(np.asarray(hist.objective)[k])) / max(c, 1)
            row.append(d)
        table[name] = row
        print(f"{name:4s} " + " ".join(f"{d:.4e}" for d in row))
    # CHB > LAG at every error level; descent/comm decays with tighter error
    for i in range(len(levels)):
        assert table["chb"][i] > table["lag"][i], (i, table)
    assert table["chb"][-1] < table["chb"][0]
    payload = {"fstar": fstar, "error_levels": levels,
               "descent_per_comm": table}
    return (f"fig12_descent,0,chb@1e-7={table['chb'][-1]:.3e};"
            f"lag@1e-7={table['lag'][-1]:.3e}", payload)


if __name__ == "__main__":
    print(main()[0])

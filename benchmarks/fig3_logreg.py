"""Fig. 3: logistic regression with common smoothness L_m = 4 for all
workers — censoring helps even with homogeneous workers."""
from .common import compare_algorithms, csv_row, print_table
from repro.data import paper_tasks


def main() -> str:
    b = paper_tasks.make_logistic_regression()
    res = compare_algorithms(b, num_iters=6000, tol=1e-5)
    print_table("Fig. 3: logreg synthetic, common L_m=4 (tol 1e-5)", res)
    chb, hb, lag = res["chb"], res["hb"], res["lag"]
    # paper claims: CHB saves comms vs HB even with homogeneous workers,
    # at nearly the same iteration count, and converges in fewer iterations
    # than censored GD (the momentum advantage).
    assert chb["comms_to_tol"] < 0.5 * hb["comms_to_tol"]
    assert chb["iters_to_tol"] <= 1.1 * hb["iters_to_tol"]
    assert chb["iters_to_tol"] < lag["iters_to_tol"]
    ratio = hb["comms_to_tol"] / max(chb["comms_to_tol"], 1)
    return csv_row("fig3_logreg", res,
                   f"chb_comms={chb['comms_to_tol']};saving_x={ratio:.2f}")


if __name__ == "__main__":
    print(main())

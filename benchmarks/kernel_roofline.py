"""Kernel roofline: analytic HBM sweeps per CHB step, both opt backends.

The censored step is memory-bound — every stage is an elementwise pass or
a reduction over parameter-sized tensors — so the right roofline metric is
*parameter-sweep equivalents per iteration*: how many times the step reads
or writes a parameter-sized array from HBM. The analytic model below
counts them stage by stage for the reference jnp path, the staged pallas
path (one kernel per stage), and the fused megakernel path (the default
pallas route: everything after ``censor.decide`` in ONE sweep per leaf).

    dense step (M workers, P params/worker bank rows):
      reference:     delta materialize (2R+W per bank row) + sqnorm
                     reduction (2R) + bank advance (3R+W) + aggregate (R)
                     + hb (3R+W)
      pallas staged: fused sqnorm (2R) + fused advance (2R+W)
                     + aggregate (R) + fused hb (3R+W)
      pallas fused:  fused sqnorm (2R) + megakernel (2R+W per row, plus
                     theta/theta_prev reads and agg/theta writes at 4/M)
                     + the diagnostic agg recompute (R). Byte-for-byte
                     this EQUALS the staged route — the dense win is
                     launch count (one kernel, not three) and removing
                     the agg HBM round-trip between them.

    int8 is where fusion pays in bytes: the staged route materializes the
    pending tree on the host (delta + prepare) before quantizing; the
    fused route's stats kernel reads (g, ghat, err) directly and the
    megakernel re-derives pending in-register — the pending tree never
    exists in HBM.

Two *measured* views are reported side by side, because they disagree for
an instructive reason:

  * ``measured_bytes["reference"/"pallas"]`` — XLA's own
    ``cost_analysis`` "bytes accessed" for one compiled step. For the
    reference backend this is a fair count. For the pallas backend on CPU
    it **over-counts by ~20x**: the Pallas interpreter lowers each grid
    step to HLO dynamic-slice/dynamic-update-slice emulation, so every
    block copy and SMEM scalar broadcast is billed as fresh buffer
    traffic. It is kept in the artifact as a regression tripwire, not as
    a traffic estimate.
  * ``measured_bytes["pallas_*_kernel_*"]`` — the
    ``kernels.common.track_kernel_bytes`` recorder: padded operand +
    result bytes of every ``pallas_call`` traced for one step. This is
    the Mosaic-equivalent HBM traffic and is the number the 1.5x
    roofline acceptance check is asserted against (at a lane-aligned
    shape; tiny paper tensors pad 20 -> 128 lanes and measure the
    padding, not the algorithm).

The benchmark also measures the **trace/retrace count** across an
(alpha, eps1) hyperparameter grid: traced SMEM hyperparameter operands
mean the whole grid compiles each kernel dispatch exactly once (the old
``static_argnames`` wrappers recompiled per point).

Finally a backend-crossover shape ladder (n = 50 -> 1e6 per leaf) times
one composed step for reference vs staged-pallas vs fused-pallas. On this
CPU container the pallas numbers run through the interpreter and are
*validation* numbers, not performance numbers — the analytic sweep table
is the hardware story, the ladder is the scaling/crossover story.
"""
import contextlib
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro import opt, sweep
from repro.data import paper_tasks
from repro.kernels import common as kernel_common
from repro.kernels import fused_step
from repro.kernels import ops as kernel_ops
from repro.obs import hlo_report

# REPRO_BENCH_FAST=1: CI-smoke shapes — same code paths, tiny grid/problem
FAST = os.environ.get("REPRO_BENCH_FAST", "") not in ("", "0")

M = 5
NUM_ITERS = 40 if FAST else 300
ALPHAS = (0.5, 1.0) if FAST else (0.25, 0.5, 1.0)   # x alpha_paper
EPS_SCALES = (0.1,) if FAST else (0.05, 0.1, 0.2)
TASK_SHAPE = dict(m=M, n_per=10, d=8) if FAST else dict(m=M, n_per=30, d=20)
# crossover ladder: per-leaf element counts, all multiples of the 128-lane
# tile past the first (50 pads to one 128-lane row — the padding-dominated
# regime the docstring warns about)
LADDER = (50, 1024, 32768) if FAST else (50, 1024, 32768, 262144, 1048576)
LADDER_REPS = 2 if FAST else 3
# 32768 = 256 rows x 128 lanes: zero padding, zero block remainder — the
# shape the measured-vs-analytic acceptance ratio is asserted at
ALIGNED_N = 32768
ROOFLINE_TOL = 1.5


def analytic_sweeps(quantize: bool) -> dict[str, float]:
    """Parameter-sweep equivalents per step, per worker bank row.

    R/W of one parameter-sized tensor = 1 sweep. The per-worker bank
    terms dominate (the hb update is 1/M of the bank traffic).
    """
    if not quantize:
        reference = (2 + 1) + 2 + (3 + 1)       # delta, sqnorm, advance
        staged = 2 + (2 + 1)                    # fused sqnorm, fused adv
        # sweep-1 sqnorm (2R) + megakernel (2R + W) + diagnostic agg
        # recompute (R); theta/prev/agg epilogue traffic rides in the
        # shared 1/M terms below
        fused = 2 + (2 + 1) + 1
    else:
        # delta+prepare, sqnorm, absmax, quantize, feedback, advance
        reference = (2 + 1) + (2 + 1) + 2 + 1 + (2 + 1) + (3 + 1) \
            + (3 + 1)
        staged = (2 + 1) + (2 + 1) + 1 + (2 + 2) + (2 + 1)
        # stats kernel reads (g, ghat, err) = 3R; megakernel reads the
        # same three and writes new_ghat + new_err = 3R + 2W; + recompute
        fused = 3 + (3 + 2) + 1
    shared = (1 + (3 + 1) / M)                  # aggregate + hb, per row
    out = {"reference": reference + shared,
           "pallas_staged": staged + shared,
           "pallas_fused": fused + shared}
    out["ratio_staged"] = out["reference"] / out["pallas_staged"]
    out["ratio_fused"] = out["reference"] / out["pallas_fused"]
    return out


def _step_inputs(task, alpha_paper, backend, quantize=None):
    o = opt.make("chb", alpha_paper, M, backend=backend, quantize=quantize)
    state = o.init(task.init_params)
    grads = jax.vmap(task.grad_fn, in_axes=(None, 0))(
        task.init_params, task.worker_data)
    return o, state, grads


def _route_ctx(route: str):
    return fused_step.force_staged() if route == "staged" \
        else contextlib.nullcontext()


def measured_traces(backend: str, task, alpha_paper) -> dict:
    """Trace counts + wall-clock for the (alpha, eps1) grid, one backend."""
    grid = sweep.ConfigGrid(
        alpha=tuple(a * alpha_paper for a in ALPHAS),
        beta=(0.4,), eps1_scale=EPS_SCALES)
    base = opt.make("chb", alpha_paper, M, backend=backend)
    kernel_ops.reset_trace_counts()
    t0 = time.perf_counter()
    res = sweep.run_sweep(grid, task, num_iters=NUM_ITERS, base_cfg=base)
    dt = time.perf_counter() - t0
    final = [float(np.asarray(h.objective)[-1]) for h in res.histories]
    return {"points": len(res), "programs": res.num_programs,
            "kernel_traces": dict(kernel_ops.trace_counts),
            "elapsed_s": dt, "final_objective": final}


def step_bytes(backend: str, task, alpha_paper) -> dict:
    """XLA ``cost_analysis`` vs analytic bytes for ONE dense composed step.

    Measured = the compiler's own "bytes accessed" for the compiled step
    (``obs.hlo_report.cost_summary``); analytic = the sweep model above
    times the bank row size. See the module docstring for why the pallas
    measured number is an interpreter-emulation over-count — the honest
    kernel traffic is ``kernel_traffic`` below. The ratio is reported,
    not asserted; what *is* meaningful is tracking either number across
    commits (``tools/bench_diff.py``).
    """
    o, state, grads = _step_inputs(task, alpha_paper, backend)
    cost = hlo_report.cost_summary(
        lambda s, p, g: o.step(s, p, g), state, task.init_params, grads)
    row_bytes = sum(np.asarray(x).nbytes for x in
                    jax.tree_util.tree_leaves(state.ghat)) / M
    key = "pallas_fused" if backend == "pallas" else "reference"
    analytic = analytic_sweeps(False)[key] * row_bytes * M
    return {"measured_bytes_accessed": cost["bytes_accessed"],
            "analytic_bytes": analytic,
            "measured_flops": cost["flops"],
            "bank_row_bytes": row_bytes}


def kernel_traffic(task, alpha_paper) -> dict:
    """Per-pallas-call HBM bytes for one step: staged vs fused, per mode.

    Counts padded operand + result bytes at trace time
    (``kernels.common.track_kernel_bytes``) — the Mosaic-equivalent HBM
    traffic, immune to the interpreter's cost_analysis inflation. The
    per-kernel breakdown is the per-stage bytes story: the fused routes
    replace advance/aggregate/hb (and, for int8, quantize+EF) with one
    megakernel entry.
    """
    out = {}
    for mode, quantize in (("dense", None), ("int8", "int8")):
        for route in ("staged", "fused"):
            o, state, grads = _step_inputs(task, alpha_paper, "pallas",
                                           quantize)
            with kernel_common.track_kernel_bytes() as rec, \
                    _route_ctx(route):
                jax.jit(o.step).lower(state, task.init_params, grads)
            out[f"{mode}_{route}"] = {"total": rec.total(),
                                      "per_kernel": dict(rec.bytes)}
    return out


def _synthetic_step(n: int, route: str, quantize=None):
    """One composed CHB step over a single (n,)-element f32 leaf."""
    backend = "reference" if route == "reference" else "pallas"
    o = opt.make("chb", 0.05, M, backend=backend, quantize=quantize)
    rng = np.random.default_rng(7)
    params = {"w": jnp.asarray(rng.standard_normal(n), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal((M, n)), jnp.float32)}
    state = o.init(params)
    step = jax.jit(o.step)
    with kernel_common.track_kernel_bytes() as rec, _route_ctx(route):
        jax.block_until_ready(step(state, params, grads))   # trace+compile
    return step, (state, params, grads), rec


def shape_ladder() -> list[dict]:
    """Backend crossover: one dense step, reference vs staged vs fused.

    Pallas rows run the interpreter on CPU, so elapsed times are about
    scaling behaviour (where the jnp path's extra materialized sweeps
    start to cost) rather than absolute speed; ``kernel_bytes`` is the
    recorder's real per-step kernel traffic (0 for the reference route,
    which issues no pallas calls).
    """
    rows = []
    for n in LADDER:
        for route in ("reference", "staged", "fused"):
            step, args, rec = _synthetic_step(n, route)
            times = []
            for _ in range(LADDER_REPS):
                t0 = time.perf_counter()
                jax.block_until_ready(step(*args))
                times.append(time.perf_counter() - t0)
            rows.append({"n": n, "route": route,
                         "us_per_step": min(times) * 1e6,
                         "kernel_bytes": rec.total()})
    return rows


def roofline_check() -> dict:
    """The acceptance ratio: fused kernel bytes vs analytic, aligned shape.

    At ``ALIGNED_N`` (256 rows x 128 lanes: no padding, no block
    remainder) the recorder total for one fused step must be within
    ``ROOFLINE_TOL`` of the hand-counted pallas-call traffic, for dense
    AND int8. Analytic counts full-leaf sweeps (f32 = 4 bytes/elt):

      dense: sweep-1 sqnorm reads (g, ghat) = 2M; megakernel reads
             (g, ghat) + writes new_ghat = 3M, plus theta + theta_prev
             reads and agg + new_theta writes = 4.         -> 5M + 4
      int8:  stats kernel reads (g, ghat, err) = 3M; megakernel reads
             those three + writes (new_ghat, new_err) = 5M, plus the
             same epilogue 4.                              -> 8M + 4
    """
    leaf_bytes = ALIGNED_N * 4
    analytic = {"dense": (5 * M + 4) * leaf_bytes,
                "int8": (8 * M + 4) * leaf_bytes}
    out = {}
    for mode, quantize in (("dense", None), ("int8", "int8")):
        _, _, rec = _synthetic_step(ALIGNED_N, "fused", quantize)
        ratio = rec.total() / analytic[mode]
        assert ratio <= ROOFLINE_TOL, (
            f"{mode} fused step kernel traffic {rec.total():.0f}B is "
            f"{ratio:.2f}x the analytic roofline "
            f"{analytic[mode]:.0f}B (tolerance {ROOFLINE_TOL}x)")
        out[mode] = {"n": ALIGNED_N, "measured_bytes": rec.total(),
                     "analytic_bytes": float(analytic[mode]),
                     "ratio": ratio, "per_kernel": dict(rec.bytes)}
    return out


def main() -> tuple[str, dict]:
    b = paper_tasks.make_linear_regression(seed=0, **TASK_SHAPE)
    task = b.task

    analytic = {"dense": analytic_sweeps(False),
                "int8": analytic_sweeps(True)}
    print("analytic HBM sweeps per step (per worker bank row):")
    for mode, row in analytic.items():
        print(f"  {mode:6s} reference={row['reference']:.2f} "
              f"staged={row['pallas_staged']:.2f} "
              f"fused={row['pallas_fused']:.2f} "
              f"ratio={row['ratio_fused']:.2f}x")

    bytes_moved = {be: step_bytes(be, task, b.alpha_paper)
                   for be in opt.BACKENDS}
    print("dense-step HBM bytes (measured = XLA cost_analysis; the pallas"
          " row is interpreter-inflated, see module docstring):")
    for be, rowb in bytes_moved.items():
        ratio = rowb["measured_bytes_accessed"] / max(
            1.0, rowb["analytic_bytes"])
        print(f"  {be:9s} measured={rowb['measured_bytes_accessed']:.3g}B "
              f"analytic={rowb['analytic_bytes']:.3g}B "
              f"(x{ratio:.2f} of model)")

    traffic = kernel_traffic(task, b.alpha_paper)
    print("per-step pallas kernel traffic (trace-time recorder):")
    for key, rowt in traffic.items():
        print(f"  {key:13s} total={rowt['total']:.0f}B over "
              f"{len(rowt['per_kernel'])} kernel(s)")

    roof = roofline_check()
    for mode, rowr in roof.items():
        print(f"  roofline {mode}: measured/analytic = "
              f"{rowr['ratio']:.2f}x at n={rowr['n']} "
              f"(tol {ROOFLINE_TOL}x)")

    ladder = shape_ladder()
    print("crossover ladder (one dense step; pallas = interpreter):")
    for n in LADDER:
        cells = {r["route"]: r for r in ladder if r["n"] == n}
        print(f"  n={n:>8d} " + " ".join(
            f"{route}={cells[route]['us_per_step']:.0f}us/"
            f"{cells[route]['kernel_bytes']:.2g}B"
            for route in ("reference", "staged", "fused")))

    measured = {be: measured_traces(be, task, b.alpha_paper)
                for be in opt.BACKENDS}
    for be, row in measured.items():
        print(f"  {be:9s} {row['points']} grid points -> "
              f"{row['programs']} compiled program(s), kernel traces "
              f"{row['kernel_traces'] or '{}'}, {row['elapsed_s']:.2f}s")

    # trajectories of the two backends must agree (bit-exact at f64)
    drift = max(abs(a - r) for a, r in
                zip(measured["pallas"]["final_objective"],
                    measured["reference"]["final_objective"]))
    assert drift == 0.0, f"backend trajectories drifted: {drift}"

    # the headline regression: every pallas kernel dispatch traced once
    traces = measured["pallas"]["kernel_traces"]
    assert traces and all(v == 1 for v in traces.values()), traces

    n_points = measured["pallas"]["points"]
    us = measured["pallas"]["elapsed_s"] / (n_points * NUM_ITERS) * 1e6
    row = (f"kernel_roofline,{us:.1f},"
           f"dense_sweep_ratio={analytic['dense']['ratio_fused']:.2f}x"
           f";int8_sweep_ratio={analytic['int8']['ratio_fused']:.2f}x"
           f";retraces=0")
    payload = {"analytic_sweeps": analytic, "measured": measured,
               "backend": list(opt.BACKENDS),
               "fast": FAST,
               "measured_bytes": {
                   **{be: rowb["measured_bytes_accessed"]
                      for be, rowb in bytes_moved.items()},
                   **{f"pallas_{route}_kernel_{mode}":
                      traffic[f"{mode}_{route}"]["total"]
                      for mode in ("dense", "int8")
                      for route in ("staged", "fused")}},
               "analytic_bytes": {
                   be: rowb["analytic_bytes"]
                   for be, rowb in bytes_moved.items()},
               "bytes_detail": {"xla_cost_analysis": bytes_moved,
                                "kernel_traffic": traffic,
                                "roofline_check": roof,
                                "ladder": ladder},
               "specs": {be: opt.to_spec(
                   opt.make("chb", b.alpha_paper, M, backend=be))
                   for be in opt.BACKENDS}}
    return row, payload


if __name__ == "__main__":
    print(main()[0])

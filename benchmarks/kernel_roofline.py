"""Kernel roofline: analytic HBM sweeps per CHB step, both opt backends.

The censored step is memory-bound — every stage is an elementwise pass or
a reduction over parameter-sized tensors — so the right roofline metric is
*parameter-sweep equivalents per iteration*: how many times the step reads
or writes a parameter-sized array from HBM. The analytic model below
counts them stage by stage for the reference jnp path (every tree_map is
at least one read + one write that XLA cannot always fuse across stage
boundaries) and for the fused pallas path.

    dense step (M workers, P params/worker bank rows):
      reference: delta materialize (2R+W per bank row) + sqnorm reduction
                 (2R) + bank advance (3R+W) + aggregate (R) + hb (3R+W)
      pallas:    fused sqnorm (2R) + fused advance (2R+W) + aggregate (R)
                 + fused hb (3R+W)

    int8 adds: reference absmax/quantize/feedback as separate sweeps;
    pallas one absmax (R) + ONE fused quantize+EF sweep (2R+2W).

Secondly, the benchmark measures the **trace/retrace count** across an
(alpha, eps1) hyperparameter grid for both backends — the PR's bugfix
headline: traced SMEM hyperparameter operands mean the whole grid compiles
each kernel dispatch exactly once (the old ``static_argnames`` wrappers
recompiled per point).

Wall-clock of the two backends is also timed, but on this CPU container
the pallas numbers run through the interpreter (``interpret=True``) and
are *validation* numbers, not performance numbers — the analytic sweep
table is the hardware story, the measured table is the no-retrace story.
"""
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import opt, sweep
from repro.data import paper_tasks
from repro.kernels import ops as kernel_ops
from repro.obs import hlo_report

# REPRO_BENCH_FAST=1: CI-smoke shapes — same code paths, tiny grid/problem
FAST = os.environ.get("REPRO_BENCH_FAST", "") not in ("", "0")

M = 5
NUM_ITERS = 40 if FAST else 300
ALPHAS = (0.5, 1.0) if FAST else (0.25, 0.5, 1.0)   # x alpha_paper
EPS_SCALES = (0.1,) if FAST else (0.05, 0.1, 0.2)
TASK_SHAPE = dict(m=M, n_per=10, d=8) if FAST else dict(m=M, n_per=30, d=20)


def analytic_sweeps(quantize: bool) -> dict[str, float]:
    """Parameter-sweep equivalents per step, per worker bank row.

    R/W of one parameter-sized tensor = 1 sweep. The per-worker bank
    terms dominate (the hb update is 1/M of the bank traffic).
    """
    if not quantize:
        reference = (2 + 1) + 2 + (3 + 1)       # delta, sqnorm, advance
        pallas = 2 + (2 + 1)                    # fused sqnorm, fused adv
    else:
        # delta+prepare, sqnorm, absmax, quantize, feedback, advance
        reference = (2 + 1) + (2 + 1) + 2 + 1 + (2 + 1) + (3 + 1) \
            + (3 + 1)
        pallas = (2 + 1) + (2 + 1) + 1 + (2 + 2) + (2 + 1)
    shared = (1 + (3 + 1) / M)                  # aggregate + hb, per row
    return {"reference": reference + shared, "pallas": pallas + shared,
            "ratio": (reference + shared) / (pallas + shared)}


def measured_traces(backend: str, task, alpha_paper) -> dict:
    """Trace counts + wall-clock for the (alpha, eps1) grid, one backend."""
    grid = sweep.ConfigGrid(
        alpha=tuple(a * alpha_paper for a in ALPHAS),
        beta=(0.4,), eps1_scale=EPS_SCALES)
    base = opt.make("chb", alpha_paper, M, backend=backend)
    kernel_ops.reset_trace_counts()
    t0 = time.perf_counter()
    res = sweep.run_sweep(grid, task, num_iters=NUM_ITERS, base_cfg=base)
    dt = time.perf_counter() - t0
    final = [float(np.asarray(h.objective)[-1]) for h in res.histories]
    return {"points": len(res), "programs": res.num_programs,
            "kernel_traces": dict(kernel_ops.trace_counts),
            "elapsed_s": dt, "final_objective": final}


def step_bytes(backend: str, task, alpha_paper) -> dict:
    """Measured vs analytic HBM bytes for ONE dense composed step.

    Measured = XLA's own ``cost_analysis`` "bytes accessed" for the
    compiled step (``obs.hlo_report.cost_summary``); analytic = the sweep
    model above times the bank row size. The two count different things —
    XLA sees every buffer the program touches (task data included), the
    model only parameter-sized stage traffic — so the ratio is reported,
    not asserted; what *is* meaningful is tracking either number across
    commits (``tools/bench_diff.py``).
    """
    o = opt.make("chb", alpha_paper, M, backend=backend)
    state = o.init(task.init_params)
    grads = jax.vmap(task.grad_fn, in_axes=(None, 0))(
        task.init_params, task.worker_data)
    cost = hlo_report.cost_summary(
        lambda s, p, g: o.step(s, p, g), state, task.init_params, grads)
    row_bytes = sum(np.asarray(x).nbytes for x in
                    jax.tree_util.tree_leaves(state.ghat)) / M
    analytic = analytic_sweeps(False)[backend] * row_bytes * M
    return {"measured_bytes_accessed": cost["bytes_accessed"],
            "analytic_bytes": analytic,
            "measured_flops": cost["flops"],
            "bank_row_bytes": row_bytes}


def main() -> tuple[str, dict]:
    b = paper_tasks.make_linear_regression(seed=0, **TASK_SHAPE)
    task = b.task

    analytic = {"dense": analytic_sweeps(False),
                "int8": analytic_sweeps(True)}
    print("analytic HBM sweeps per step (per worker bank row):")
    for mode, row in analytic.items():
        print(f"  {mode:6s} reference={row['reference']:.2f} "
              f"pallas={row['pallas']:.2f} ratio={row['ratio']:.2f}x")

    bytes_moved = {be: step_bytes(be, task, b.alpha_paper)
                   for be in opt.BACKENDS}
    print("dense-step HBM bytes (measured = XLA cost_analysis):")
    for be, rowb in bytes_moved.items():
        ratio = rowb["measured_bytes_accessed"] / max(
            1.0, rowb["analytic_bytes"])
        print(f"  {be:9s} measured={rowb['measured_bytes_accessed']:.3g}B "
              f"analytic={rowb['analytic_bytes']:.3g}B "
              f"(x{ratio:.2f} of model)")

    measured = {be: measured_traces(be, task, b.alpha_paper)
                for be in opt.BACKENDS}
    for be, row in measured.items():
        print(f"  {be:9s} {row['points']} grid points -> "
              f"{row['programs']} compiled program(s), kernel traces "
              f"{row['kernel_traces'] or '{}'}, {row['elapsed_s']:.2f}s")

    # trajectories of the two backends must agree (bit-exact at f64)
    drift = max(abs(a - r) for a, r in
                zip(measured["pallas"]["final_objective"],
                    measured["reference"]["final_objective"]))
    assert drift == 0.0, f"backend trajectories drifted: {drift}"

    # the headline regression: every pallas kernel dispatch traced once
    traces = measured["pallas"]["kernel_traces"]
    assert traces and all(v == 1 for v in traces.values()), traces

    n_points = measured["pallas"]["points"]
    us = measured["pallas"]["elapsed_s"] / (n_points * NUM_ITERS) * 1e6
    row = (f"kernel_roofline,{us:.1f},"
           f"dense_sweep_ratio={analytic['dense']['ratio']:.2f}x"
           f";int8_sweep_ratio={analytic['int8']['ratio']:.2f}x"
           f";retraces=0")
    payload = {"analytic_sweeps": analytic, "measured": measured,
               "backend": list(opt.BACKENDS),
               "fast": FAST,
               "measured_bytes": {
                   be: rowb["measured_bytes_accessed"]
                   for be, rowb in bytes_moved.items()},
               "analytic_bytes": {
                   be: rowb["analytic_bytes"]
                   for be, rowb in bytes_moved.items()},
               "bytes_detail": bytes_moved,
               "specs": {be: opt.to_spec(
                   opt.make("chb", b.alpha_paper, M, backend=be))
                   for be in opt.BACKENDS}}
    return row, payload


if __name__ == "__main__":
    print(main()[0])

"""Edge-deployment scenario sweep: CHB vs HB vs LAG vs GD under realistic
wireless conditions, reporting *energy-to-accuracy* and
*wall-clock-to-accuracy* — the costs the paper motivates (Sec. I) but never
measures.

Scenarios (all on the paper's 9-worker linear-regression task):
  ideal          zero-latency lossless channel, full participation — the
                 sync anchor; numbers here match the core simulator.
  lossy          1 Mbps uplink, 20% Bernoulli packet loss.
  stragglers     2 of 9 clients are 15x slower (exp jitter); the server
                 advances on an 8/9 quorum and folds late uplinks stale.
  fading         block-fading uplink bitrate (Rayleigh-power multiplier).
  partial        server samples 50% of clients per round (alpha halved —
                 scheduler-forced staleness shrinks the stable step range).

  PYTHONPATH=src python -m benchmarks.fig_edge_scenarios [--rounds N]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)   # paper experiments run in f64

from repro import opt
from repro.core import simulator
from repro.data import paper_tasks
from repro import fed

ALGOS = ["chb", "hb", "lag", "gd"]


def scenarios(m: int) -> dict:
    return {
        "ideal": dict(
            edge=lambda seed: fed.sync_config(m, seed=seed),
            alpha_scale=1.0),
        "lossy": dict(
            edge=lambda seed: fed.EdgeConfig(
                population=fed.uniform_population(m, compute_mean_s=1.0),
                channel=fed.ChannelConfig.lossy(0.2, uplink_rate_bps=1e6),
                seed=seed),
            alpha_scale=1.0),
        "stragglers": dict(
            edge=lambda seed: fed.EdgeConfig(
                population=fed.straggler_population(
                    m, compute_mean_s=1.0, straggler_frac=0.22,
                    straggler_slowdown=15.0, jitter="exp", seed=seed),
                channel=fed.ChannelConfig(uplink_rate_bps=1e6),
                quorum=8.0 / 9.0, seed=seed),
            alpha_scale=1.0),
        "fading": dict(
            edge=lambda seed: fed.EdgeConfig(
                population=fed.uniform_population(m, compute_mean_s=1.0),
                channel=fed.ChannelConfig.fading(uplink_rate_bps=1e6),
                seed=seed),
            alpha_scale=1.0),
        "partial": dict(
            edge=lambda seed: fed.EdgeConfig(
                population=fed.uniform_population(m, compute_mean_s=1.0,
                                                  participation=0.5),
                channel=fed.ChannelConfig(uplink_rate_bps=1e6),
                seed=seed),
            alpha_scale=0.5),
    }


def main(rounds: int = 600) -> tuple[str, dict]:
    m = 9
    bundle = paper_tasks.make_linear_regression(m=m)
    fstar = float(simulator.estimate_fstar(bundle.task, bundle.alpha_paper,
                                           40000))
    tol = 1e-6
    hdr = (f"{'scenario':12s} {'algo':5s} {'rounds':>7s} {'uplinks':>8s} "
           f"{'MB':>8s} {'energy J':>9s} {'wall s':>8s}")
    print(f"\n== edge scenarios: {{uplinks, bytes, energy, wall-clock}} to "
          f"f-f* < {tol:g} ==")
    chb_wins = 0
    rows = []
    specs: dict[str, dict] = {}
    results: dict[str, dict] = {}
    for sname, sc in scenarios(m).items():
        print("\n" + hdr)
        per_algo = {}
        for algo in ALGOS:
            cfg = opt.make(algo, bundle.alpha_paper * sc["alpha_scale"], m)
            specs[f"{sname}/{algo}"] = opt.to_spec(cfg)
            hist = fed.run_edge(cfg, bundle.task, sc["edge"](seed=17),
                                rounds)
            met = fed.edge_metrics_to_accuracy(hist, fstar, tol)
            per_algo[algo] = met
            mb = met["bytes"] / 1e6 if met["bytes"] >= 0 else -1
            print(f"{sname:12s} {algo:5s} {met['rounds']:7d} "
                  f"{met['uplinks']:8d} {mb:8.2f} "
                  f"{met['energy_j']:9.2f} {met['wall_clock_s']:8.2f}")
            rows.append((sname, algo, met))
        results[sname] = per_algo
        # headline: CHB reaches target with fewer uplinks than HB
        if 0 <= per_algo["chb"]["uplinks"] < per_algo["hb"]["uplinks"] or \
                per_algo["hb"]["uplinks"] < 0 <= per_algo["chb"]["uplinks"]:
            chb_wins += 1
    n_scen = len(scenarios(m))
    print(f"\nCHB fewer-uplinks-than-HB in {chb_wins}/{n_scen} scenarios")
    reached = sum(1 for _, a, met in rows
                  if a == "chb" and met["rounds"] >= 0)
    row = (f"fig_edge_scenarios,0,chb_wins={chb_wins}/{n_scen};"
           f"chb_reached={reached}/{n_scen}")
    payload = {"backend": "reference", "specs": specs, "tol": tol,
               "fstar": fstar, "rounds": rounds, "scenarios": results}
    return row, payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=600)
    args = ap.parse_args()
    print(main(rounds=args.rounds)[0])

"""Transport zoo: uplink bytes-to-accuracy frontier across all registered
transports — dense, int8, top-k, low-rank — for CHB on the paper's
synthetic linear-regression task (the Fig. 2 setting).

Each compressed curve is CHB with a task-scaled transport (see
``common.task_transport``); "matched final loss" means first reaching the
same objective-error tolerance as the dense run. Per-communication bytes
come from the transport's exact ``payload_bytes`` accounting, so
``bytes_to_tol = comms_to_tol * per_comm_bytes`` is exact, not estimated.

``REPRO_BENCH_FAST=1`` shrinks the task and iteration count to a CI-smoke
shape (same curves, same assertions, looser tolerance).
"""
import os

from .common import compare_algorithms, csv_row, print_table, specs_payload
from repro import opt
from repro.data import paper_tasks

TRANSPORT_KINDS = ("int8", "topk", "lowrank")


def _frontier(res, bundle, tol):
    """Per-curve byte-frontier rows, keyed by curve name."""
    rows = {}
    for name in ["chb"] + [f"chb_{k}" for k in TRANSPORT_KINDS]:
        r = res[name]
        o = opt.from_spec(r["spec"])
        per_comm = o.transport.payload_bytes(bundle.task.init_params)
        comms = r["comms_to_tol"]
        rows[name] = {
            "transport": r["spec"]["transport"],
            "final_err": r["final_err"],
            "comms_to_tol": comms,
            "per_comm_bytes": per_comm,
            "bytes_to_tol": None if comms is None else comms * per_comm,
            "uplink_bytes_total": r["uplink_bytes"],
            "tol": tol,
        }
    return rows


def main():
    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    if fast:
        bundle = paper_tasks.make_linear_regression(m=5, n_per=30, d=20,
                                                    seed=0)
        num_iters, tol, fstar_iters = 1500, 1e-4, 8000
    else:
        bundle = paper_tasks.make_linear_regression()
        num_iters, tol, fstar_iters = 3000, 1e-4, 40000
    res = compare_algorithms(bundle, num_iters=num_iters, tol=tol,
                             fstar_iters=fstar_iters,
                             transports=TRANSPORT_KINDS)
    print_table(f"Transport zoo: linreg synthetic (tol {tol:g})", res,
                metric_keys=("comms_to_tol", "final_err", "uplink_bytes"))
    frontier = _frontier(res, bundle, tol)

    # every curve converges (EF sparsification at the paper step size is
    # only stable at the task-scaled densities common.task_transport picks)
    for name, row in frontier.items():
        assert row["final_err"] < 1e-2, (name, row["final_err"])
        assert row["comms_to_tol"] is not None, name
    # headline claim: at matched final loss, at least one compressed
    # transport spends fewer uplink bytes than dense CHB
    dense_bytes = frontier["chb"]["bytes_to_tol"]
    best = min((k for k in TRANSPORT_KINDS),
               key=lambda k: frontier[f"chb_{k}"]["bytes_to_tol"])
    best_bytes = frontier[f"chb_{best}"]["bytes_to_tol"]
    assert best_bytes < dense_bytes, (best, best_bytes, dense_bytes)

    ratio = dense_bytes / best_bytes
    row = csv_row("transport_zoo", res,
                  f"dense_bytes={dense_bytes};best={best};"
                  f"best_bytes={best_bytes};saving_x={ratio:.2f}")
    return row, {"specs": specs_payload(res), "frontier": frontier,
                 "tol": tol, "fast": fast}


if __name__ == "__main__":
    print(main()[0])

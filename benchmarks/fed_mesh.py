"""Fed-mesh scaling: 10^5-client frontier + clients-vs-wall-clock ladder.

The ISSUE deliverable for the mesh-sharded federated runtime
(``repro.fed.mesh``, guide: docs/fed_scaling.md): run a federated sweep
with >= 10^5 clients on 8 XLA host devices and report

  * a **scenario frontier** — bytes / energy / wall-clock / accuracy for
    a small grid of deployment scenarios (participation, uplink loss,
    quorum) at 10^5 clients, with the accuracy target honest because the
    O(M*d) ``edge_quadratics`` task has a closed-form optimum; and
  * a **scaling ladder** — host wall-clock per synchronous round as the
    client count climbs to 10^6, the "does the client axis actually
    scale" story (``collect_mask=False``, ``bake_data=False`` — the
    documented 10^6-client knobs; masks and counts are unchanged).

The benchmark driver's process is pinned to one device (XLA reads
``XLA_FLAGS`` at first backend init), so the measured body runs in a
subprocess with ``--xla_force_host_platform_device_count=8`` — the same
harness ``tests/test_distributed.py`` uses for the mesh exactness pins.

Numbers land in ``BENCH_fed_mesh.json``; CI runs the fast shapes and
gates against the committed ``BENCH_fed_mesh_smoke.json`` baseline via
``tools/bench_diff.py``. In-benchmark assertions are the functional
gate: the ideal scenario must converge to f*, censoring must save bytes
versus transmit-everything, and every ladder rung must complete.
"""
import json
import os
import subprocess
import sys
import textwrap

# REPRO_BENCH_FAST=1: CI-smoke shapes — same code paths, tiny population
FAST = os.environ.get("REPRO_BENCH_FAST", "") not in ("", "0")

DEVICES = 8
FRONTIER_M = 800 if FAST else 100_000
FRONTIER_ROUNDS = 60 if FAST else 80
LADDER_M = (400, 800, 1600) if FAST else (100_000, 250_000, 500_000,
                                          1_000_000)
LADDER_ROUNDS = 3 if FAST else 5

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The measured body. Runs on 8 host devices in a fresh process; prints
# exactly one JSON line on the last stdout line (everything else it may
# print is progress noise the parent ignores).
_SUB = textwrap.dedent("""
    import json
    import time

    import jax

    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro import fed, opt
    from repro.data import edge_tasks
    from repro.launch import mesh as mk

    CFG = json.loads({cfg!r})
    K = CFG["devices"]
    assert jax.device_count() == K, (jax.device_count(), K)
    mesh = mk.make_client_mesh(K)

    # ---- scenario frontier at FRONTIER_M clients ----------------------
    M = CFG["frontier_m"]
    R = CFG["frontier_rounds"]
    task = edge_tasks.make_edge_quadratics(M, d=16, seed=0)
    fstar = edge_tasks.edge_quadratics_fstar(task)
    # 0.5/M keeps alpha * L ~ mean(a)/2 < 1 at any M (curvatures are
    # log-uniform over [1, 3]). For the eq.-(8) censor, delta_sq tracks
    # a_m^2 * step_sq on a quadratic, so eps1=4 censors the flat half of
    # the curvature spread until their deltas accumulate — the frontier's
    # byte axis actually moves
    o = opt.make("chb", 0.5 / M, M, eps1=4.0)
    pop = fed.uniform_vector_population(M, compute_mean_s=0.05,
                                       straggler_frac=0.1, seed=1)
    chan = fed.ChannelConfig()
    en = fed.EnergyModel()
    payload = o.transport.payload_bytes(task.init_params)

    SCENARIOS = (("ideal", 1.0, 0.0, 1.0),
                 ("lossy", 1.0, 0.2, 0.7),
                 ("partial", 0.5, 0.0, 0.5),
                 ("harsh", 0.5, 0.3, 0.5))
    frontier = []
    for name, part, loss, quo in SCENARIOS:
        sc = fed.MeshScenario(participation=part, loss_prob=loss,
                              quorum=quo, seed=3)
        t0 = time.perf_counter()
        mh = fed.run_mesh(o, task, R, mesh=mesh, scenario=sc,
                          population=pop, channel=chan, energy=en,
                          collect_mask=False, bake_data=False)
        host_s = time.perf_counter() - t0
        frontier.append(dict(
            scenario=name, participation=part, loss_prob=loss,
            quorum=quo, rounds=R,
            uplink_bytes=int(mh.bytes_cum[-1]),
            attempted=int(mh.attempted.sum()),
            joules=float(mh.energy_cum[-1]),
            sim_wall_s=float(mh.wall_clock[-1]),
            host_s=round(host_s, 2),
            quorum_met_frac=float(mh.quorum_met.mean()),
            gap0=float(mh.objective[0] - fstar),
            gap=float(mh.objective[-1] - fstar)))

    # ---- clients-vs-wall-clock ladder ---------------------------------
    LR = CFG["ladder_rounds"]
    ladder = []
    for m in CFG["ladder_m"]:
        t = edge_tasks.make_edge_quadratics(m, d=16, seed=0)
        ol = opt.make("chb", 0.5 / m, m, eps1=4.0)
        t0 = time.perf_counter()
        mh = fed.run_mesh(ol, t, LR, mesh=mesh,
                          scenario=fed.MeshScenario(seed=0),
                          collect_mask=False, bake_data=False)
        total = time.perf_counter() - t0
        assert np.isfinite(mh.objective).all()
        ladder.append(dict(clients=m, rounds=LR,
                           total_s=round(total, 2),
                           s_per_round=round(total / LR, 3),
                           client_rounds_per_s=round(m * LR / total)))

    print(json.dumps(dict(frontier=frontier, ladder=ladder,
                          payload_bytes=payload, fstar=fstar,
                          devices=K)))
""")


def _run_sub(cfg: dict) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{cfg['devices']}")
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    code = _SUB.format(cfg=json.dumps(cfg))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError("fed_mesh subprocess failed:\n"
                           + r.stdout[-2000:] + r.stderr[-2000:])
    return json.loads(r.stdout.splitlines()[-1])


def main() -> tuple[str, dict]:
    cfg = dict(devices=DEVICES, frontier_m=FRONTIER_M,
               frontier_rounds=FRONTIER_ROUNDS,
               ladder_m=list(LADDER_M), ladder_rounds=LADDER_ROUNDS)
    out = _run_sub(cfg)
    frontier, ladder = out["frontier"], out["ladder"]

    print(f"fed_mesh: {DEVICES} host devices, frontier at "
          f"{FRONTIER_M:,} clients, ladder to {LADDER_M[-1]:,}")
    print(f"{'scenario':>9} {'part':>5} {'loss':>5} {'quo':>4} "
          f"{'MBytes':>9} {'kJ':>8} {'sim_s':>8} {'gap/gap0':>9}")
    for row in frontier:
        rel = row["gap"] / row["gap0"]
        print(f"{row['scenario']:>9} {row['participation']:>5.2f} "
              f"{row['loss_prob']:>5.2f} {row['quorum']:>4.2f} "
              f"{row['uplink_bytes'] / 1e6:>9.2f} "
              f"{row['joules'] / 1e3:>8.2f} {row['sim_wall_s']:>8.1f} "
              f"{rel:>9.2e}")
    print(f"{'clients':>10} {'rounds':>6} {'s/round':>8} "
          f"{'client-rounds/s':>16}")
    for row in ladder:
        print(f"{row['clients']:>10,} {row['rounds']:>6} "
              f"{row['s_per_round']:>8.3f} "
              f"{row['client_rounds_per_s']:>16,}")

    # functional gates: the ideal scenario converges to the closed-form
    # optimum; censoring beats transmit-everything on bytes; every rung
    # of the ladder completed with finite objectives (asserted in-sub)
    ideal = frontier[0]
    assert ideal["scenario"] == "ideal"
    assert ideal["gap"] < 1e-3 * ideal["gap0"], \
        f"ideal scenario did not converge: {ideal}"
    naive = FRONTIER_M * FRONTIER_ROUNDS * out["payload_bytes"]
    assert ideal["uplink_bytes"] < naive, "censoring saved no bytes"
    assert [row["clients"] for row in ladder] == list(LADDER_M)
    # accuracy under deployment stress stays bounded: every scenario
    # improved on its starting gap
    assert all(row["gap"] < row["gap0"] for row in frontier)

    us = ladder[-1]["s_per_round"] * 1e6
    row = (f"fed_mesh,{us:.1f},"
           f"clients_max={LADDER_M[-1]};devices={DEVICES};"
           f"ideal_relgap={ideal['gap'] / ideal['gap0']:.2e}")
    payload = dict(row=row, backend="cpu", fast=FAST,
                   devices=DEVICES, payload_bytes=out["payload_bytes"],
                   fstar=out["fstar"], frontier=frontier, ladder=ladder,
                   spec=None)
    return row, payload


if __name__ == "__main__":
    print(main()[0])

"""Table II: small-dataset suite (Housing/Bodyfat/Abalone linear; Ionosphere/
Adult/Derm logistic+lasso; Adult NN), 3 workers, alpha=1/L. Synthetic
stand-ins with matched (n, d, M)."""
from .common import compare_algorithms, csv_row, print_table
from repro.data import paper_tasks


def main() -> str:
    rows = []
    suites = [("housing", "linear", 1e-7), ("bodyfat", "linear", 1e-7),
              ("abalone", "linear", 1e-7), ("ionosphere", "logistic", 1e-5),
              ("adult", "logistic", 1e-5), ("derm", "lasso", 1e-5)]
    res = None
    for ds, kind, tol in suites:
        b = paper_tasks.make_standin(ds, kind)
        res = compare_algorithms(b, num_iters=2500, tol=tol)
        print_table(f"Table II: {ds} {kind} (tol {tol})", res)
        chb, hb = res["chb"], res["hb"]
        if chb["comms_to_tol"] > 0 and hb["comms_to_tol"] > 0:
            assert chb["comms_to_tol"] <= hb["comms_to_tol"], ds
            rows.append(f"{ds}={hb['comms_to_tol']/chb['comms_to_tol']:.1f}x")
    return csv_row("table2_small", res, ";".join(rows))


if __name__ == "__main__":
    print(main())

"""Recompile one dry-run case and print the largest collective/HBM ops
(trip-count weighted) — the hillclimb microscope."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse, sys
import jax
sys.path.insert(0, "src")
from repro.configs import ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_case
from repro.launch import hlo_analysis as ha
from repro.models import tuning

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", default="train_4k")
ap.add_argument("--moe-mode", default="scan")
ap.add_argument("--opt", action="append", default=[])
ap.add_argument("--top", type=int, default=14)
args = ap.parse_args()

mesh = make_production_mesh()
for o in args.opt:
    tuning.set_flags(**{o: True})
if args.opt:
    tuning.set_mesh(mesh)
kw = {}
if args.shape == "train_4k":
    kw["moe_mode"] = args.moe_mode
case = build_case(ARCHS[args.arch], args.shape, mesh, strategy="scan", **kw)
with mesh:
    hlo = jax.jit(case.fn, donate_argnums=case.donate).lower(*case.args)\
        .compile().as_text()

comps = ha.parse_module(hlo)
entry = next(c for c in comps.values() if c.is_entry)
edges = {c: [] for c in comps}
for comp in comps.values():
    for i in comp.instrs:
        if i.opcode == "while":
            bm = ha._BODY_RE.search(i.rest); cm = ha._COND_RE.search(i.rest)
            trips = ha._trip_count(comps[cm.group(1)]) if cm else 1
            if bm: edges[comp.name].append((bm.group(1), trips, True))
            if cm: edges[comp.name].append((cm.group(1), trips, False))
        else:
            keeps = i.opcode in ("call", "conditional")
            for callee in ha._CALLS_RE.findall(i.rest):
                if callee in comps:
                    edges[comp.name].append((callee, 1, keeps))
order, seen = [], set()
def topo(n):
    if n in seen: return
    seen.add(n)
    for c, _, _ in edges[n]: topo(c)
    order.append(n)
topo(entry.name)
mult = {c: 0.0 for c in comps}; mult[entry.name] = 1.0
control = {entry.name}
for name in reversed(order):
    for callee, t, k in edges[name]:
        mult[callee] += mult[name] * t
        if name in control and k: control.add(callee)

colls, hbms = [], []
for cn, comp in comps.items():
    m = mult[cn]
    if m == 0: continue
    sym = comp.symbol_table()
    for i in comp.instrs:
        for k in ha.COLLECTIVE_OPS:
            if i.opcode in (k, k + "-start"):
                w = 2 if k == "all-reduce" else 1
                colls.append((m * w * ha.shape_bytes(i.result_type), m, k,
                              i.result_type[:70], i.rest[:90]))
        if cn in control and i.opcode not in ha._SKIP_BYTES_OPS and \
                i.opcode != "while" and not i.opcode.endswith("-done"):
            hbms.append((m * ha._instr_hbm_bytes(i, sym, comps), m,
                         i.opcode, i.name[:40], i.result_type[:60]))
print("== top collectives (bytes x trips) ==")
for b, m, k, ty, rest in sorted(colls, reverse=True)[:args.top]:
    print(f"{b/1e9:9.1f}GB m={m:7.0f} {k:18s} {ty}")
print("== top HBM ops ==")
for b, m, op, nm, ty in sorted(hbms, reverse=True)[:args.top]:
    print(f"{b/1e9:9.1f}GB m={m:7.0f} {op:18s} {nm:40s} {ty}")

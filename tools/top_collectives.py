"""Recompile one dry-run case and print the largest collective/HBM ops
(trip-count weighted) — the hillclimb microscope.

Thin CLI over ``repro.obs.hlo_report``: the call-graph walk, trip-count
weighting, and per-op ranking live there (shared with tests and artifact
writers); this script only builds the case and prints the tables."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import sys

sys.path.insert(0, "src")
from repro.configs import ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_case
from repro.models import tuning
from repro.obs import hlo_report

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", default="train_4k")
ap.add_argument("--moe-mode", default="scan")
ap.add_argument("--opt", action="append", default=[])
ap.add_argument("--top", type=int, default=14)
args = ap.parse_args()

mesh = make_production_mesh()
for o in args.opt:
    tuning.set_flags(**{o: True})
if args.opt:
    tuning.set_mesh(mesh)
kw = {}
if args.shape == "train_4k":
    kw["moe_mode"] = args.moe_mode
case = build_case(ARCHS[args.arch], args.shape, mesh, strategy="scan", **kw)
with mesh:
    hlo = hlo_report.compiled_text(case.fn, *case.args,
                                   donate_argnums=case.donate)

print(hlo_report.format_report(hlo_report.report(hlo, top=args.top)))

"""Minimal reproducer for an XLA SPMD partitioner CHECK-failure.

F spmd_partitioner_util.cc:504 Check failed:
  partition_group_list.num_replica_groups() *
  partition_group_list.num_devices_per_group()
  == device_groups.num_devices_per_group()

Trigger: a lax.scan (while loop) whose body touches a MODEL-axis-sharded
array, inside a shard_map that is partial-manual over a "pod" axis, on a
(2,16,16) host-device mesh (jax 0.8.2 / CPU PJRT). The same program
compiles fine on a (2,2,2) mesh, and without the while loop, and with the
array sharded over the data axis only. A pure-pjit vmap-over-pods variant
crashes identically, so this is not specific to shard_map.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
        PYTHONPATH=src python tools/xla_partitioner_repro.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
D, B = 256, 64
W = jax.device_put(jnp.ones((D, D)), NamedSharding(mesh, P(None, "model")))
x = jax.device_put(jnp.ones((B, D)), NamedSharding(mesh, P(("pod", "data"))))


def inner(w, xx):
    def body(h, _):
        return jnp.tanh(h @ w), None
    h, _ = jax.lax.scan(body, xx, None, length=3)
    return jax.lax.psum(jnp.mean(h), "pod")


f = jax.shard_map(inner, mesh=mesh, in_specs=(P(), P("pod")),
                  out_specs=P(), axis_names={"pod"}, check_vma=False)
with mesh:
    print(jax.jit(f)(W, x))  # aborts in the SPMD partitioner

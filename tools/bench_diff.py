"""Compare two BENCH_*.json artifacts and print regressions.

  PYTHONPATH=src python tools/bench_diff.py OLD.json NEW.json [--tol-pct 25]

Reads two ``repro.obs.bench`` artifacts (schema-validated on load) and
reports, per benchmark:

  * benchmarks that disappeared or newly fail;
  * ``us_per_call`` slowdowns beyond ``--tol-pct`` (wall-clock is noisy —
    default tolerance is generous; tighten it on quiet machines);
  * measured HBM bytes (``measured_bytes``) growth beyond ``--tol-pct``
    — the roofline accounting moving is a real program change, not noise;
  * kernel retraces: any per-dispatch trace count that grew between
    artifacts (``measured.*.kernel_traces``), which means a compile-cache
    regression.

Exit status 1 if any regression was found, 0 otherwise — usable directly
as a CI gate between a checked-in baseline artifact and a fresh run.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs import bench  # noqa: E402


def _us_per_call(payload: dict) -> float | None:
    row = payload.get("row", "")
    parts = row.split(",")
    if len(parts) < 2:
        return None
    try:
        return float(parts[1])
    except ValueError:
        return None


def _trace_counts(payload: dict) -> dict[str, dict]:
    """{backend: {dispatch: count}} where the payload recorded them."""
    out = {}
    measured = payload.get("measured")
    if isinstance(measured, dict):
        for be, rec in measured.items():
            if isinstance(rec, dict) and \
                    isinstance(rec.get("kernel_traces"), dict):
                out[be] = rec["kernel_traces"]
    return out


def diff(old: dict, new: dict, tol_pct: float) -> list[str]:
    """All regressions of ``new`` relative to ``old`` (empty = clean)."""
    regressions: list[str] = []
    old_b, new_b = old["benchmarks"], new["benchmarks"]

    for name in sorted(old_b):
        if name not in new_b:
            if name in new.get("failed", []):
                regressions.append(f"{name}: newly FAILING")
            else:
                regressions.append(f"{name}: missing from new artifact")
            continue
        op, np_ = old_b[name], new_b[name]

        o_us, n_us = _us_per_call(op), _us_per_call(np_)
        if o_us and n_us and o_us > 0 and n_us > o_us * (1 + tol_pct / 100):
            regressions.append(
                f"{name}: us_per_call {o_us:.1f} -> {n_us:.1f} "
                f"(+{(n_us / o_us - 1) * 100:.0f}% > {tol_pct:g}%)")

        o_bytes = op.get("measured_bytes") or {}
        n_bytes = np_.get("measured_bytes") or {}
        for be in sorted(set(o_bytes) & set(n_bytes)):
            ob, nb = float(o_bytes[be]), float(n_bytes[be])
            if ob > 0 and nb > ob * (1 + tol_pct / 100):
                regressions.append(
                    f"{name}: measured_bytes[{be}] {ob:.3g} -> {nb:.3g} "
                    f"(+{(nb / ob - 1) * 100:.0f}% > {tol_pct:g}%)")

        o_tr, n_tr = _trace_counts(op), _trace_counts(np_)
        for be in sorted(set(o_tr) & set(n_tr)):
            for k in sorted(set(o_tr[be]) | set(n_tr[be])):
                ov, nv = o_tr[be].get(k, 0), n_tr[be].get(k, 0)
                if nv > ov:
                    regressions.append(
                        f"{name}: retrace {be}/{k} {ov} -> {nv}")

    for name in sorted(set(new.get("failed", [])) - set(old.get("failed",
                                                                []))):
        if f"{name}: newly FAILING" not in regressions:
            regressions.append(f"{name}: newly FAILING")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json artifacts; exit 1 on regression")
    ap.add_argument("old", help="baseline artifact")
    ap.add_argument("new", help="candidate artifact")
    ap.add_argument("--tol-pct", type=float, default=25.0,
                    help="allowed growth in us_per_call / measured bytes "
                         "before it counts as a regression (default 25)")
    args = ap.parse_args(argv)

    old = bench.load_artifact(args.old)
    new = bench.load_artifact(args.new)
    print(f"old: {args.old} ({len(old['benchmarks'])} benchmark(s), "
          f"env {old['env']})")
    print(f"new: {args.new} ({len(new['benchmarks'])} benchmark(s), "
          f"env {new['env']})")
    if old["env"] != new["env"]:
        print("note: environments differ; wall-clock deltas may be noise")

    regressions = diff(old, new, args.tol_pct)
    both = sorted(set(old["benchmarks"]) & set(new["benchmarks"]))
    for name in both:
        o_us = _us_per_call(old["benchmarks"][name])
        n_us = _us_per_call(new["benchmarks"][name])
        if o_us is not None and n_us is not None:
            print(f"  {name}: us_per_call {o_us:.1f} -> {n_us:.1f}")
    if regressions:
        print(f"\n{len(regressions)} regression(s):")
        for r in regressions:
            print(f"  REGRESSION {r}")
        return 1
    print("\nno regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Compare two repro-lint findings artifacts and print what changed.

  PYTHONPATH=src python tools/lint_diff.py OLD.json NEW.json

Reads two ``repro-lint-findings/v1`` artifacts (as written by
``python -m repro.lint --json-file``, schema-checked on load) and
reports, keyed by ``(rule, path, message)`` so line-number drift from
unrelated edits does not register:

  * findings introduced since the baseline (the CI gate);
  * findings that went from active to suppressed — each must carry a
    reason, which is printed for review;
  * findings resolved outright (informational).

Exit status 1 if any finding was introduced, 0 otherwise — usable
directly as a CI gate between the baseline artifact of the target branch
and a fresh run, mirroring ``tools/bench_diff.py``.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.lint import load_artifact  # noqa: E402


def _key(f: dict) -> tuple:
    return (f["rule"], f["path"], f["message"])


def _where(f: dict) -> str:
    return f"{f['path']}:{f['line']}: {f['rule']}"


def diff(old: dict, new: dict) -> tuple[list[dict], list[dict], list[dict]]:
    """(introduced, newly_suppressed, resolved) of ``new`` vs ``old``."""
    old_active = {_key(f): f for f in old["findings"]}
    old_any = old_active | {_key(f): f for f in old["suppressed"]}
    new_active = {_key(f): f for f in new["findings"]}
    new_sup = {_key(f): f for f in new["suppressed"]}

    introduced = [f for k, f in sorted(new_active.items())
                  if k not in old_any]
    newly_suppressed = [f for k, f in sorted(new_sup.items())
                       if k in old_active]
    resolved = [f for k, f in sorted(old_active.items())
                if k not in new_active and k not in new_sup]
    return introduced, newly_suppressed, resolved


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two repro-lint findings artifacts; "
                    "exit 1 when findings were introduced")
    ap.add_argument("old", help="baseline artifact")
    ap.add_argument("new", help="candidate artifact")
    args = ap.parse_args(argv)

    old = load_artifact(args.old)
    new = load_artifact(args.new)
    for label, art, path in (("old", old, args.old), ("new", new, args.new)):
        c = art["counts"]
        print(f"{label}: {path} ({c['findings']} finding(s), "
              f"{c['suppressed']} suppressed)")

    introduced, newly_suppressed, resolved = diff(old, new)
    for f in resolved:
        print(f"  resolved   {_where(f)}")
    for f in newly_suppressed:
        print(f"  suppressed {_where(f)} -- reason: {f.get('reason')}")
    if introduced:
        print(f"\n{len(introduced)} finding(s) introduced:")
        for f in introduced:
            print(f"  INTRODUCED {_where(f)}: {f['message']}")
        return 1
    print("\nno findings introduced")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
